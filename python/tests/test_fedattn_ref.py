"""FedAttn reference-simulator tests: the H=1 ≡ CenAttn identity, mask
semantics, sparse KV exchange, and monotone error growth."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig
from compile import model as M
from compile import fedattn_ref as F


MC = ModelConfig(
    name="t", vocab_size=128, d_model=48, n_layers=4, n_heads=4,
    n_kv_heads=2, head_dim=12, d_ff=64,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(MC, jax.random.PRNGKey(1))


def episode_ids(L=48, n=3, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(32, 127, size=L).astype(np.int32)
    owners = np.minimum(np.arange(L) * n // L, n - 1).astype(np.int32)
    return ids, owners


def test_h1_equals_centralized(params):
    ids, owners = episode_ids()
    sched = F.FedSchedule.uniform(MC.n_layers, 3, 1)
    fed = F.fedattn_forward(MC, params, ids, owners, sched)
    cen = M.forward_hidden(MC, params, jnp.asarray(ids))
    np.testing.assert_allclose(np.asarray(fed), np.asarray(cen), atol=1e-4)


def test_mask_full_sync_is_causal():
    ids, owners = episode_ids(L=12)
    pos = np.arange(12, dtype=np.int32)
    sync = F.BlockSync(participants=(0, 1, 2))
    mask = F.build_mask(owners, pos, sync, 3)
    want = np.where(pos[:, None] >= pos[None, :], 0.0, F.NEG)
    np.testing.assert_array_equal(mask, want.astype(np.float32))


def test_mask_local_block_is_block_diagonal():
    ids, owners = episode_ids(L=12)
    pos = np.arange(12, dtype=np.int32)
    mask = F.build_mask(owners, pos, F.BlockSync(()), 3)
    for i in range(12):
        for j in range(12):
            visible = mask[i, j] == 0.0
            want = owners[i] == owners[j] and j <= i
            assert visible == want, (i, j)


def test_mask_partial_attendance():
    # Only participant 0 attends: it sees transmitted remote rows; others
    # stay local.
    ids, owners = episode_ids(L=12)
    pos = np.arange(12, dtype=np.int32)
    mask = F.build_mask(owners, pos, F.BlockSync((0,)), 3)
    # participant 0 owns the first third; it can see nothing ahead of it
    # (causality) but that's all it owns anyway. Participant 2's rows (last
    # third) never see remote rows.
    last = 11
    assert owners[last] == 2
    for j in range(12):
        visible = mask[last, j] == 0.0
        assert visible == (owners[j] == 2 and j <= last)


def test_sparse_kv_exchange_hides_remote_rows(params):
    ids, owners = episode_ids()
    n = 3
    # Participant 0 transmits nothing.
    tx = {0: np.zeros((owners == 0).sum(), dtype=bool)}
    blocks = [F.BlockSync(tuple(range(n)), transmitted=tx)
              for _ in range(MC.n_layers)]
    fed = F.fedattn_forward(MC, params, ids, owners, F.FedSchedule(blocks))
    # Equivalent: participant 0's rows only ever visible to itself.
    full = F.fedattn_forward(
        MC, params, ids, owners,
        F.FedSchedule([F.BlockSync(tuple(range(n))) for _ in range(MC.n_layers)]))
    # Rows owned by others must differ (they lost participant 0's context).
    d = np.abs(np.asarray(fed) - np.asarray(full))[owners != 0]
    assert d.max() > 1e-4


def test_error_grows_with_h(params):
    ids, owners = episode_ids()
    cen = np.asarray(M.forward_hidden(MC, params, jnp.asarray(ids)))
    devs = []
    for h in [1, 2, 4]:
        sched = F.FedSchedule.uniform(MC.n_layers, 3, h)
        fed = np.asarray(F.fedattn_forward(MC, params, ids, owners, sched))
        devs.append(float(np.linalg.norm(fed - cen)))
    assert devs[0] < 1e-3
    assert devs[1] <= devs[2] + 1e-6
    assert devs[2] > devs[0]


def test_publisher_logits_position(params):
    ids, owners = episode_ids()
    sched = F.FedSchedule.uniform(MC.n_layers, 3, 2)
    logits = F.fedattn_logits(MC, params, ids, owners, sched, publisher=2)
    assert logits.shape == (1, MC.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
