"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle.

Randomized sweeps over shapes, GQA ratios and mask patterns with fixed
seeds (hypothesis-style; the library itself is not installed offline).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.attention import pallas_mha
from compile.kernels.ref import mha_ref, NEG


def rand_inputs(rng, L, G, Hq, Hkv, hd, mask_p=0.6):
    q = jnp.asarray(rng.standard_normal((L, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((G, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((G, Hkv, hd)), jnp.float32)
    mask = jnp.where(
        jnp.asarray(rng.random((L, G))) < mask_p, 0.0, NEG
    ).astype(jnp.float32)
    return q, k, v, mask


@pytest.mark.parametrize(
    "L,G,Hq,Hkv,hd",
    [
        (32, 64, 4, 2, 24),     # base preset shape
        (32, 128, 4, 2, 24),
        (64, 128, 4, 4, 16),    # MHA (no grouping)
        (64, 64, 8, 2, 8),      # 4x GQA
        (96, 192, 2, 1, 32),
        (32, 64, 4, 2, 40),     # wide preset head_dim
    ],
)
def test_kernel_matches_ref_shapes(L, G, Hq, Hkv, hd):
    rng = np.random.default_rng(L * 1000 + G)
    q, k, v, mask = rand_inputs(rng, L, G, Hq, Hkv, hd)
    got = pallas_mha(q, k, v, mask, block_q=32, block_kv=32)
    want = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("seed", range(8))
def test_kernel_random_sweep(seed):
    rng = np.random.default_rng(seed)
    L = 32 * int(rng.integers(1, 4))
    G = 64 * int(rng.integers(1, 4))
    Hkv = int(rng.integers(1, 3))
    Hq = Hkv * int(rng.integers(1, 4))
    hd = int(rng.integers(2, 10)) * 4
    q, k, v, mask = rand_inputs(rng, L, G, Hq, Hkv, hd, mask_p=float(rng.random()))
    got = pallas_mha(q, k, v, mask)
    want = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fully_masked_rows_are_zero():
    rng = np.random.default_rng(0)
    q, k, v, _ = rand_inputs(rng, 32, 64, 4, 2, 24)
    mask = jnp.full((32, 64), NEG, jnp.float32)
    out = pallas_mha(q, k, v, mask)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_causal_mask_pattern():
    # With a causal mask over equal L=G, row i must only depend on rows <= i.
    rng = np.random.default_rng(1)
    L = 64
    q, k, v, _ = rand_inputs(rng, L, L, 4, 2, 24)
    i = jnp.arange(L)
    mask = jnp.where(i[:, None] >= i[None, :], 0.0, NEG).astype(jnp.float32)
    out1 = pallas_mha(q, k, v, mask)
    # Perturb the last KV row: rows < L-1 must not change.
    k2 = k.at[-1].add(10.0)
    v2 = v.at[-1].add(10.0)
    out2 = pallas_mha(q, k2, v2, mask)
    np.testing.assert_allclose(
        np.asarray(out1[:-1]), np.asarray(out2[:-1]), atol=1e-6
    )
    assert float(jnp.max(jnp.abs(out1[-1] - out2[-1]))) > 1e-3


def test_mask_large_negative_not_nan():
    rng = np.random.default_rng(2)
    q, k, v, mask = rand_inputs(rng, 32, 64, 4, 2, 24, mask_p=0.05)
    out = np.asarray(pallas_mha(q, k, v, mask))
    assert np.isfinite(out).all()


def test_gqa_broadcast_equivalence():
    # GQA with duplicated KV heads must equal MHA on the duplicated tensor.
    rng = np.random.default_rng(3)
    L, G, Hkv, hd = 32, 64, 2, 16
    group = 2
    Hq = Hkv * group
    q, k, v, mask = rand_inputs(rng, L, G, Hq, Hkv, hd)
    k_full = jnp.repeat(k, group, axis=1)
    v_full = jnp.repeat(v, group, axis=1)
    got = pallas_mha(q, k, v, mask)
    want = pallas_mha(q, k_full, v_full, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_block_size_invariance():
    # The same inputs through different tilings must agree (the online
    # softmax is associative across KV tiles).
    rng = np.random.default_rng(4)
    q, k, v, mask = rand_inputs(rng, 64, 128, 4, 2, 24)
    a = pallas_mha(q, k, v, mask, block_q=32, block_kv=32)
    b = pallas_mha(q, k, v, mask, block_q=64, block_kv=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
