"""L2 model-piece tests: shapes, RoPE properties, block decomposition
consistency (fused == projected+attended), decode-path equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import PRESETS, ModelConfig
from compile import model as M


MC = ModelConfig(
    name="test", vocab_size=128, d_model=48, n_layers=2, n_heads=4,
    n_kv_heads=2, head_dim=12, d_ff=64,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(MC, jax.random.PRNGKey(0))


def test_param_count_matches_config(params):
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == MC.param_count()


def test_rope_preserves_norm():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 2, 12)),
                    jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        atol=1e-4,
    )


def test_rope_relative_positions():
    # RoPE inner products depend only on relative offsets: shifting both
    # positions by a constant leaves q·k unchanged.
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 12)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 12)), jnp.float32)
    def dot_at(pq, pk):
        qr = M.rope(q, jnp.asarray([pq], jnp.int32))
        kr = M.rope(k, jnp.asarray([pk], jnp.int32))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(3, 1) - dot_at(13, 11)) < 1e-4


def test_block_fused_equals_decomposed(params):
    rng = np.random.default_rng(2)
    L = 32
    x = jnp.asarray(rng.standard_normal((L, MC.d_model)), jnp.float32)
    pos = jnp.arange(L, dtype=jnp.int32)
    mask = M.causal_mask(L)
    bp = M.block_params(params, 0)
    fused_x, fused_k, fused_v = M.block_fused(
        MC, x, pos, mask, *bp, use_pallas=False)
    q, k, v = M.qkv_project(MC, x, pos, *bp[:7])
    x2 = M.attn_ffn(MC, x, q, k, v, mask, *bp[7:], use_pallas=False)
    np.testing.assert_allclose(np.asarray(fused_x), np.asarray(x2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused_k), np.asarray(k), atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused_v), np.asarray(v), atol=1e-6)


def test_decode_block_matches_prefill_last_row(params):
    """Decoding token L-1 against the cache of tokens 0..L-2 must equal the
    prefill block output at row L-1 — the KV-cache correctness property."""
    rng = np.random.default_rng(3)
    L = 16
    x = jnp.asarray(rng.standard_normal((L, MC.d_model)), jnp.float32)
    pos = jnp.arange(L, dtype=jnp.int32)
    mask = M.causal_mask(L)
    bp = M.block_params(params, 0)
    full_x, full_k, full_v = M.block_fused(MC, x, pos, mask, *bp, use_pallas=False)

    C = 24  # padded cache
    kc = jnp.zeros((C, MC.n_kv_heads, MC.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    kc = kc.at[: L - 1].set(full_k[: L - 1])
    vc = vc.at[: L - 1].set(full_v[: L - 1])
    dmask = jnp.where(jnp.arange(C)[None, :] < L - 1, 0.0, -1e30).astype(jnp.float32)
    xd, k_new, v_new = M.decode_block(
        MC, x[L - 1 : L], pos[L - 1 : L], kc, vc, dmask, *bp)
    np.testing.assert_allclose(
        np.asarray(xd[0]), np.asarray(full_x[L - 1]), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(k_new[0]), np.asarray(full_k[L - 1]), atol=1e-5)


def test_decode_block_tail_equals_decode_block(params):
    """decode_block_tail over (frozen cache, tail) must equal decode_block
    over the concatenated cache — the device-resident decode invariant."""
    rng = np.random.default_rng(5)
    C, R = 24, 8
    used_c, used_t = 13, 3  # visible rows in cache / tail
    bp = M.block_params(params, 0)
    x = jnp.asarray(rng.standard_normal((1, MC.d_model)), jnp.float32)
    pos = jnp.asarray([used_c + used_t], jnp.int32)
    kc = jnp.asarray(rng.standard_normal((C, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((C, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((R, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((R, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    mask_c = jnp.where(jnp.arange(C)[None, :] < used_c, 0.0, -1e30).astype(jnp.float32)
    mask_t = jnp.where(jnp.arange(R)[None, :] < used_t, 0.0, -1e30).astype(jnp.float32)

    xt, kt_new, vt_new = M.decode_block_tail(
        MC, x, pos, kc, vc, mask_c, kt, vt, mask_t, *bp)

    # Reference: one flat cache of capacity C+R holding the same rows.
    k_flat = jnp.concatenate([kc, kt], axis=0)
    v_flat = jnp.concatenate([vc, vt], axis=0)
    mask_flat = jnp.concatenate([mask_c, mask_t], axis=1)
    xd, k_new, v_new = M.decode_block(
        MC, x, pos, k_flat, v_flat, mask_flat, *bp)

    np.testing.assert_allclose(np.asarray(xt), np.asarray(xd), atol=1e-5)
    np.testing.assert_allclose(np.asarray(kt_new), np.asarray(k_new), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vt_new), np.asarray(v_new), atol=1e-6)


def test_decode_block_tail_batched_equals_per_item(params):
    """Each batch slot of the vmapped cross-session decode must equal the
    per-session decode_block_tail on the same operands — bitwise, since the
    fabric's batched dispatch is pinned byte-identical to the fallback."""
    rng = np.random.default_rng(6)
    B, C, R = 4, 24, 8
    bp = M.block_params(params, 0)
    x = jnp.asarray(rng.standard_normal((B, 1, MC.d_model)), jnp.float32)
    pos = jnp.asarray(rng.integers(1, 20, size=(B, 1)), jnp.int32)
    kc = jnp.asarray(rng.standard_normal((B, C, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, C, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((B, R, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((B, R, MC.n_kv_heads, MC.head_dim)), jnp.float32)
    used_c = rng.integers(1, C, size=B)
    used_t = rng.integers(0, R, size=B)
    mask_c = jnp.asarray(np.where(
        np.arange(C)[None, None, :] < used_c[:, None, None], 0.0, -1e30),
        jnp.float32)
    mask_t = jnp.asarray(np.where(
        np.arange(R)[None, None, :] < used_t[:, None, None], 0.0, -1e30),
        jnp.float32)

    xb, kb, vb = M.decode_block_tail_batched(
        MC, x, pos, kc, vc, mask_c, kt, vt, mask_t, *bp)
    assert xb.shape == (B, 1, MC.d_model)
    assert kb.shape == (B, 1, MC.n_kv_heads, MC.head_dim)

    for i in range(B):
        xi, ki, vi = M.decode_block_tail(
            MC, x[i], pos[i], kc[i], vc[i], mask_c[i], kt[i], vt[i],
            mask_t[i], *bp)
        np.testing.assert_allclose(np.asarray(xb[i]), np.asarray(xi), atol=1e-5)
        np.testing.assert_allclose(np.asarray(kb[i]), np.asarray(ki), atol=1e-6)
        np.testing.assert_allclose(np.asarray(vb[i]), np.asarray(vi), atol=1e-6)


def test_forward_logits_shape(params):
    ids = jnp.asarray(np.arange(10) % MC.vocab_size, jnp.int32)
    logits = M.forward_logits(MC, params, ids)
    assert logits.shape == (10, MC.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_pallas_and_ref_paths_agree(params):
    rng = np.random.default_rng(4)
    L = 32
    x = jnp.asarray(rng.standard_normal((L, MC.d_model)), jnp.float32)
    pos = jnp.arange(L, dtype=jnp.int32)
    mask = M.causal_mask(L)
    bp = M.block_params(params, 1)
    a, _, _ = M.block_fused(MC, x, pos, mask, *bp, use_pallas=True,
                            block_q=32, block_kv=32)
    b, _, _ = M.block_fused(MC, x, pos, mask, *bp, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_presets_are_consistent():
    for name, mc in PRESETS.items():
        assert mc.n_heads % mc.n_kv_heads == 0, name
        assert mc.q_dim == mc.n_heads * mc.head_dim
        assert mc.param_count() > 0
