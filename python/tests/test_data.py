"""Data-path tests: PRNG cross-language vectors, episode generation,
training batch packing."""

import numpy as np

from compile import data as D


def test_splitmix_reference_vectors():
    # These vectors are also asserted on the Rust side (util::prng tests) —
    # the two implementations must stay bit-identical.
    r = D.SplitMix64(0)
    assert [r.next_u64() for _ in range(4)] == [
        0xE220A8397B1DCDAF,
        0x6E789E6AA1B965F4,
        0x06C45D188009454F,
        0xF88BB8A8724C81EC,
    ]


def test_episode_deterministic_and_valid():
    for seed in range(10):
        e1 = D.gen_episode(D.SplitMix64(seed), 4)
        e2 = D.gen_episode(D.SplitMix64(seed), 4)
        assert e1.full_text == e2.full_text
        assert e1.question.endswith("A:")
        if e1.q_kind in ("get", "sum"):
            assert e1.answer.isdigit()
        else:
            assert e1.answer in D.NAMES


def test_answer_correctness():
    rng = D.SplitMix64(123)
    for _ in range(100):
        ep = D.gen_episode(rng, 5)
        counts = {}
        for f in ep.facts:
            parts = f.split()
            counts[parts[0]] = int(parts[2])
        if ep.q_kind == "get":
            name = ep.question.split(" does ")[1].split(" have")[0]
            assert ep.answer == str(counts[name])
        elif ep.q_kind == "sum":
            seg = ep.question.split(" do ")[1].split(" have")[0]
            a, b = seg.split(" and ")
            assert ep.answer == str(counts[a] + counts[b])


def test_encode_decode_roundtrip():
    s = "Lia has 7 plums. Q: who? A:"
    assert D.decode_ids(D.encode(s)) == s


def test_pack_training_batch_shapes_and_weights():
    rng = D.SplitMix64(5)
    inputs, targets, weights = D.pack_training_batch(rng, 4, 128)
    assert inputs.shape == (4, 127)
    assert targets.shape == (4, 127)
    assert weights.shape == (4, 127)
    # Answer tokens are up-weighted; both weight levels must appear.
    assert (weights == D.ANSWER_WEIGHT).any()
    assert (weights == 1.0).any()
    # Inputs and targets are shifted views of the same stream.
    np.testing.assert_array_equal(inputs[:, 1:], targets[:, :-1])
