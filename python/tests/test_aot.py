"""AOT exporter tests: entry-point coverage, HLO-text generation, manifest
structure.  (The full export is exercised by `make artifacts`; here we lower
one representative entry to keep the test fast.)"""

import json

import jax
import pytest

from compile.config import PRESETS, DEFAULT_AOT, manifest_dict
from compile.aot import build_entries, to_hlo_text


MC = PRESETS["base"]


def test_entry_coverage():
    entries = build_entries(MC, DEFAULT_AOT)
    kinds = {}
    for name, _, args, outs, meta in entries:
        kinds.setdefault(meta["kind"], []).append(name)
        assert len(outs) >= 1
        assert len(args) >= 2
    # Every kind the Rust runtime calls must be present.
    for kind in ["block_fused", "qkv_project", "attn_ffn", "decode_block",
                 "decode_tail", "logits", "embed"]:
        assert kind in kinds, kind
    # One block_fused / qkv / embed per L variant.
    assert len(kinds["block_fused"]) == len(DEFAULT_AOT.l_variants)
    assert len(kinds["attn_ffn"]) == len(DEFAULT_AOT.attn_pairs())
    # One decode_tail per R variant, each carrying the (c, r) pair the
    # runtime keys its `decode_tail_C{c}_R{r}` lookup on.
    assert len(kinds["decode_tail"]) == len(DEFAULT_AOT.decode_tail)
    tails = [e for e in entries if e[4]["kind"] == "decode_tail"]
    for name, _, args, outs, meta in tails:
        assert name == f"decode_tail_C{meta['c']}_R{meta['r']}"
        assert outs == ["x_out", "k_new", "v_new"]


def test_manifest_dict_lists_decode_tail():
    m = manifest_dict(MC, DEFAULT_AOT)
    assert m["aot"]["decode_tail"] == list(DEFAULT_AOT.decode_tail)


def test_block_weight_order_matches_model():
    from compile.aot import block_weight_specs
    from compile.model import BLOCK_PARAM_NAMES
    specs = block_weight_specs(MC)
    assert tuple(n for n, _ in specs) == BLOCK_PARAM_NAMES


def test_lower_one_entry_to_hlo_text():
    entries = build_entries(MC, DEFAULT_AOT)
    # logits is the smallest entry — lower it end to end.
    name, fn, args, outs, meta = next(e for e in entries if e[0] == "logits")
    lowered = jax.jit(fn).lower(*[s for _, s in args])
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # HLO text (not serialized proto) is the interchange contract.
    assert "parameter(0)" in text


def test_manifest_dict_roundtrips_json():
    m = manifest_dict(MC, DEFAULT_AOT)
    text = json.dumps(m)
    back = json.loads(text)
    assert back["model"]["d_model"] == MC.d_model
    assert back["aot"]["l_variants"] == list(DEFAULT_AOT.l_variants)


def test_l_variants_tile_aligned():
    for l in DEFAULT_AOT.l_variants:
        assert l % DEFAULT_AOT.block_q == 0
    for g in DEFAULT_AOT.g_variants:
        assert g % DEFAULT_AOT.block_q == 0
