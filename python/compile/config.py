"""Shared model / AOT configuration for the FedAttn build path.

This module is the single source of truth for the TinyQwen architecture and
the artifact variant grid.  Rust consumes the same values through
``artifacts/manifest.json`` emitted by :mod:`compile.aot`.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Qwen2.5-shaped decoder-only LM (RMSNorm pre-norm, RoPE, GQA, SwiGLU).

    The defaults are the ``base`` preset used for all paper-figure benches.
    """

    name: str = "tinyqwen-base"
    vocab_size: int = 128          # byte-level ASCII tokenizer
    d_model: int = 96
    n_layers: int = 8
    n_heads: int = 4               # query heads
    n_kv_heads: int = 2            # GQA: grouped KV heads
    head_dim: int = 24
    d_ff: int = 256
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    qkv_bias: bool = True          # Qwen2.5 uses bias on Q/K/V projections

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        per_block = (
            d  # ln1
            + d * self.q_dim + self.q_dim      # wq + bq
            + d * self.kv_dim + self.kv_dim    # wk + bk
            + d * self.kv_dim + self.kv_dim    # wv + bv
            + self.q_dim * d                   # wo
            + d                                # ln2
            + d * self.d_ff * 2                # gate + up
            + self.d_ff * d                    # down
        )
        return v * d + self.n_layers * per_block + d + d * v  # emb, blocks, ln_f, w_out


# Named width/depth presets standing in for the paper's 0.5B..7B model-size
# sweep (calibration band repro=0: real Qwen checkpoints are unavailable).
PRESETS = {
    "tiny": ModelConfig(name="tinyqwen-tiny", d_model=48, n_layers=4, n_heads=2,
                        n_kv_heads=1, head_dim=24, d_ff=128),
    "base": ModelConfig(),
    "wide": ModelConfig(name="tinyqwen-wide", d_model=160, n_layers=8, n_heads=4,
                        n_kv_heads=2, head_dim=40, d_ff=448),
}


@dataclass(frozen=True)
class AotConfig:
    """Artifact variant grid.

    ``l_variants``  — per-participant padded sequence lengths (block_fused /
                      qkv_project / attn_ffn L dimension).
    ``g_variants``  — global KV buffer lengths for sync-block attention.
    ``decode_cache``— KV cache capacity for autoregressive decode blocks.
    ``decode_tail`` — tail capacities for the device-resident decode
                      variants (``decode_tail_C{c}_R{r}``): the ``[C]``
                      cache is uploaded once and frozen, each step ships
                      only the ``[R]`` tail of decode-appended rows.
    ``decode_batch``— batch widths for the cross-session batched decode
                      variants (``decode_tail_B{b}_C{c}_R{r}``): one
                      dispatch advances ``B`` independent sessions by one
                      token each (leading batch dim, weights broadcast).
    All lengths are multiples of the Pallas query tile (32), except the
    decode tail (decode uses the jnp reference attention, untiled).
    """

    l_variants: Tuple[int, ...] = (32, 64, 128, 256, 384)
    g_variants: Tuple[int, ...] = (128, 256, 384)
    decode_cache: int = 448
    decode_tail: Tuple[int, ...] = (16, 32)
    decode_batch: Tuple[int, ...] = (2, 4, 8)
    block_q: int = 32              # Pallas query tile
    block_kv: int = 64             # Pallas KV tile

    def attn_pairs(self) -> List[Tuple[int, int]]:
        """(L, G) pairs compiled for sync-block attention."""
        return [(l, g) for l in self.l_variants for g in self.g_variants if g >= l]


DEFAULT_AOT = AotConfig()


def manifest_dict(mc: ModelConfig, ac: AotConfig) -> dict:
    return {
        "format": 1,
        "model": asdict(mc),
        "aot": {
            "l_variants": list(ac.l_variants),
            "g_variants": list(ac.g_variants),
            "decode_cache": ac.decode_cache,
            "decode_tail": list(ac.decode_tail),
            "decode_batch": list(ac.decode_batch),
            "block_q": ac.block_q,
            "block_kv": ac.block_kv,
        },
    }
