"""AOT export: lower TinyQwen pieces to HLO *text* artifacts + manifest.

Python runs once at build time (``make artifacts``); the Rust coordinator
loads ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file``,
compiles them on the PJRT CPU client, and drives the FedAttn schedule.

HLO text — NOT ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every weight is a runtime *parameter*, so one lowered block serves all
layers and Rust uploads weights once as device buffers (``execute_b``).

Usage: (cd python && python -m compile.aot --out ../artifacts [--fixtures])
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import PRESETS, DEFAULT_AOT, AotConfig, ModelConfig, manifest_dict
from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _kv_tile(ac: AotConfig, g: int) -> int:
    """KV tile: prefer the configured tile, shrink for small buffers."""
    return ac.block_kv if g % ac.block_kv == 0 else ac.block_q


def block_weight_specs(mc: ModelConfig):
    d, qd, kd, dff = mc.d_model, mc.q_dim, mc.kv_dim, mc.d_ff
    return [
        ("ln1", _f32(d)), ("wq", _f32(d, qd)), ("bq", _f32(qd)),
        ("wk", _f32(d, kd)), ("bk", _f32(kd)),
        ("wv", _f32(d, kd)), ("bv", _f32(kd)),
        ("wo", _f32(qd, d)), ("ln2", _f32(d)),
        ("wg", _f32(d, dff)), ("wu", _f32(d, dff)), ("wd", _f32(dff, d)),
    ]


def build_entries(mc: ModelConfig, ac: AotConfig):
    """Yield (name, fn, [(arg_name, spec), ...], [out_name, ...]) tuples."""
    d, hd, hq, hkv = mc.d_model, mc.head_dim, mc.n_heads, mc.n_kv_heads
    wspecs = block_weight_specs(mc)
    attn_w = wspecs[7:]   # wo, ln2, wg, wu, wd
    proj_w = wspecs[:7]   # ln1, wq..bv
    entries = []

    for l in ac.l_variants:
        bkv = _kv_tile(ac, l)

        def bf(x, pos, mask, *w, _bkv=bkv):
            return M.block_fused(mc, x, pos, mask, *w,
                                 block_q=ac.block_q, block_kv=_bkv)

        entries.append((
            f"block_fused_L{l}", bf,
            [("x", _f32(l, d)), ("pos", _i32(l)), ("mask", _f32(l, l))] + wspecs,
            ["x_out", "k", "v"],
            {"kind": "block_fused", "l": l, "g": l},
        ))

        def qkv(x, pos, *w):
            return M.qkv_project(mc, x, pos, *w)

        entries.append((
            f"qkv_project_L{l}", qkv,
            [("x", _f32(l, d)), ("pos", _i32(l))] + proj_w,
            ["q", "k", "v"],
            {"kind": "qkv_project", "l": l},
        ))

    for (l, g) in ac.attn_pairs():
        bkv = _kv_tile(ac, g)

        def af(x, q, k, v, mask, *w, _bkv=bkv):
            return (M.attn_ffn(mc, x, q, k, v, mask, *w,
                               block_q=ac.block_q, block_kv=_bkv),)

        entries.append((
            f"attn_ffn_L{l}_G{g}", af,
            [("x", _f32(l, d)), ("q", _f32(l, hq, hd)),
             ("k", _f32(g, hkv, hd)), ("v", _f32(g, hkv, hd)),
             ("mask", _f32(l, g))] + attn_w,
            ["x_out"],
            {"kind": "attn_ffn", "l": l, "g": g},
        ))

    c = ac.decode_cache

    def dec(x, pos, kc, vc, mask, *w):
        return M.decode_block(mc, x, pos, kc, vc, mask, *w)

    entries.append((
        f"decode_block_C{c}", dec,
        [("x", _f32(1, d)), ("pos", _i32(1)),
         ("k_cache", _f32(c, hkv, hd)), ("v_cache", _f32(c, hkv, hd)),
         ("mask", _f32(1, c))] + wspecs,
        ["x_out", "k_new", "v_new"],
        {"kind": "decode_block", "c": c},
    ))

    # Device-resident decode: the [C] cache + its [1, C] mask are frozen
    # device buffers; only the [R] tail uploads per step.
    for r in ac.decode_tail:
        def dect(x, pos, kc, vc, mc_, kt, vt, mt, *w):
            return M.decode_block_tail(mc, x, pos, kc, vc, mc_, kt, vt, mt, *w)

        entries.append((
            f"decode_tail_C{c}_R{r}", dect,
            [("x", _f32(1, d)), ("pos", _i32(1)),
             ("k_cache", _f32(c, hkv, hd)), ("v_cache", _f32(c, hkv, hd)),
             ("mask_cache", _f32(1, c)),
             ("k_tail", _f32(r, hkv, hd)), ("v_tail", _f32(r, hkv, hd)),
             ("mask_tail", _f32(1, r))] + wspecs,
            ["x_out", "k_new", "v_new"],
            {"kind": "decode_tail", "c": c, "r": r},
        ))

    # Cross-session batched decode: B sessions advance one token each in a
    # single dispatch (leading batch dim, weights broadcast).  The serving
    # fabric falls back to per-session decode_tail when these are absent.
    for b in ac.decode_batch:
        for r in ac.decode_tail:
            def dectb(x, pos, kc, vc, mc_, kt, vt, mt, *w):
                return M.decode_block_tail_batched(
                    mc, x, pos, kc, vc, mc_, kt, vt, mt, *w)

            entries.append((
                f"decode_tail_B{b}_C{c}_R{r}", dectb,
                [("x", _f32(b, 1, d)), ("pos", _i32(b, 1)),
                 ("k_cache", _f32(b, c, hkv, hd)),
                 ("v_cache", _f32(b, c, hkv, hd)),
                 ("mask_cache", _f32(b, 1, c)),
                 ("k_tail", _f32(b, r, hkv, hd)),
                 ("v_tail", _f32(b, r, hkv, hd)),
                 ("mask_tail", _f32(b, 1, r))] + wspecs,
                ["x_out", "k_new", "v_new"],
                {"kind": "decode_tail_batched", "b": b, "c": c, "r": r},
            ))

    def logits(x, ln_f, w_out):
        return (M.logits_head(mc, x, ln_f, w_out),)

    entries.append((
        "logits", logits,
        [("x", _f32(1, d)), ("ln_f", _f32(d)),
         ("w_out", _f32(d, mc.vocab_size))],
        ["logits"],
        {"kind": "logits"},
    ))

    for l in ac.l_variants:
        def emb(ids, table):
            return (table[ids],)

        entries.append((
            f"embed_L{l}", emb,
            [("ids", _i32(l)), ("emb", _f32(mc.vocab_size, d))],
            ["x"],
            {"kind": "embed", "l": l},
        ))
    return entries


def export(mc: ModelConfig, ac: AotConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = manifest_dict(mc, ac)
    manifest["entries"] = []
    for name, fn, args, outs, meta in build_entries(mc, ac):
        specs = [s for (_, s) in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name,
            "file": fname,
            **meta,
            "inputs": [
                {"name": an, "dtype": str(s.dtype), "shape": list(s.shape)}
                for (an, s) in args
            ],
            "outputs": outs,
        })
        print(f"  {name}: {len(text)} chars, {len(args)} inputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def dump_fixtures(mc: ModelConfig, ac: AotConfig, out_dir: str, seed=3):
    """Dump cross-language test fixtures (random weights, deterministic).

    ``fixtures.npz`` holds, for each entry-point kind, one concrete
    input/output example computed by the JAX reference, plus a complete
    FedAttn scenario (uniform H=2, 3 participants) for the end-to-end
    integration test in Rust.
    """
    from . import fedattn_ref as F
    from . import data as D

    rng = np.random.default_rng(seed)
    params = M.init_params(mc, jax.random.PRNGKey(seed))
    fx = {}

    # --- block_fused on the smallest L variant ---
    l = ac.l_variants[0]
    x = rng.standard_normal((l, mc.d_model)).astype(np.float32)
    pos = np.arange(l, dtype=np.int32)
    mask = np.asarray(M.causal_mask(l))
    bp = M.block_params(params, 0)
    xo, k, v = M.block_fused(mc, jnp.asarray(x), jnp.asarray(pos),
                             jnp.asarray(mask), *bp,
                             block_q=ac.block_q, block_kv=_kv_tile(ac, l))
    fx.update({"bf.x": x, "bf.pos": pos, "bf.mask": mask,
               "bf.x_out": np.asarray(xo), "bf.k": np.asarray(k),
               "bf.v": np.asarray(v)})

    # --- attn_ffn with a global KV buffer ---
    g = ac.g_variants[0]
    q2, k2, v2 = M.qkv_project(mc, jnp.asarray(x), jnp.asarray(pos), *bp[:7])
    kg = rng.standard_normal((g, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
    vg = rng.standard_normal((g, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
    maskg = np.where(rng.random((l, g)) < 0.5, 0.0, -1e30).astype(np.float32)
    xo2 = M.attn_ffn(mc, jnp.asarray(x), q2, jnp.asarray(kg), jnp.asarray(vg),
                     jnp.asarray(maskg), *bp[7:],
                     block_q=ac.block_q, block_kv=_kv_tile(ac, g))
    fx.update({"af.q": np.asarray(q2), "af.kg": kg, "af.vg": vg,
               "af.mask": maskg, "af.x_out": np.asarray(xo2),
               "qkv.k": np.asarray(k2), "qkv.v": np.asarray(v2)})

    # --- decode_block ---
    c = ac.decode_cache
    xd = rng.standard_normal((1, mc.d_model)).astype(np.float32)
    posd = np.array([g + 1], dtype=np.int32)
    kc = rng.standard_normal((c, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
    vc = rng.standard_normal((c, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
    maskd = np.where(np.arange(c)[None, :] < g, 0.0, -1e30).astype(np.float32)
    xd2, kn, vn = M.decode_block(mc, jnp.asarray(xd), jnp.asarray(posd),
                                 jnp.asarray(kc), jnp.asarray(vc),
                                 jnp.asarray(maskd), *bp)
    fx.update({"dec.x": xd, "dec.pos": posd, "dec.kc": kc, "dec.vc": vc,
               "dec.mask": maskd, "dec.x_out": np.asarray(xd2),
               "dec.k_new": np.asarray(kn), "dec.v_new": np.asarray(vn)})

    # --- decode_block_tail: same cache split as frozen prefix + tail ---
    # (skipped for configs without tail variants; the Rust fixture test
    # skips on the absent dt.* keys.)
    if ac.decode_tail:
        r = ac.decode_tail[0]
        kt = rng.standard_normal((r, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
        vt = rng.standard_normal((r, mc.n_kv_heads, mc.head_dim)).astype(np.float32)
        tail_used = min(3, r)
        maskt = np.where(np.arange(r)[None, :] < tail_used, 0.0,
                         -1e30).astype(np.float32)
        xt2, ktn, vtn = M.decode_block_tail(
            mc, jnp.asarray(xd), jnp.asarray(posd), jnp.asarray(kc),
            jnp.asarray(vc), jnp.asarray(maskd), jnp.asarray(kt),
            jnp.asarray(vt), jnp.asarray(maskt), *bp)
        fx.update({"dt.k_tail": kt, "dt.v_tail": vt, "dt.mask_tail": maskt,
                   "dt.x_out": np.asarray(xt2), "dt.k_new": np.asarray(ktn),
                   "dt.v_new": np.asarray(vtn)})

    # --- full FedAttn scenario: 3 participants, uniform H=2 ---
    drng = D.SplitMix64(seed)
    ep = D.gen_episode(drng, 4)
    prompt_ids, _ = D.episode_ids(ep)
    ids = np.asarray(prompt_ids, dtype=np.int32)
    L = len(ids)
    owners = np.minimum(np.arange(L) * 3 // L, 2).astype(np.int32)
    sched = F.FedSchedule.uniform(mc.n_layers, 3, 2)
    xfin = F.fedattn_forward(mc, params, ids, owners, sched)
    logits = F.fedattn_logits(mc, params, ids, owners, sched, publisher=2)
    fx.update({"fed.ids": ids, "fed.owners": owners,
               "fed.h": np.int32(2),
               "fed.x_final": np.asarray(xfin),
               "fed.logits": np.asarray(logits)})

    np.savez(os.path.join(out_dir, "fixtures.npz"), **fx)
    np.savez(os.path.join(out_dir, "fixture_weights.npz"),
             **{kk: np.asarray(vv) for kk, vv in params.items()})
    print(f"  fixtures: {len(fx)} arrays")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="base", choices=sorted(PRESETS))
    ap.add_argument("--fixtures", action="store_true",
                    help="also dump cross-language test fixtures")
    args = ap.parse_args()
    mc = PRESETS[args.preset]
    print(f"exporting {mc.name} ({mc.param_count()} params) -> {args.out}")
    export(mc, DEFAULT_AOT, args.out)
    if args.fixtures:
        dump_fixtures(mc, DEFAULT_AOT, args.out)


if __name__ == "__main__":
    main()
