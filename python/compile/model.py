"""Layer-2 JAX model: TinyQwen forward pieces used by FedAttn.

A Qwen2.5-shaped decoder-only LM (pre-norm RMSNorm, RoPE, GQA, SwiGLU, QKV
bias).  The model is decomposed exactly along the FedAttn algorithm's joints
(paper Alg. 1) so the Rust coordinator owns the schedule:

  * ``block_fused``  — one Transformer block with *local* self-attention
                       (Phase I, Eq. 17–19); also returns the block's K/V for
                       the decode-stage cache.
  * ``qkv_project``  — Q/K/V projection + RoPE only (Eq. 17), run before the
                       KV exchange at a sync block.
  * ``attn_ffn``     — attention of local Q over an (aggregated, global) KV
                       buffer + residual + FFN (Eq. 20–21 + 19).
  * ``decode_block`` — one block of autoregressive decoding over a KV cache
                       (paper §IV-C); uses the jnp reference attention since
                       decode is not the paper's hot-spot.
  * ``logits``       — final RMSNorm + LM head.

All weights are *runtime parameters* (no baked constants) so a single lowered
HLO serves every layer; Rust uploads weights once as device buffers.

Weight-name convention (npz keys): ``blk{m}.{ln1,wq,bq,wk,bk,wv,bv,wo,
ln2,wg,wu,wd}``, plus ``emb``, ``ln_f``, ``w_out``.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.attention import pallas_mha
from .kernels.ref import mha_ref, NEG

# Per-block weight tensor order — shared with the manifest and Rust runtime.
BLOCK_PARAM_NAMES = (
    "ln1", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "ln2", "wg", "wu", "wd",
)


def rms_norm(x, w, eps=1e-6):
    """RMSNorm over the last axis: x * w / rms(x)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta=10_000.0):
    """Rotary position embedding (half-rotation form).

    Args:
      x:   [L, H, hd].
      pos: [L] int32 *global* token positions (FedAttn participants keep
           their tokens' positions in the global sequence).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]       # [L, half]
    cos = jnp.cos(ang)[:, None, :]                                # [L, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(h, wg, wu, wd):
    """SwiGLU FFN: (silu(h @ wg) * (h @ wu)) @ wd."""
    g = h @ wg
    return (jax.nn.silu(g) * (h @ wu)) @ wd


def qkv_project(mc: ModelConfig, x, pos, ln1, wq, bq, wk, bk, wv, bv):
    """Eq. 17: pre-norm QKV projection with RoPE applied to Q and K.

    Returns q [L,Hq,hd], k [L,Hkv,hd], v [L,Hkv,hd] in token-major layout so
    that KV aggregation (Eq. 20) is a concatenation along axis 0.
    """
    L = x.shape[0]
    h = rms_norm(x, ln1, mc.rms_eps)
    q = (h @ wq + bq).reshape(L, mc.n_heads, mc.head_dim)
    k = (h @ wk + bk).reshape(L, mc.n_kv_heads, mc.head_dim)
    v = (h @ wv + bv).reshape(L, mc.n_kv_heads, mc.head_dim)
    q = rope(q, pos, mc.rope_theta)
    k = rope(k, pos, mc.rope_theta)
    return q, k, v


def attn_ffn(mc: ModelConfig, x, q, k, v, mask, wo, ln2, wg, wu, wd,
             *, block_q=32, block_kv=64, use_pallas=True):
    """Eq. 18/21 + Eq. 19: attention output, residual, FFN, residual."""
    L = x.shape[0]
    if use_pallas:
        o = pallas_mha(q, k, v, mask, block_q=block_q, block_kv=block_kv)
    else:
        o = mha_ref(q, k, v, mask)
    o = o.reshape(L, mc.q_dim) @ wo
    x = x + o
    x = x + swiglu(rms_norm(x, ln2, mc.rms_eps), wg, wu, wd)
    return x


def block_fused(mc: ModelConfig, x, pos, mask,
                ln1, wq, bq, wk, bk, wv, bv, wo, ln2, wg, wu, wd,
                *, block_q=32, block_kv=64, use_pallas=True):
    """One local-attention Transformer block (Phase I).

    Returns (x_out, k, v); K/V are kept for the decode-stage cache and for
    the KV exchange bookkeeping in the coordinator.
    """
    q, k, v = qkv_project(mc, x, pos, ln1, wq, bq, wk, bk, wv, bv)
    x = attn_ffn(mc, x, q, k, v, mask, wo, ln2, wg, wu, wd,
                 block_q=block_q, block_kv=block_kv, use_pallas=use_pallas)
    return x, k, v


def decode_block(mc: ModelConfig, x, pos, k_cache, v_cache, mask,
                 ln1, wq, bq, wk, bk, wv, bv, wo, ln2, wg, wu, wd):
    """One block of single-token decoding over a padded KV cache.

    Args:
      x:       [1, d] current token hidden state.
      pos:     [1] global position of the token.
      k_cache: [C, Hkv, hd] padded cache (local KV for local blocks, global
               KV for sync blocks — paper §IV-C).
      mask:    [1, C] additive validity mask for cache rows.

    Returns (x_out [1,d], k_new [1,Hkv,hd], v_new [1,Hkv,hd]); the Rust
    coordinator writes k_new/v_new into the cache at the token's slot.
    """
    q, k_new, v_new = qkv_project(mc, x, pos, ln1, wq, bq, wk, bk, wv, bv)
    k_all = jnp.concatenate([k_cache, k_new], axis=0)
    v_all = jnp.concatenate([v_cache, v_new], axis=0)
    mask_all = jnp.concatenate(
        [mask, jnp.zeros((1, 1), dtype=mask.dtype)], axis=1)
    o = mha_ref(q, k_all, v_all, mask_all)
    o = o.reshape(1, mc.q_dim) @ wo
    x = x + o
    x = x + swiglu(rms_norm(x, ln2, mc.rms_eps), wg, wu, wd)
    return x, k_new, v_new


def decode_block_tail(mc: ModelConfig, x, pos, k_cache, v_cache, mask_cache,
                      k_tail, v_tail, mask_tail,
                      ln1, wq, bq, wk, bk, wv, bv, wo, ln2, wg, wu, wd):
    """Decode over a *frozen* cache plus a small growing tail.

    Device-resident execution: the ``[C]`` cache and its ``[1, C]`` mask
    stay on the device across the whole decode (uploaded once after
    prefill), while rows appended during decode ride in the ``[R]`` tail —
    so per-step upload bytes are O(R), independent of C.

    Semantically identical to :func:`decode_block` over
    ``concat(cache, tail)`` with visibility ``concat(mask_cache,
    mask_tail)``; masked rows (cache padding, unused tail slots) drop out
    of the softmax exactly.

    Args:
      x:          [1, d] current token hidden state.
      pos:        [1] global position of the token.
      k_cache:    [C, Hkv, hd] frozen prefill-time cache.
      mask_cache: [1, C] additive visibility of the frozen cache rows.
      k_tail:     [R, Hkv, hd] decode-appended rows (zero-padded).
      mask_tail:  [1, R] additive visibility of the tail rows.

    Returns (x_out [1,d], k_new [1,Hkv,hd], v_new [1,Hkv,hd]); the Rust
    coordinator appends k_new/v_new to the tail.
    """
    q, k_new, v_new = qkv_project(mc, x, pos, ln1, wq, bq, wk, bk, wv, bv)
    k_all = jnp.concatenate([k_cache, k_tail, k_new], axis=0)
    v_all = jnp.concatenate([v_cache, v_tail, v_new], axis=0)
    mask_all = jnp.concatenate(
        [mask_cache, mask_tail, jnp.zeros((1, 1), dtype=mask_cache.dtype)],
        axis=1)
    o = mha_ref(q, k_all, v_all, mask_all)
    o = o.reshape(1, mc.q_dim) @ wo
    x = x + o
    x = x + swiglu(rms_norm(x, ln2, mc.rms_eps), wg, wu, wd)
    return x, k_new, v_new


def decode_block_tail_batched(mc: ModelConfig, x, pos, k_cache, v_cache,
                              mask_cache, k_tail, v_tail, mask_tail,
                              ln1, wq, bq, wk, bk, wv, bv, wo, ln2, wg, wu, wd):
    """Cross-session batched decode: ``B`` independent sessions per dispatch.

    ``vmap`` of :func:`decode_block_tail` over a leading batch axis on every
    activation/cache operand, with the block weights broadcast.  Slot ``i``
    computes exactly ``decode_block_tail`` on its own operands — sessions
    never attend across slots, so a fabric can stack unrelated sessions and
    still produce per-session results identical to per-session dispatch.
    Dead slots (sessions that finished early) are driven with zero operands
    and fully masked caches; their outputs are discarded by the caller.

    Args:
      x:          [B, 1, d] per-session current-token hidden states.
      pos:        [B, 1] per-session global positions.
      k_cache:    [B, C, Hkv, hd] per-session frozen caches.
      mask_cache: [B, 1, C]; k_tail/v_tail [B, R, Hkv, hd]; mask_tail [B, 1, R].

    Returns (x_out [B,1,d], k_new [B,1,Hkv,hd], v_new [B,1,Hkv,hd]).
    """
    def one(x1, p1, kc, vc, mcm, kt, vt, mt):
        return decode_block_tail(mc, x1, p1, kc, vc, mcm, kt, vt, mt,
                                 ln1, wq, bq, wk, bk, wv, bv, wo,
                                 ln2, wg, wu, wd)

    return jax.vmap(one)(x, pos, k_cache, v_cache, mask_cache,
                         k_tail, v_tail, mask_tail)


def logits_head(mc: ModelConfig, x, ln_f, w_out):
    """Final RMSNorm + LM head for the last-position hidden state [1, d]."""
    return rms_norm(x, ln_f, mc.rms_eps) @ w_out


# ---------------------------------------------------------------------------
# Whole-model forward (training / reference / fixtures) — centralized
# attention, i.e. the CenAttn baseline of the paper.
# ---------------------------------------------------------------------------

def init_params(mc: ModelConfig, key):
    """Initialise a full parameter dict (flat name -> f32 array)."""
    d, dff = mc.d_model, mc.d_ff
    params = {}
    k_emb, key = jax.random.split(key)
    params["emb"] = jax.random.normal(k_emb, (mc.vocab_size, d)) * 0.02
    for m in range(mc.n_layers):
        keys = jax.random.split(jax.random.fold_in(key, m), 8)
        s = 1.0 / jnp.sqrt(d)
        blk = {
            "ln1": jnp.ones((d,)),
            "wq": jax.random.normal(keys[0], (d, mc.q_dim)) * s,
            "bq": jnp.zeros((mc.q_dim,)),
            "wk": jax.random.normal(keys[1], (d, mc.kv_dim)) * s,
            "bk": jnp.zeros((mc.kv_dim,)),
            "wv": jax.random.normal(keys[2], (d, mc.kv_dim)) * s,
            "bv": jnp.zeros((mc.kv_dim,)),
            "wo": jax.random.normal(keys[3], (mc.q_dim, d)) * s,
            "ln2": jnp.ones((d,)),
            "wg": jax.random.normal(keys[4], (d, dff)) * s,
            "wu": jax.random.normal(keys[5], (d, dff)) * s,
            "wd": jax.random.normal(keys[6], (dff, d)) / jnp.sqrt(dff),
        }
        for name, val in blk.items():
            params[f"blk{m}.{name}"] = val.astype(jnp.float32)
    k_out, _ = jax.random.split(key)
    params["ln_f"] = jnp.ones((d,), jnp.float32)
    params["w_out"] = (jax.random.normal(k_out, (d, mc.vocab_size))
                       / jnp.sqrt(d)).astype(jnp.float32)
    return {k: v.astype(jnp.float32) for k, v in params.items()}


def block_params(params, m):
    """Ordered per-block weight list for layer ``m``."""
    return [params[f"blk{m}.{n}"] for n in BLOCK_PARAM_NAMES]


def causal_mask(L, valid=None):
    """[L, L] additive causal mask; ``valid`` [L] bool marks real tokens."""
    i = jnp.arange(L)
    m = jnp.where(i[:, None] >= i[None, :], 0.0, NEG).astype(jnp.float32)
    if valid is not None:
        m = jnp.where(valid[None, :], m, NEG)
    return m


def forward_hidden(mc: ModelConfig, params, ids, *, use_pallas=False):
    """Centralized full-stack forward returning final hidden states [L, d].

    Uses the jnp reference attention by default (training path — faster to
    trace); the Pallas path is exercised by the AOT artifacts and tests.
    """
    L = ids.shape[0]
    x = params["emb"][ids]
    pos = jnp.arange(L, dtype=jnp.int32)
    mask = causal_mask(L)
    for m in range(mc.n_layers):
        x, _, _ = block_fused(mc, x, pos, mask, *block_params(params, m),
                              use_pallas=use_pallas)
    return x


def forward_logits(mc: ModelConfig, params, ids, *, use_pallas=False):
    """Centralized forward returning next-token logits [L, V]."""
    x = forward_hidden(mc, params, ids, use_pallas=use_pallas)
    return rms_norm(x, params["ln_f"], mc.rms_eps) @ params["w_out"]
