"""Build-time training of TinyQwen on MicroFact (CPU JAX).

This replaces the paper's pretrained Qwen2.5 checkpoints (unavailable —
repro band 0).  Training is centralized (CenAttn): FedAttn is an *inference*
paradigm and reuses the very same weights, so H=1 FedAttn recovers the
trained model's accuracy and larger H degrades it — the paper's Fig. 5
mechanism.

Hand-rolled Adam (optax is not installed in this image).  The checkpoint is
written as an uncompressed ``.npz`` (the Rust ``xla`` crate reads npz
natively) plus a JSON training log.

Usage:  python -m compile.train --out ../artifacts [--steps N] [--preset base]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .config import PRESETS, ModelConfig
from .model import forward_logits, init_params


def cross_entropy(logits, targets, weights):
    """Mean weighted token-level cross entropy."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def make_step(mc: ModelConfig, lr_schedule):
    """Jitted Adam step with gradient clipping and decoupled weight decay."""

    def loss_fn(params, inputs, targets, weights):
        logits = jax.vmap(lambda ids: forward_logits(mc, params, ids))(inputs)
        return cross_entropy(logits, targets, weights)

    @jax.jit
    def step(params, m_state, v_state, inputs, targets, weights, it):
        loss, grads = jax.value_and_grad(loss_fn)(params, inputs, targets, weights)
        # Global-norm clip at 1.0.
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
        lr = lr_schedule(it)
        b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01

        def upd(p, g, m, v, name_is_matrix):
            g = g * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** (it + 1))
            vhat = v / (1 - b2 ** (it + 1))
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if name_is_matrix:
                delta = delta + wd * p
            return p - lr * delta, m, v

        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            is_matrix = params[k].ndim >= 2
            new_p[k], new_m[k], new_v[k] = upd(
                params[k], grads[k], m_state[k], v_state[k], is_matrix)
        return new_p, new_m, new_v, loss, gnorm

    return step


def greedy_decode_batch(mc, params, prompts, max_new=8):
    """Greedy decode (centralized) for EM evaluation during training.

    Re-runs the full forward per generated token — fine at this scale and
    keeps the training script free of cache plumbing.
    """
    outs = []
    for ids in prompts:
        ids = list(ids)
        for _ in range(max_new):
            logits = forward_logits(mc, params, jnp.asarray(ids, jnp.int32))
            nxt = int(jnp.argmax(logits[-1]))
            if nxt == D.EOS:
                break
            ids.append(nxt)
        outs.append(ids)
    return outs


def eval_em(mc, params, rng, n_episodes=32, max_new=8):
    """Exact-match accuracy of the numeric/name answer, centralized."""
    hits = 0
    for _ in range(n_episodes):
        ep = D.gen_episode(rng, 4)
        prompt, _ = D.episode_ids(ep)
        out = greedy_decode_batch(mc, params, [prompt], max_new=max_new)[0]
        gen = D.decode_ids(out[len(prompt):]).strip()
        if gen == ep.answer:
            hits += 1
    return hits / n_episodes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="base", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--seq", type=int, default=160)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--eval-every", type=int, default=400)
    ap.add_argument("--init", default=None,
                    help="resume from an existing weights.npz")
    args = ap.parse_args()

    mc = PRESETS[args.preset]
    os.makedirs(args.out, exist_ok=True)

    def lr_schedule(it):
        it = jnp.asarray(it, jnp.float32)
        warm = jnp.minimum(1.0, (it + 1) / args.warmup)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(it / args.steps, 1.0)))
        return args.lr * warm * (0.1 + 0.9 * cos)

    params = init_params(mc, jax.random.PRNGKey(args.seed))
    if args.init:
        loaded = np.load(args.init)
        params = {k: jnp.asarray(loaded[k]) for k in loaded.files}
    m_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    v_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    step = make_step(mc, lr_schedule)
    rng = D.SplitMix64(args.seed)
    eval_rng = D.SplitMix64(args.seed ^ 0xDEAD)

    log = {"preset": args.preset, "params": mc.param_count(),
           "steps": args.steps, "batch": args.batch, "seq": args.seq,
           "loss": [], "em": []}
    t0 = time.time()
    for it in range(args.steps):
        inputs, targets, weights = D.pack_training_batch(
            rng, args.batch, args.seq + 1)
        params, m_state, v_state, loss, gnorm = step(
            params, m_state, v_state,
            jnp.asarray(inputs), jnp.asarray(targets), jnp.asarray(weights),
            it)
        if it % 100 == 0 or it == args.steps - 1:
            log["loss"].append([it, float(loss)])
            print(f"step {it:5d} loss {float(loss):.4f} gnorm {float(gnorm):.2f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if (it + 1) % args.eval_every == 0 or it == args.steps - 1:
            em = eval_em(mc, params, eval_rng)
            log["em"].append([it, em])
            print(f"  eval EM = {em:.3f}", flush=True)

    np.savez(os.path.join(args.out, "weights.npz"),
             **{k: np.asarray(v) for k, v in params.items()})
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    print(f"saved weights ({mc.param_count()} params) to {args.out}/weights.npz")


if __name__ == "__main__":
    main()
