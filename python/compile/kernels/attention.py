"""Layer-1 Pallas kernel: blocked, masked multi-head attention (GQA).

This is the compute hot-spot of FedAttn's non-autoregressive prefill: every
Transformer block — local self-attention (Eq. 18) and global self-attention
over the aggregated KV matrix (Eq. 21) — funnels through this kernel.  The
FedAttn-specific semantics (causality by *global* token position, padding
validity, sparse-KV-exchange visibility, per-participant aggregation masks)
are all carried by the additive ``mask`` operand built by the Rust
coordinator, so a single kernel serves every schedule and sparsity policy.

Hardware adaptation (paper targets generic edge accelerators / GPUs):
  * the KV sequence is tiled along ``G`` into VMEM-resident blocks via
    ``BlockSpec`` index maps — the TPU analogue of CUDA threadblock tiling
    over shared memory;
  * Q.K^T and P.V contractions are expressed as dense [bq,hd]x[hd,bk]
    matmuls that map onto the MXU systolic array;
  * softmax is computed *online* (flash-style running max / denominator in
    scratch) so no [L,G] score matrix ever exists in HBM.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO ops that the
Rust runtime runs unmodified.  The BlockSpec structure (VMEM footprint, MXU
tile shapes) is what the DESIGN.md TPU estimate is based on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG


def _mha_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale, n_kv_tiles):
    """One (head, q-tile, kv-tile) grid cell of online-softmax attention.

    Grid is (Hq, L/bq, G/bk) with the KV tile as the innermost dimension, so
    the running statistics in scratch carry across KV tiles of a fixed
    (head, q-tile) pair.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]          # [bq, hd]
    k = k_ref[0]          # [bk, hd]
    v = v_ref[0]          # [bk, hd]
    mask = mask_ref[...]  # [bq, bk]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + mask

    m_prev = m_ref[...]                       # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)            # [bq]
    p = jnp.exp(s - m_new[:, None])           # [bq, bk]

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_tiles - 1)
    def _flush():
        l = l_ref[...]
        # Fully-masked rows (padding queries): running max never left NEG.
        dead = m_ref[...] <= NEG / 2
        denom = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / denom[:, None]
        o_ref[0] = jnp.where(dead[:, None], 0.0, out).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv"))
def pallas_mha(q, k, v, mask, *, block_q=32, block_kv=64):
    """Masked GQA attention via the blocked Pallas kernel.

    Args:
      q:    [L, Hq, hd].
      k:    [G, Hkv, hd].
      v:    [G, Hkv, hd].
      mask: [L, G] additive (0 visible, NEG hidden).
      block_q / block_kv: tile sizes; must divide L and G respectively.

    Returns:
      [L, Hq, hd] attention output, matching :func:`compile.kernels.ref.mha_ref`.
    """
    L, Hq, hd = q.shape
    G, Hkv, _ = k.shape
    assert L % block_q == 0, (L, block_q)
    assert G % block_kv == 0, (G, block_kv)
    assert Hq % Hkv == 0
    group = Hq // Hkv
    n_kv_tiles = G // block_kv
    scale = 1.0 / (hd ** 0.5)

    # Head-major layouts so BlockSpec can index heads on the leading axis.
    qh = jnp.transpose(q, (1, 0, 2))  # [Hq, L, hd]
    kh = jnp.transpose(k, (1, 0, 2))  # [Hkv, G, hd]
    vh = jnp.transpose(v, (1, 0, 2))

    kernel = functools.partial(_mha_kernel, scale=scale, n_kv_tiles=n_kv_tiles)
    out = pl.pallas_call(
        kernel,
        grid=(Hq, L // block_q, n_kv_tiles),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((block_q, block_kv), lambda h, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hq, L, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m
            pltpu.VMEM((block_q,), jnp.float32),   # running denominator l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(qh, kh, vh, mask)
    return jnp.transpose(out, (1, 0, 2))
