"""Pure-jnp oracle for the Pallas attention kernel.

This is the correctness ground truth: every Pallas kernel variant is checked
against this implementation in ``python/tests/test_kernel.py``, and the same
math backs the decode-path attention (which is not a Pallas hot-spot — the
paper targets the non-autoregressive prefill).
"""

import jax.numpy as jnp

# Additive mask value for invisible positions.  Finite (not -inf) so that
# fully-masked rows produce zeros rather than NaNs after the guard below.
NEG = -1e30


def mha_ref(q, k, v, mask):
    """Masked multi-head attention with GQA broadcast.

    Args:
      q:    [L, Hq, hd] queries.
      k:    [G, Hkv, hd] keys.
      v:    [G, Hkv, hd] values.
      mask: [L, G] additive mask (0 = visible, NEG = hidden).  Encodes
            causality by global position, padding validity and FedAttn's
            sparse-KV-exchange visibility.

    Returns:
      [L, Hq, hd] attention output.  Fully-masked query rows return zeros.
    """
    L, Hq, hd = q.shape
    G, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))

    # [Hq, L, hd] x [Hkv->Hq, hd, G] -> [Hq, L, G]
    qh = jnp.transpose(q, (1, 0, 2))
    kh = jnp.repeat(jnp.transpose(k, (1, 0, 2)), group, axis=0)
    vh = jnp.repeat(jnp.transpose(v, (1, 0, 2)), group, axis=0)
    s = jnp.einsum("hld,hgd->hlg", qh, kh) * scale + mask[None, :, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    # Guard fully-masked rows: when every score is NEG the row max is NEG.
    fully_masked = m <= NEG / 2
    o = jnp.einsum("hlg,hgd->hld", p / denom, vh)
    o = jnp.where(fully_masked, 0.0, o)
    return jnp.transpose(o, (1, 0, 2))
