"""MicroFact: the synthetic collaborative-QA corpus (GSM8K stand-in).

The paper evaluates FedAttn with Qwen2.5 on GSM8K few-shot prompts; neither
is available here (repro band 0), so we substitute a task that preserves the
*mechanism* being measured: answering requires combining information held by
**different participants**, so the exact-match accuracy is causally coupled
to KV-exchange frequency, sync placement, and sparsity — exactly the knobs
of Figs. 5–10.

An episode:  F entity–count facts (``"Lia has 7 plums."``) + a question that
combines two of them (sum / difference / larger-of) + the numeric answer.
Centralized text:

    <BOS>Lia has 7 plums. Omar has 5 plums. ... Q: how many plums do Lia and
    Omar have in total? A: 12<EOS>

The same generator (same PRNG: SplitMix64) is re-implemented in Rust
(``rust/src/data``) so training data (Python) and serving workloads (Rust)
come from one distribution; cross-language agreement is tested via fixture
dumps.
"""

from dataclasses import dataclass
from typing import List, Tuple

# --- byte-level tokenizer (mirrors rust/src/tokenizer) ---------------------
PAD, BOS, EOS = 0, 1, 2
VOCAB_SIZE = 128


def encode(text: str) -> List[int]:
    """ASCII chars map to their own codes; everything else is dropped."""
    return [b for b in text.encode("ascii", errors="ignore") if 32 <= b < 127]


def decode_ids(ids) -> str:
    return "".join(chr(i) for i in ids if 32 <= i < 127)


# --- SplitMix64 — identical constants to rust/src/util/prng.rs -------------
MASK64 = (1 << 64) - 1


class SplitMix64:
    """Tiny deterministic PRNG shared bit-for-bit with the Rust side."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) (modulo method; n << 2^64 so bias ~0)."""
        return self.next_u64() % n


# Pools — keep in lockstep with rust/src/data/microfact.rs.
NAMES = [
    "Lia", "Omar", "Tess", "Ravi", "Noa", "Kai", "Mia", "Jon",
    "Zoe", "Eli", "Ana", "Max", "Ida", "Sam", "Uma", "Leo",
]
ITEMS = [
    "plums", "coins", "books", "pens", "cards", "nuts", "cups", "keys",
    "bags", "hats", "rocks", "seeds",
]
MIN_COUNT, MAX_COUNT = 2, 9  # single-digit counts: answers are <= 2 chars


@dataclass
class Episode:
    facts: List[str]          # one sentence per fact
    question: str             # includes trailing "A:" marker? (no — see text)
    answer: str               # numeric string
    n_facts: int
    q_kind: str

    @property
    def prompt(self) -> str:
        return " ".join(self.facts) + " " + self.question

    @property
    def full_text(self) -> str:
        return self.prompt + " " + self.answer


def gen_episode(rng: SplitMix64, n_facts: int = 4) -> Episode:
    """Generate one episode with ``n_facts`` facts and a 2-entity question."""
    item = ITEMS[rng.below(len(ITEMS))]
    # Distinct names, one count each.
    idxs: List[int] = []
    while len(idxs) < n_facts:
        c = rng.below(len(NAMES))
        if c not in idxs:
            idxs.append(c)
    names = [NAMES[i] for i in idxs]
    counts = [MIN_COUNT + rng.below(MAX_COUNT - MIN_COUNT + 1)
              for _ in range(n_facts)]
    facts = [f"{n} has {c} {item}." for n, c in zip(names, counts)]

    a = rng.below(n_facts)
    b = rng.below(n_facts)
    while b == a:
        b = rng.below(n_facts)
    # Retrieval-heavy mix: "get" (single-fact lookup) dominates so that EM is
    # driven by cross-participant attention rather than arithmetic capacity.
    r = rng.below(10)
    kind = "get" if r < 4 else ("most" if r < 7 else "sum")
    if kind == "get":
        q = f"Q: how many {item} does {names[a]} have? A:"
        ans = str(counts[a])
    elif kind == "most":
        hi = a if counts[a] >= counts[b] else b
        q = f"Q: who has more {item}, {names[a]} or {names[b]}? A:"
        ans = names[hi]
    else:
        q = (f"Q: how many {item} do {names[a]} and {names[b]} have in "
             f"total? A:")
        ans = str(counts[a] + counts[b])
    return Episode(facts, q, ans, n_facts, kind)


def episode_ids(ep: Episode) -> Tuple[List[int], List[int]]:
    """(prompt ids with BOS, answer ids with EOS)."""
    return [BOS] + encode(ep.prompt), encode(" " + ep.answer) + [EOS]


ANSWER_WEIGHT = 8.0


def pack_training_batch(rng: SplitMix64, batch: int, seq_len: int,
                        min_facts: int = 3, max_facts: int = 6):
    """Pack episodes into [batch, seq_len] id / target / weight arrays.

    Targets are next-token ids.  Answer-span targets (the tokens after
    "A:" plus EOS) carry ``ANSWER_WEIGHT`` — they are the task signal and
    only ~2% of the tokens; the facts are irreducibly random and would
    otherwise dominate the gradient.
    """
    import numpy as np

    ids = np.zeros((batch, seq_len), dtype=np.int32)
    wts = np.ones((batch, seq_len), dtype=np.float32)
    for bi in range(batch):
        row: List[int] = []
        roww: List[float] = []
        while len(row) < seq_len:
            nf = min_facts + rng.below(max_facts - min_facts + 1)
            ep = gen_episode(rng, nf)
            p, a = episode_ids(ep)
            row.extend(p + a)
            roww.extend([1.0] * len(p) + [ANSWER_WEIGHT] * len(a))
        ids[bi] = row[:seq_len]
        wts[bi] = roww[:seq_len]
    inputs = ids[:, :-1]
    targets = ids[:, 1:]
    weights = wts[:, 1:] * (targets != PAD)
    return inputs, targets, weights
