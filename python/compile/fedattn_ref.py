"""Pure-JAX reference implementation of the FedAttn algorithm (Alg. 1).

Used for (a) the H=1 ≡ CenAttn invariant test, (b) cross-language fixtures
checked by the Rust integration tests, and (c) quick python-side experiments.

Implementation note — the *global mask formulation*: because attention rows
are independent, running each participant's local attention over its own
token set is mathematically identical to running one global attention over
the full sequence with a visibility mask:

    visible(i, j)  ⇔  pos_j ≤ pos_i                     (causality)
                   ∧ ( owner(i) == owner(j)             (always see own KV)
                     ∨ ( attending(owner(i), m)         (i's owner performs
                       ∧ transmitted(j, m) ) )           global attention and
                                                         j's row was exchanged)

Every participant computes K/V at every block as part of its local forward,
so any attendee can receive any peer's current-block KV; "attending" means
*performing global attention* (and is what costs communication).

This reproduces Eq. 18 (local), Eq. 20–21 (global aggregation + attention),
per-participant schedules (paper Fig. 8), and sparse KV exchange (Fig. 10)
in one place.  The Rust coordinator implements the *distributed* version
(real per-participant buffers + exchange); fixtures pin the two together.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels.ref import NEG
from .model import block_params, qkv_project, attn_ffn, rms_norm


@dataclass
class BlockSync:
    """Sync behaviour of one Transformer block.

    ``participants``: indices that perform *global* self-attention at this
    block (empty = pure local block).  ``transmitted``: optional per-
    participant boolean array over its local rows — which KV rows it
    actually transmits (sparse KV exchange); ``None`` = all rows.
    """
    participants: Sequence[int] = ()
    transmitted: Optional[Dict[int, np.ndarray]] = None


@dataclass
class FedSchedule:
    """Per-block sync configuration; length == n_layers."""
    blocks: List[BlockSync]

    @staticmethod
    def uniform(n_layers: int, n_participants: int, h: int) -> "FedSchedule":
        """Every h-th block is a global-sync block (Alg. 1's fixed H)."""
        blocks = []
        for m in range(n_layers):
            if (m + 1) % h == 0:
                blocks.append(BlockSync(tuple(range(n_participants))))
            else:
                blocks.append(BlockSync(()))
        return FedSchedule(blocks)


def build_mask(owners: np.ndarray, pos: np.ndarray, sync: BlockSync,
               n_participants: int) -> np.ndarray:
    """[L, L] additive mask for one block under the global-mask formulation."""
    L = owners.shape[0]
    causal = pos[:, None] >= pos[None, :]
    same = owners[:, None] == owners[None, :]
    syncing = np.zeros(n_participants, dtype=bool)
    for p in sync.participants:
        syncing[p] = True
    tx = np.ones(L, dtype=bool)
    if sync.transmitted is not None:
        for p, keep in sync.transmitted.items():
            tx[owners == p] = keep
    cross = syncing[owners][:, None] & tx[None, :]
    visible = causal & (same | cross)
    return np.where(visible, 0.0, NEG).astype(np.float32)


def fedattn_forward(mc: ModelConfig, params, ids: np.ndarray,
                    owners: np.ndarray, schedule: FedSchedule,
                    *, use_pallas=False, collect_hidden=False):
    """Run the federated prefill; returns final hidden states [L, d].

    Args:
      ids:     [L] global token ids (participant shards interleaved in
               global order).
      owners:  [L] participant index of each token.
      schedule: per-block sync configuration.
      collect_hidden: also return the per-block hidden list (error analysis).
    """
    L = ids.shape[0]
    pos = np.arange(L, dtype=np.int32)
    x = params["emb"][jnp.asarray(ids)]
    n_participants = int(owners.max()) + 1 if L else 0
    hiddens = []
    for m in range(mc.n_layers):
        mask = jnp.asarray(build_mask(owners, pos, schedule.blocks[m],
                                      n_participants))
        bp = block_params(params, m)
        q, k, v = qkv_project(mc, x, jnp.asarray(pos), *bp[:7])
        x = attn_ffn(mc, x, q, k, v, mask, *bp[7:], use_pallas=use_pallas)
        if collect_hidden:
            hiddens.append(np.asarray(x))
    if collect_hidden:
        return x, hiddens
    return x


def fedattn_logits(mc: ModelConfig, params, ids, owners, schedule,
                   publisher: int, **kw):
    """Next-token logits at the publisher's last token (decode kick-off)."""
    x = fedattn_forward(mc, params, ids, owners, schedule, **kw)
    idx = int(np.where(owners == publisher)[0][-1])
    h = x[idx:idx + 1]
    return rms_norm(h, params["ln_f"], mc.rms_eps) @ params["w_out"]
