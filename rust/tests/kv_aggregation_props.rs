//! Policy-agnostic KV-aggregation properties (host-side only — these run
//! without compiled artifacts, so CI always exercises them):
//!
//! * every valid row is packed into `GlobalKv` exactly once, in
//!   participant-major local order (owner-visible rows are never lost,
//!   whatever the exchange policy decided);
//! * no participant's transmission set is empty;
//! * `tx_rows_by_owner() × row_bytes()` exactly matches the `NetSim`
//!   uplink/downlink byte accounting, including the per-round record.

use fedattn::fedattn::{GlobalKv, KvExchangePolicy, TxContext};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::tensor::HostTensor;
use fedattn::util::prng::Xoshiro256ss;
use fedattn::util::propcheck::propcheck;

fn random_policy(rng: &mut Xoshiro256ss) -> KvExchangePolicy {
    match rng.below(6) {
        0 => KvExchangePolicy::Full,
        1 => KvExchangePolicy::Random { ratio: rng.next_f64() },
        2 => KvExchangePolicy::PublisherPriority { remote_ratio: rng.next_f64() },
        3 => KvExchangePolicy::RecentBudget { budget_rows: rng.below(10) as usize },
        4 => KvExchangePolicy::TopKRelevance { budget_rows: rng.below(10) as usize },
        _ => KvExchangePolicy::ByteBudget { bytes_per_round: rng.below(4096) as usize },
    }
}

#[test]
fn aggregation_conserves_rows_and_byte_accounting() {
    propcheck(120, |rng| {
        let n = 1 + rng.below(4) as usize;
        let hkv = 1 + rng.below(2) as usize;
        let hd = 2usize;
        let row_bytes = GlobalKv::row_bytes(hkv, hd);
        let publisher = rng.below(n as u64) as usize;
        let policy = random_policy(rng);

        // Per-participant KV and transmission decisions.
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let mut poss: Vec<Vec<i32>> = Vec::new();
        let mut valids = Vec::new();
        let mut txs: Vec<Vec<bool>> = Vec::new();
        let mut next_pos = 0i32;
        for p in 0..n {
            let valid = 1 + rng.below(6) as usize;
            let mut k = HostTensor::zeros(&[valid, hkv, hd]);
            for i in 0..valid {
                k.row_mut(i).fill((p * 100 + i) as f32);
            }
            vs.push(k.clone());
            ks.push(k);
            poss.push((0..valid as i32).map(|i| next_pos + i).collect());
            next_pos += valid as i32;
            let scores: Vec<f64> = (0..valid).map(|_| rng.next_f64()).collect();
            let ctx = TxContext {
                who: p,
                publisher,
                len: valid,
                row_bytes,
                relevance: rng.bernoulli(0.5).then_some(scores.as_slice()),
                row_budget: rng.bernoulli(0.3).then(|| 1 + rng.below(6) as usize),
            };
            txs.push(policy.transmitted_ctx(&ctx, rng));
            valids.push(valid);
        }

        let refs: Vec<_> = (0..n)
            .map(|p| {
                (
                    &ks[p],
                    &vs[p],
                    poss[p].as_slice(),
                    valids[p],
                    txs[p].as_slice(),
                )
            })
            .collect();
        let total: usize = valids.iter().sum();
        let gkv = GlobalKv::pack(&refs, total).map_err(|e| e.to_string())?;

        // Row conservation: every valid row appears exactly once, in
        // participant-major local order, with its owner and position.
        if gkv.rows() != total {
            return Err(format!("packed {} rows, expected {total}", gkv.rows()));
        }
        let mut idx = 0usize;
        for p in 0..n {
            for i in 0..valids[p] {
                let m = gkv.meta[idx];
                if m.owner != p || m.pos != poss[p][i] || m.transmitted != txs[p][i] {
                    return Err(format!("meta mismatch at {idx}: {m:?}"));
                }
                idx += 1;
            }
        }
        // Owner-visible rows never lost: every owner keeps all its rows.
        for p in 0..n {
            let owned = gkv.meta.iter().filter(|m| m.owner == p).count();
            if owned != valids[p] {
                return Err(format!("participant {p} lost rows: {owned}/{}", valids[p]));
            }
        }

        // Never-empty transmission per participant ({} < valid rows).
        let tx_rows = gkv.tx_rows_by_owner(n);
        for (p, (&r, &v)) in tx_rows.iter().zip(&valids).enumerate() {
            if v > 0 && r == 0 {
                return Err(format!(
                    "participant {p} transmitted nothing under {}",
                    policy.as_str()
                ));
            }
            if r > v {
                return Err(format!("participant {p} transmitted {r} > {v} rows"));
            }
        }

        // Byte accounting: tx_rows x row_bytes must equal the NetReport.
        let tx_bytes: Vec<u64> = tx_rows.iter().map(|&r| (r * row_bytes) as u64).collect();
        let attending: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
        let mut sim = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 5);
        sim.exchange_round(&tx_bytes, &attending);
        let rep = sim.report();
        if rep.tx_bytes != tx_bytes {
            return Err(format!("uplink mismatch: {:?} vs {:?}", rep.tx_bytes, tx_bytes));
        }
        let round_total: u64 = tx_bytes.iter().sum();
        for p in 0..n {
            let want_rx = if attending[p] { round_total - tx_bytes[p] } else { 0 };
            if rep.rx_bytes[p] != want_rx {
                return Err(format!(
                    "downlink mismatch for {p}: {} vs {want_rx}",
                    rep.rx_bytes[p]
                ));
            }
        }
        if rep.round_bytes != vec![round_total] {
            return Err(format!("round record {:?} vs {round_total}", rep.round_bytes));
        }
        Ok(())
    });
}

/// Relevance metadata rides along with packed rows: scores attached via
/// `attach_relevance` land on the owning participant's rows in order.
#[test]
fn relevance_metadata_follows_rows() {
    propcheck(60, |rng| {
        let n = 1 + rng.below(3) as usize;
        let hkv = 1usize;
        let hd = 2usize;
        let mut parts = Vec::new();
        let mut scores: Vec<Vec<f64>> = Vec::new();
        let mut next_pos = 0i32;
        for _ in 0..n {
            let valid = 1 + rng.below(5) as usize;
            let k = HostTensor::zeros(&[valid, hkv, hd]);
            let pos: Vec<i32> = (0..valid as i32).map(|i| next_pos + i).collect();
            next_pos += valid as i32;
            let tx = vec![true; valid];
            scores.push((0..valid).map(|_| rng.next_f64() * 10.0).collect());
            parts.push((k.clone(), k, pos, valid, tx));
        }
        let refs: Vec<_> = parts
            .iter()
            .map(|(k, v, p, val, tx)| (k, v, p.as_slice(), *val, tx.as_slice()))
            .collect();
        let total: usize = refs.iter().map(|r| r.3).sum();
        let mut gkv = GlobalKv::pack(&refs, total).map_err(|e| e.to_string())?;
        gkv.attach_relevance(&scores);
        let mut cursor = vec![0usize; n];
        for m in &gkv.meta {
            let i = cursor[m.owner];
            cursor[m.owner] += 1;
            let want = scores[m.owner][i] as f32;
            if m.relevance != want {
                return Err(format!("relevance {} != {want} for {m:?}", m.relevance));
            }
        }
        Ok(())
    });
}
