//! Participant-protocol message properties (host-side only — no compiled
//! artifacts needed, so CI always exercises them):
//!
//! * every message type encode/decode round-trips bit-exactly;
//! * **byte accounting**: for every one of the six KV policies, the sum
//!   of the per-participant [`KvContribution::payload_bytes`] fed into
//!   `NetSim::exchange_round` is exactly what lands in
//!   `NetReport.round_bytes` (and per-participant `tx_bytes`), and the
//!   downlink each attendee is billed equals what the broadcast
//!   [`GlobalKvFrame`] would actually deliver it — the protocol messages
//!   are the single source of truth for comm bytes;
//! * the wire payload is the real data: a contribution's K/V rows match
//!   the packed global KV's transmitted rows value-for-value;
//! * **adversarial hardening**: every truncation of every message, wrong
//!   tags, hostile length fields, and seeded random/mutated byte fuzzing
//!   must all return `Err` (or a canonical `Ok`) — no decode path may
//!   panic or allocate unboundedly on untrusted input, because the wire
//!   transport feeds these decoders bytes straight off a socket.

use fedattn::fedattn::{
    requantize_row, DecodeTail, GlobalKv, GlobalKvDeltaFrame, GlobalKvFrame, KvContribution,
    KvExchangePolicy, KvPrecision, TokenBroadcast, TxContext,
};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::tensor::HostTensor;
use fedattn::util::prng::Xoshiro256ss;
use fedattn::util::propcheck::propcheck;

const ALL_POLICIES: [KvExchangePolicy; 6] = [
    KvExchangePolicy::Full,
    KvExchangePolicy::Random { ratio: 0.5 },
    KvExchangePolicy::PublisherPriority { remote_ratio: 0.4 },
    KvExchangePolicy::RecentBudget { budget_rows: 3 },
    KvExchangePolicy::TopKRelevance { budget_rows: 3 },
    KvExchangePolicy::ByteBudget { bytes_per_round: 2048 },
];

fn random_tensor(rng: &mut Xoshiro256ss, rows: usize, hkv: usize, hd: usize) -> HostTensor {
    let mut t = HostTensor::zeros(&[rows, hkv, hd]);
    for x in t.data_mut() {
        *x = rng.next_f32() * 4.0 - 2.0;
    }
    t
}

/// One random federation round: per-participant K/V, positions, and the
/// policy's transmission decisions.
struct Round {
    ks: Vec<HostTensor>,
    vs: Vec<HostTensor>,
    poss: Vec<Vec<i32>>,
    valids: Vec<usize>,
    txs: Vec<Vec<bool>>,
    hkv: usize,
    hd: usize,
}

fn random_round(
    rng: &mut Xoshiro256ss,
    policy: KvExchangePolicy,
    n: usize,
) -> Round {
    let hkv = 1 + rng.below(2) as usize;
    let hd = 2usize;
    let row_bytes = GlobalKv::row_bytes(hkv, hd);
    let publisher = rng.below(n as u64) as usize;
    let mut r = Round {
        ks: Vec::new(),
        vs: Vec::new(),
        poss: Vec::new(),
        valids: Vec::new(),
        txs: Vec::new(),
        hkv,
        hd,
    };
    let mut next_pos = 0i32;
    for p in 0..n {
        let valid = 1 + rng.below(6) as usize;
        r.ks.push(random_tensor(rng, valid, hkv, hd));
        r.vs.push(random_tensor(rng, valid, hkv, hd));
        r.poss.push((0..valid as i32).map(|i| next_pos + i).collect());
        next_pos += valid as i32;
        let scores: Vec<f64> = (0..valid).map(|_| rng.next_f64()).collect();
        let ctx = TxContext {
            who: p,
            publisher,
            len: valid,
            row_bytes,
            relevance: rng.bernoulli(0.5).then_some(scores.as_slice()),
            row_budget: rng.bernoulli(0.3).then(|| 1 + rng.below(4) as usize),
        };
        r.txs.push(policy.transmitted_ctx(&ctx, rng));
        r.valids.push(valid);
    }
    r
}

#[test]
fn contribution_roundtrip_under_every_policy() {
    propcheck(60, |rng| {
        for policy in ALL_POLICIES {
            let n = 1 + rng.below(3) as usize;
            let r = random_round(rng, policy, n);
            for p in 0..n {
                let c = KvContribution::from_rows(
                    rng.below(8) as usize,
                    p,
                    &r.ks[p],
                    &r.vs[p],
                    &r.poss[p],
                    &r.txs[p],
                    None,
                );
                let back = KvContribution::decode(&c.encode())
                    .map_err(|e| format!("{}: {e}", policy.as_str()))?;
                if back != c {
                    return Err(format!("{}: contribution drifted", policy.as_str()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn frame_and_decode_messages_roundtrip() {
    propcheck(60, |rng| {
        let n = 1 + rng.below(3) as usize;
        let r = random_round(rng, KvExchangePolicy::Random { ratio: 0.6 }, n);
        let refs: Vec<_> = (0..n)
            .map(|p| {
                (&r.ks[p], &r.vs[p], r.poss[p].as_slice(), r.valids[p], r.txs[p].as_slice())
            })
            .collect();
        let total: usize = r.valids.iter().sum();
        let g_pad = total + rng.below(4) as usize;
        let gkv = GlobalKv::pack(&refs, g_pad).map_err(|e| e.to_string())?;

        let frame = GlobalKvFrame::from_global(2, &gkv);
        let back = GlobalKvFrame::decode(&frame.encode()).map_err(|e| e.to_string())?;
        if back != frame {
            return Err("frame drifted through encode/decode".into());
        }
        let g2 = back.to_global(g_pad).map_err(|e| e.to_string())?;
        if g2.k != gkv.k || g2.v != gkv.v || g2.meta != gkv.meta {
            return Err("frame->global lost data".into());
        }

        let row_len = r.hkv * r.hd;
        let tail = DecodeTail::from_row(
            rng.below(8) as usize,
            total as i32,
            &vec![1.5; row_len],
            &vec![-0.5; row_len],
            r.hkv,
            r.hd,
        );
        if DecodeTail::decode(&tail.encode()).map_err(|e| e.to_string())? != tail {
            return Err("decode tail drifted".into());
        }

        let tb = TokenBroadcast { step: rng.below(100) as usize, token: 42 };
        if TokenBroadcast::decode(&tb.encode()).map_err(|e| e.to_string())? != tb {
            return Err("token broadcast drifted".into());
        }
        Ok(())
    });
}

/// The acceptance property: across all six KV policies, summed message
/// payload bytes equal `NetReport.round_bytes`, per participant and per
/// round, uplink and downlink.
#[test]
fn message_payload_bytes_equal_net_round_bytes_for_all_policies() {
    propcheck(80, |rng| {
        for policy in ALL_POLICIES {
            let n = 1 + rng.below(4) as usize;
            let r = random_round(rng, policy, n);
            let row_bytes = GlobalKv::row_bytes(r.hkv, r.hd) as u64;

            // The uplink messages each node would put on the wire.
            let contributions: Vec<KvContribution> = (0..n)
                .map(|p| {
                    KvContribution::from_rows(
                        0, p, &r.ks[p], &r.vs[p], &r.poss[p], &r.txs[p], None,
                    )
                })
                .collect();
            let payloads: Vec<u64> =
                contributions.iter().map(|c| c.payload_bytes()).collect();

            // Message accounting must agree with the packed aggregation.
            let refs: Vec<_> = (0..n)
                .map(|p| {
                    (
                        &r.ks[p],
                        &r.vs[p],
                        r.poss[p].as_slice(),
                        r.valids[p],
                        r.txs[p].as_slice(),
                    )
                })
                .collect();
            let total_rows: usize = r.valids.iter().sum();
            let gkv = GlobalKv::pack(&refs, total_rows).map_err(|e| e.to_string())?;
            for (p, (&pay, &tx_rows)) in
                payloads.iter().zip(&gkv.tx_rows_by_owner(n)).enumerate()
            {
                if pay != tx_rows as u64 * row_bytes {
                    return Err(format!(
                        "{}: participant {p} payload {pay} != {tx_rows} rows x {row_bytes} B",
                        policy.as_str()
                    ));
                }
            }

            // Feed the message sizes into the simulator: NetReport must
            // echo them exactly, per participant and per round.
            let attending: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.7)).collect();
            let mut sim = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 5);
            sim.exchange_round(&payloads, &attending);
            let rep = sim.report();
            if rep.tx_bytes != payloads {
                return Err(format!(
                    "{}: uplink {:?} != payloads {payloads:?}",
                    policy.as_str(),
                    rep.tx_bytes
                ));
            }
            let round_total: u64 = payloads.iter().sum();
            if rep.round_bytes != vec![round_total] {
                return Err(format!(
                    "{}: round record {:?} != {round_total}",
                    policy.as_str(),
                    rep.round_bytes
                ));
            }

            // Downlink: what the simulator bills an attendee equals what
            // the broadcast frame would actually deliver it.
            let frame = GlobalKvFrame::from_global(0, &gkv);
            for p in 0..n {
                let want = if attending[p] { frame.payload_bytes_for(p) } else { 0 };
                if rep.rx_bytes[p] != want {
                    return Err(format!(
                        "{}: attendee {p} rx {} != frame {want}",
                        policy.as_str(),
                        rep.rx_bytes[p]
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Adversarial codec hardening (the wire transport feeds these decoders
// bytes straight off a socket, so none of them may panic or allocate
// unboundedly on arbitrary input).
// ---------------------------------------------------------------------------

/// One valid encoding per message type, for the attack helpers below.
fn valid_encodings(rng: &mut Xoshiro256ss) -> Vec<(&'static str, Vec<u8>)> {
    let k = random_tensor(rng, 3, 2, 2);
    let v = random_tensor(rng, 3, 2, 2);
    let c = KvContribution::from_rows(
        1,
        0,
        &k,
        &v,
        &[0, 1, 2],
        &[true, false, true],
        Some(&[0.25, 0.5, 0.75]),
    );
    let gkv = GlobalKv::pack(
        &[(&k, &v, &[0, 1, 2][..], 3, &[true, false, true][..])],
        4,
    )
    .unwrap();
    let f = GlobalKvFrame::from_global(2, &gkv);
    let t = DecodeTail::from_row(3, 7, &[1.0; 4], &[2.0; 4], 2, 2);
    let tb = TokenBroadcast { step: 5, token: -3 };
    // Two-party frame so the delta both retains (owner 0's rows) and
    // ships (owner 1's transmitted row).
    let k2 = random_tensor(rng, 1, 2, 2);
    let v2 = random_tensor(rng, 1, 2, 2);
    let gkv2 = GlobalKv::pack(
        &[
            (&k, &v, &[0, 1, 2][..], 3, &[true, false, true][..]),
            (&k2, &v2, &[3][..], 1, &[true][..]),
        ],
        4,
    )
    .unwrap();
    let d = GlobalKvDeltaFrame::from_frame(&GlobalKvFrame::from_global(2, &gkv2), 1, 0);
    vec![
        ("contribution", c.encode()),
        ("frame", f.encode()),
        ("decode-tail", t.encode()),
        ("token", tb.encode()),
        ("delta-frame", d.encode()),
    ]
}

/// Quantized (version-2) variants of the KV-carrying messages, for the
/// same attack helpers: reduced-precision payloads must survive exactly
/// the same truncation and mutation batteries as the legacy layout.
fn quant_encodings(rng: &mut Xoshiro256ss) -> Vec<(&'static str, Vec<u8>)> {
    let k = random_tensor(rng, 3, 2, 2);
    let v = random_tensor(rng, 3, 2, 2);
    let c = KvContribution::from_rows(
        1,
        0,
        &k,
        &v,
        &[0, 1, 2],
        &[true, false, true],
        Some(&[0.25, 0.5, 0.75]),
    );
    let gkv = GlobalKv::pack(
        &[(&k, &v, &[0, 1, 2][..], 3, &[true, false, true][..])],
        4,
    )
    .unwrap();
    let f = GlobalKvFrame::from_global(2, &gkv);
    let k2 = random_tensor(rng, 1, 2, 2);
    let v2 = random_tensor(rng, 1, 2, 2);
    let gkv2 = GlobalKv::pack(
        &[
            (&k, &v, &[0, 1, 2][..], 3, &[true, false, true][..]),
            (&k2, &v2, &[3][..], 1, &[true][..]),
        ],
        4,
    )
    .unwrap();
    let d = GlobalKvDeltaFrame::from_frame(
        &GlobalKvFrame::from_global(2, &gkv2).with_precision(KvPrecision::Int8),
        1,
        0,
    );
    vec![
        ("contribution-f16", c.clone().with_precision(KvPrecision::F16).encode()),
        ("contribution-int8", c.with_precision(KvPrecision::Int8).encode()),
        ("frame-f16", f.clone().with_precision(KvPrecision::F16).encode()),
        ("frame-int8", f.with_precision(KvPrecision::Int8).encode()),
        ("delta-frame-int8", d.encode()),
    ]
}

/// Run every typed decoder over `bytes`; panics propagate (that is the
/// test failure), and any `Ok` must re-encode to exactly the input —
/// the codec is canonical, so "successfully decoded garbage" is only
/// acceptable when the garbage happens to *be* a valid message.
fn decode_all_canonical(name: &str, bytes: &[u8]) {
    if let Ok(m) = KvContribution::decode(bytes) {
        assert_eq!(m.encode(), bytes, "{name}: contribution not canonical");
    }
    if let Ok(m) = GlobalKvFrame::decode(bytes) {
        assert_eq!(m.encode(), bytes, "{name}: frame not canonical");
    }
    if let Ok(m) = DecodeTail::decode(bytes) {
        assert_eq!(m.encode(), bytes, "{name}: decode-tail not canonical");
    }
    if let Ok(m) = TokenBroadcast::decode(bytes) {
        assert_eq!(m.encode(), bytes, "{name}: token not canonical");
    }
    if let Ok(m) = GlobalKvDeltaFrame::decode(bytes) {
        assert_eq!(m.encode(), bytes, "{name}: delta-frame not canonical");
    }
}

/// Truncating a valid message at *every* byte boundary must fail
/// cleanly: the length fields always describe data that is no longer
/// there.
#[test]
fn every_truncation_of_every_message_errors() {
    let mut rng = Xoshiro256ss::new(41);
    let mut encodings = valid_encodings(&mut rng);
    encodings.extend(quant_encodings(&mut rng));
    for (name, bytes) in encodings {
        for cut in 0..bytes.len() {
            let prefix = &bytes[..cut];
            assert!(KvContribution::decode(prefix).is_err(), "{name} cut {cut}");
            assert!(GlobalKvFrame::decode(prefix).is_err(), "{name} cut {cut}");
            assert!(DecodeTail::decode(prefix).is_err(), "{name} cut {cut}");
            assert!(TokenBroadcast::decode(prefix).is_err(), "{name} cut {cut}");
            assert!(GlobalKvDeltaFrame::decode(prefix).is_err(), "{name} cut {cut}");
        }
    }
}

/// Every decoder rejects every *other* message type's bytes (wrong tag),
/// and all reject a wrong magic or version byte.
#[test]
fn wrong_tag_magic_and_version_all_rejected() {
    use fedattn::fedattn::protocol::{WIRE_MAGIC, WIRE_VERSION_QUANT};
    let mut rng = Xoshiro256ss::new(43);
    let encodings = valid_encodings(&mut rng);
    for (i, (name, bytes)) in encodings.iter().enumerate() {
        // i-th decoder accepts only the i-th encoding.
        let results = [
            KvContribution::decode(bytes).is_ok(),
            GlobalKvFrame::decode(bytes).is_ok(),
            DecodeTail::decode(bytes).is_ok(),
            TokenBroadcast::decode(bytes).is_ok(),
            GlobalKvDeltaFrame::decode(bytes).is_ok(),
        ];
        for (j, ok) in results.iter().enumerate() {
            assert_eq!(*ok, i == j, "{name} vs decoder {j}");
        }
        let mut bad = bytes.clone();
        bad[0] = WIRE_MAGIC.wrapping_add(1);
        decode_all_err(name, &bad);
        // Version 2 is now a *valid* layout for the KV-carrying tags
        // (quantized rows), so the unknown-version probe starts past it.
        let mut bad = bytes.clone();
        bad[2] = WIRE_VERSION_QUANT + 1;
        decode_all_err(name, &bad);
    }
}

fn decode_all_err(name: &str, bytes: &[u8]) {
    assert!(KvContribution::decode(bytes).is_err(), "{name}");
    assert!(GlobalKvFrame::decode(bytes).is_err(), "{name}");
    assert!(DecodeTail::decode(bytes).is_err(), "{name}");
    assert!(TokenBroadcast::decode(bytes).is_err(), "{name}");
    assert!(GlobalKvDeltaFrame::decode(bytes).is_err(), "{name}");
}

/// Oversized length prefixes: headers claiming astronomical row counts
/// or dimensions must fail *before* any row-sized allocation (the
/// in-header counts are multiplied with checked arithmetic and bounded
/// against the actual remaining bytes).
#[test]
fn hostile_length_fields_never_allocate() {
    use fedattn::fedattn::protocol::{WIRE_MAGIC, WIRE_VERSION};
    // (tag, header fields) crafted per message layout.
    let cases: Vec<(u8, Vec<u32>)> = vec![
        // KvContribution: block, owner, kv_heads, head_dim, rows
        (1, vec![0, 0, 1, 1, u32::MAX]),
        (1, vec![0, 0, u32::MAX, u32::MAX, u32::MAX]),
        (1, vec![0, 0, 1 << 20, 1 << 20, 1 << 20]),
        // GlobalKvFrame: block, kv_heads, head_dim, rows
        (2, vec![0, 1, 1, u32::MAX]),
        (2, vec![0, u32::MAX, u32::MAX, u32::MAX]),
        // DecodeTail: block, pos, kv_heads, head_dim
        (3, vec![0, 0, u32::MAX, u32::MAX]),
        (3, vec![0, 0, 1, u32::MAX]),
    ];
    for (tag, fields) in cases {
        let mut msg = vec![WIRE_MAGIC, tag, WIRE_VERSION];
        for f in &fields {
            msg.extend_from_slice(&f.to_le_bytes());
        }
        let res_err = match tag {
            1 => KvContribution::decode(&msg).is_err(),
            2 => GlobalKvFrame::decode(&msg).is_err(),
            _ => DecodeTail::decode(&msg).is_err(),
        };
        assert!(res_err, "tag {tag} fields {fields:?} must be rejected");
    }
}

/// Seeded fuzz: random byte strings (half of them with a plausible
/// magic/tag/version prefix so decoding reaches the length-validation
/// paths) must never panic, and anything that decodes must re-encode to
/// the identical bytes.
#[test]
fn random_bytes_fuzz_never_panics() {
    let mut rng = Xoshiro256ss::new(0xF0_2216);
    for iter in 0..4000u32 {
        let len = rng.below(160) as usize;
        let mut bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        if rng.bernoulli(0.5) && bytes.len() >= 3 {
            bytes[0] = 0xFA; // WIRE_MAGIC
            bytes[1] = 1 + rng.below(5) as u8;
            // Half legacy, half quantized-layout headers so the fuzz
            // reaches the version-2 precision-byte and scale paths too.
            bytes[2] = 1 + rng.below(2) as u8; // WIRE_VERSION | WIRE_VERSION_QUANT
        }
        decode_all_canonical(&format!("fuzz iter {iter}"), &bytes);
    }
}

/// Seeded mutation fuzz: valid messages with a few random bytes flipped
/// must never panic a decoder; a mutation that still decodes must
/// re-encode canonically.
#[test]
fn mutated_messages_fuzz_never_panics() {
    let mut rng = Xoshiro256ss::new(0xBEEF_7A6);
    for _ in 0..300u32 {
        let mut encodings = valid_encodings(&mut rng);
        encodings.extend(quant_encodings(&mut rng));
        for (name, bytes) in encodings {
            let mut mutated = bytes.clone();
            for _ in 0..1 + rng.below(4) {
                let at = rng.below(mutated.len() as u64) as usize;
                mutated[at] = rng.below(256) as u8;
            }
            decode_all_canonical(name, &mutated);
        }
    }
}

/// The delta downlink under every KV policy: round-trips canonically,
/// bills exactly what [`GlobalKvFrame::payload_bytes_for`] has always
/// billed (never more than a full frame), and reassembles — against the
/// attendee's own fresh K/V — into a frame whose every *visible* row is
/// value-identical to the full broadcast, with elided rows exactly zero.
#[test]
fn delta_frame_roundtrips_bills_and_reassembles_for_all_policies() {
    propcheck(60, |rng| {
        for policy in ALL_POLICIES {
            let n = 1 + rng.below(4) as usize;
            let r = random_round(rng, policy, n);
            let refs: Vec<_> = (0..n)
                .map(|p| {
                    (&r.ks[p], &r.vs[p], r.poss[p].as_slice(), r.valids[p], r.txs[p].as_slice())
                })
                .collect();
            let total: usize = r.valids.iter().sum();
            let gkv = GlobalKv::pack(&refs, total).map_err(|e| e.to_string())?;
            let frame = GlobalKvFrame::from_global(1, &gkv);
            let row_len = r.hkv * r.hd;
            for attendee in 0..n {
                let d = GlobalKvDeltaFrame::from_frame(&frame, 9, attendee);
                if d.payload_bytes() != frame.payload_bytes_for(attendee) {
                    return Err(format!(
                        "{}: delta bills {} != payload_bytes_for {}",
                        policy.as_str(),
                        d.payload_bytes(),
                        frame.payload_bytes_for(attendee)
                    ));
                }
                if d.payload_bytes() > frame.full_payload_bytes() {
                    return Err(format!("{}: delta exceeds full frame", policy.as_str()));
                }
                let back =
                    GlobalKvDeltaFrame::decode(&d.encode()).map_err(|e| e.to_string())?;
                if back != d || back.encode() != d.encode() {
                    return Err(format!("{}: delta not canonical", policy.as_str()));
                }
                let re = d
                    .reassemble(r.ks[attendee].data(), r.vs[attendee].data(), r.valids[attendee])
                    .map_err(|e| e.to_string())?;
                if re.meta != frame.meta {
                    return Err(format!("{}: reassembled meta drifted", policy.as_str()));
                }
                for (i, m) in frame.meta.iter().enumerate() {
                    let (gk, wk) =
                        (&re.k[i * row_len..(i + 1) * row_len], &frame.k[i * row_len..(i + 1) * row_len]);
                    let (gv, wv) =
                        (&re.v[i * row_len..(i + 1) * row_len], &frame.v[i * row_len..(i + 1) * row_len]);
                    if m.owner == attendee || m.transmitted {
                        if gk != wk || gv != wv {
                            return Err(format!(
                                "{}: visible row {i} drifted for attendee {attendee}",
                                policy.as_str()
                            ));
                        }
                    } else if gk.iter().any(|&x| x != 0.0) || gv.iter().any(|&x| x != 0.0) {
                        return Err(format!(
                            "{}: elided row {i} not zero for attendee {attendee}",
                            policy.as_str()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Hostile delta headers: astronomical meta counts, retain-list lengths
/// that do not cover the attendee's rows, and overflowing dimensions all
/// fail before any row-sized allocation.
#[test]
fn delta_hostile_retain_lists_and_lengths_rejected() {
    use fedattn::fedattn::protocol::{WIRE_MAGIC, WIRE_VERSION};
    const TAG_DELTA: u8 = 5;
    let header = |fields: &[u32]| {
        let mut msg = vec![WIRE_MAGIC, TAG_DELTA, WIRE_VERSION];
        for f in fields {
            msg.extend_from_slice(&f.to_le_bytes());
        }
        msg
    };
    // block, epoch, attendee, kv_heads, head_dim, n_meta
    assert!(GlobalKvDeltaFrame::decode(&header(&[0, 0, 0, 1, 1, u32::MAX])).is_err());
    assert!(GlobalKvDeltaFrame::decode(&header(&[0, 0, 0, u32::MAX, u32::MAX, u32::MAX])).is_err());
    // Zero meta rows but a huge claimed retain-list: rejected by the
    // own-row coverage check before any allocation.
    let mut msg = header(&[0, 0, 0, 1, 1, 0]);
    msg.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(GlobalKvDeltaFrame::decode(&msg).is_err());
    // A valid delta whose retain-list length field is tampered with in
    // either direction must fail (the list must exactly cover the
    // attendee's rows).
    let mut rng = Xoshiro256ss::new(77);
    let (_, bytes) = valid_encodings(&mut rng).pop().unwrap();
    let d = GlobalKvDeltaFrame::decode(&bytes).unwrap();
    let at = 3 + 6 * 4 + d.rows() * 13;
    for bad in [0u32, d.retain.len() as u32 + 1, u32::MAX] {
        if bad as usize == d.retain.len() {
            continue;
        }
        let mut tampered = bytes.clone();
        tampered[at..at + 4].copy_from_slice(&bad.to_le_bytes());
        assert!(GlobalKvDeltaFrame::decode(&tampered).is_err(), "retain len {bad}");
    }
}

/// The wire payload is the data, not a size estimate: a contribution's
/// rows match the packed global KV's transmitted rows value-for-value.
#[test]
fn contribution_payload_matches_packed_rows() {
    propcheck(60, |rng| {
        let n = 1 + rng.below(3) as usize;
        let r = random_round(rng, KvExchangePolicy::Random { ratio: 0.5 }, n);
        let refs: Vec<_> = (0..n)
            .map(|p| {
                (&r.ks[p], &r.vs[p], r.poss[p].as_slice(), r.valids[p], r.txs[p].as_slice())
            })
            .collect();
        let total: usize = r.valids.iter().sum();
        let gkv = GlobalKv::pack(&refs, total).map_err(|e| e.to_string())?;

        for p in 0..n {
            let c = KvContribution::from_rows(
                0, p, &r.ks[p], &r.vs[p], &r.poss[p], &r.txs[p], None,
            );
            // Walk the packed rows owned by p and transmitted; they must
            // appear in the contribution in the same order.
            let row_len = r.hkv * r.hd;
            let mut wire_row = 0usize;
            for (j, m) in gkv.meta.iter().enumerate() {
                if m.owner != p || !m.transmitted {
                    continue;
                }
                if c.pos[wire_row] != m.pos {
                    return Err(format!("pos mismatch at wire row {wire_row}"));
                }
                let wire_k = &c.k[wire_row * row_len..(wire_row + 1) * row_len];
                if wire_k != gkv.k.row(j) {
                    return Err(format!("k data mismatch at wire row {wire_row}"));
                }
                let wire_v = &c.v[wire_row * row_len..(wire_row + 1) * row_len];
                if wire_v != gkv.v.row(j) {
                    return Err(format!("v data mismatch at wire row {wire_row}"));
                }
                wire_row += 1;
            }
            if wire_row != c.rows() {
                return Err(format!(
                    "contribution has {} rows, pack says {wire_row}",
                    c.rows()
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Quantized wire rows (`kv_precision`): the reduced-precision data plane
// must round-trip canonically, dequantize to exactly what
// [`requantize_row`] predicts, and bill the simulator the *quantized*
// byte counts — under every KV policy.
// ---------------------------------------------------------------------------

/// A quantized contribution decodes to the requantized rows, re-encodes
/// bit-exactly, and its `payload_bytes` follow the wire precision —
/// every reduced precision strictly below f32 whenever any row ships.
/// (The strict f32 → f16 → int8 chain needs realistic row geometry —
/// the 8 B/row int8 scale overhead dominates these tiny `hd = 2` rows —
/// so it is pinned by the comm_quant bench schema instead.)
#[test]
fn quantized_contributions_roundtrip_and_shrink_for_all_policies() {
    propcheck(40, |rng| {
        for policy in ALL_POLICIES {
            let n = 1 + rng.below(3) as usize;
            let r = random_round(rng, policy, n);
            let row_len = r.hkv * r.hd;
            for p in 0..n {
                let base = KvContribution::from_rows(
                    0, p, &r.ks[p], &r.vs[p], &r.poss[p], &r.txs[p], None,
                );
                let f32_bytes = base.payload_bytes();
                for precision in [KvPrecision::F32, KvPrecision::F16, KvPrecision::Int8] {
                    let c = base.clone().with_precision(precision);
                    let want_bytes = (c.rows()
                        * precision.wire_row_bytes(r.hkv, r.hd))
                        as u64;
                    if c.payload_bytes() != want_bytes {
                        return Err(format!(
                            "{}: {precision:?} bills {} != {want_bytes}",
                            policy.as_str(),
                            c.payload_bytes()
                        ));
                    }
                    if precision != KvPrecision::F32
                        && c.rows() > 0
                        && c.payload_bytes() >= f32_bytes
                    {
                        return Err(format!(
                            "{}: {precision:?} does not shrink the payload",
                            policy.as_str()
                        ));
                    }
                    let bytes = c.encode();
                    let back =
                        KvContribution::decode(&bytes).map_err(|e| e.to_string())?;
                    if back.precision != precision || back.encode() != bytes {
                        return Err(format!(
                            "{}: {precision:?} not canonical",
                            policy.as_str()
                        ));
                    }
                    for w in 0..c.rows() {
                        let mut want = c.k[w * row_len..(w + 1) * row_len].to_vec();
                        requantize_row(&mut want, precision);
                        if back.k[w * row_len..(w + 1) * row_len] != want[..] {
                            return Err(format!(
                                "{}: {precision:?} k row {w} != requantize_row",
                                policy.as_str()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// The simulator is billed the quantized sizes: feeding int8
/// `payload_bytes` into `NetSim::exchange_round` lands the reduced
/// totals in `NetReport`, strictly below the f32 round.
#[test]
fn quantized_payloads_bill_the_simulator_with_quantized_bytes() {
    let mut rng = Xoshiro256ss::new(0x9A17);
    // Realistic row geometry (the tiny hd=2 rounds above would let the
    // 8-byte int8 scale overhead mask the shrink this test pins down).
    let (n, rows, hkv, hd) = (4, 6, 2, 24);
    let pos: Vec<i32> = (0..rows as i32).collect();
    let tx = vec![true; rows];
    let ks: Vec<_> = (0..n).map(|_| random_tensor(&mut rng, rows, hkv, hd)).collect();
    let vs: Vec<_> = (0..n).map(|_| random_tensor(&mut rng, rows, hkv, hd)).collect();
    let attending = vec![true; n];
    let mut totals = Vec::new();
    for precision in [KvPrecision::F32, KvPrecision::Int8] {
        let payloads: Vec<u64> = (0..n)
            .map(|p| {
                KvContribution::from_rows(0, p, &ks[p], &vs[p], &pos, &tx, None)
                    .with_precision(precision)
                    .payload_bytes()
            })
            .collect();
        let mut sim = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 5);
        sim.exchange_round(&payloads, &attending);
        let rep = sim.report();
        assert_eq!(rep.tx_bytes, payloads, "{precision:?} uplink mismatch");
        totals.push(rep.round_bytes[0]);
    }
    assert!(
        totals[1] * 3 < totals[0],
        "int8 round {} not well below f32 round {}",
        totals[1],
        totals[0]
    );
}

/// Hostile quantized payloads at the integration layer: tampered scale
/// bytes (NaN/inf/negative/subnormal/huge), inconsistent zero scales,
/// the non-canonical −128 level, bogus precision bytes, and unknown
/// versions are all rejected without panicking.
#[test]
fn hostile_quant_scales_levels_and_precision_bytes_rejected() {
    let mut rng = Xoshiro256ss::new(0x5CA1E);
    let k = random_tensor(&mut rng, 2, 2, 2);
    let v = random_tensor(&mut rng, 2, 2, 2);
    let c = KvContribution::from_rows(
        0,
        0,
        &k,
        &v,
        &[0, 1],
        &[true, true],
        Some(&[0.5, 0.5]),
    )
    .with_precision(KvPrecision::Int8);
    let bytes = c.encode();
    // scale_k[0] sits after header + precision byte + 5 u32s + pos + rel.
    let scale_at = 3 + 1 + 5 * 4 + 2 * 8;
    for hostile in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.0, 1.0e-45, f32::MAX] {
        let mut bad = bytes.clone();
        bad[scale_at..scale_at + 4].copy_from_slice(&hostile.to_le_bytes());
        assert!(KvContribution::decode(&bad).is_err(), "scale {hostile:e}");
        decode_all_canonical("hostile scale", &bad);
    }
    let mut bad = bytes.clone();
    bad[scale_at..scale_at + 4].copy_from_slice(&0.0f32.to_le_bytes());
    assert!(KvContribution::decode(&bad).is_err(), "zero scale, nonzero levels");
    let level_at = scale_at + 4 * 4; // past both rows' K and V scales
    let mut bad = bytes.clone();
    bad[level_at] = 0x80;
    assert!(KvContribution::decode(&bad).is_err(), "int8 level -128");
    for p in [0u8, 3, 255] {
        let mut bad = bytes.clone();
        bad[3] = p;
        assert!(KvContribution::decode(&bad).is_err(), "precision byte {p}");
    }
    let mut bad = bytes;
    bad[2] = 3;
    assert!(KvContribution::decode(&bad).is_err(), "version 3");
}
