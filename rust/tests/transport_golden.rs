//! Differential harness for the wire transport: a session run over real
//! transports must be **byte-identical** to the in-process `FedSession`
//! when no round deadline is set.
//!
//! Three layers:
//!
//! 1. **Host-side properties** (always run, no artifacts needed): frame
//!    integrity over channel and TCP-loopback transports, and the
//!    deadline billing invariant — with deadline `d`, a round's recorded
//!    bytes equal the sum of the *on-time* contributions' payload bytes.
//! 2. **Channel differential** (engine-gated): `TransportDriver` over
//!    in-memory channels vs `FedSession`, all six KV policies ×
//!    `workers ∈ {1, 4}`, full per-participant answer transcripts.
//! 3. **TCP-loopback differential** (engine-gated): the same sessions
//!    over real sockets, plus a direct comparison against the
//!    `session_golden` fixture file (the wire transcript must match the
//!    same golden records the in-process session is pinned to).
//!
//! Deadline semantics are pinned here too: an effectively-infinite
//! deadline changes nothing (dropout draws included), and a deadline of
//! zero degrades every sync round to local attention exactly like a
//! never-syncing schedule.
//!
//! A churn-recovery suite rides at the bottom: the rejoin differential
//! (a node cut mid-session and readmitted through `Rejoin`/`Resync` is
//! byte-identical to a deadline-miss world that never lost it), a seeded
//! chaos-transport property (faulty sessions complete, deterministically
//! per seed, and a zero-rate chaos wrapper changes nothing), and a
//! mid-decode churn regression (a node dying between token broadcasts
//! leaves its answer absent without killing the session).
//!
//! A liveness suite closes the file: answered heartbeats are
//! byte-invisible, and a node that swallows its pings is demoted before
//! it can stall a protocol turn.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use fedattn::data::{gen_episode, partition, Segmentation};
use fedattn::fedattn::{
    wire_kind, ChannelTransport, ChaosTransport, CtrlMsg, FaultSchedule, FedSession,
    GlobalKv, GlobalKvDeltaFrame, GlobalKvFrame, KvContribution, KvExchangePolicy,
    KvPrecision, LocalSparsity, NodeHost, SessionConfig, SessionReport, SyncSchedule,
    TcpTransport, Transport, TransportDriver, TransportError, WireKind,
};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::tensor::HostTensor;
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::prng::SplitMix64;
use fedattn::util::propcheck::propcheck;

// ---------------------------------------------------------------------------
// Host-side properties (no artifacts needed)
// ---------------------------------------------------------------------------

/// A protocol message survives both transports bit-exactly.
#[test]
fn protocol_frames_survive_channel_and_tcp() {
    let mut t = HostTensor::zeros(&[3, 1, 2]);
    for (i, x) in t.data_mut().iter_mut().enumerate() {
        *x = i as f32 * 0.5 - 1.0;
    }
    let c = KvContribution::from_rows(
        2,
        1,
        &t,
        &t.clone(),
        &[4, 5, 6],
        &[true, false, true],
        Some(&[0.1, 0.2, 0.3]),
    );
    let bytes = c.encode();

    // Channel pair.
    let (mut a, mut b) = ChannelTransport::pair();
    a.send(&bytes).unwrap();
    let got = b.recv().unwrap();
    assert_eq!(KvContribution::decode(&got).unwrap(), c);

    // TCP loopback.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload = bytes.clone();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::from_stream(stream).unwrap();
        t.send(&payload).unwrap();
    });
    let mut client = TcpTransport::connect(addr).unwrap();
    let got = client.recv().unwrap();
    server.join().unwrap();
    assert_eq!(KvContribution::decode(&got).unwrap(), c);
}

/// The deadline billing invariant, at the simulator level: round bytes
/// equal the sum of on-time payloads; late participants are neither
/// billed uplink nor delivered downlink.  (The driver feeds exactly this
/// shape: late entries zeroed, attendance restricted to on-time
/// attendees, and skips the round entirely when nobody makes the cut —
/// which is what the engine-gated `deadline_zero_degrades_like_never`
/// test pins end-to-end.)
#[test]
fn deadline_round_bytes_equal_on_time_payloads() {
    propcheck(120, |rng| {
        let n = 1 + rng.below(5) as usize;
        let link = LinkSpec {
            bandwidth_mbps: 5.0 + rng.next_f64() * 100.0,
            latency_ms: rng.next_f64() * 10.0,
            jitter: rng.next_f64() * 0.5,
        };
        let mut sim = NetSim::uniform(Topology::Star, n, link, rng.next_u64());
        let payloads: Vec<u64> = (0..n).map(|_| (1 + rng.below(64)) * 256).collect();
        let deadline = rng.next_f64() * 25.0;
        let arrivals = sim.uplink_arrivals(&payloads);
        let on_time: Vec<bool> = arrivals.iter().map(|&a| a <= deadline).collect();
        let billed: Vec<u64> = payloads
            .iter()
            .zip(&on_time)
            .map(|(&b, &o)| if o { b } else { 0 })
            .collect();
        if !on_time.iter().any(|&o| o) {
            // The driver skips the round entirely: nothing billed.
            return Ok(());
        }
        sim.exchange_round_scheduled(&billed, &on_time, &arrivals);
        let rep = sim.report();
        let want: u64 = billed.iter().sum();
        if rep.round_bytes != vec![want] {
            return Err(format!("round bytes {:?} != on-time sum {want}", rep.round_bytes));
        }
        if rep.tx_bytes != billed {
            return Err(format!("tx {:?} != billed {billed:?}", rep.tx_bytes));
        }
        for p in 0..n {
            let want_rx = if on_time[p] { want - billed[p] } else { 0 };
            if rep.rx_bytes[p] != want_rx {
                return Err(format!("rx[{p}] = {} != {want_rx}", rep.rx_bytes[p]));
            }
        }
        Ok(())
    });
}

/// Arrival scheduling is deterministic in the seed (the straggler sweep
/// depends on it), and a fresh simulator reproduces it draw-for-draw.
#[test]
fn arrival_scheduling_deterministic() {
    let link = LinkSpec { bandwidth_mbps: 20.0, latency_ms: 3.0, jitter: 0.4 };
    let payloads = [4096u64, 8192, 0, 1024];
    let mut a = NetSim::uniform(Topology::Star, 4, link, 77);
    let mut b = NetSim::uniform(Topology::Star, 4, link, 77);
    for _ in 0..5 {
        assert_eq!(a.uplink_arrivals(&payloads), b.uplink_arrivals(&payloads));
    }
}

// ---------------------------------------------------------------------------
// Engine-gated differentials
// ---------------------------------------------------------------------------

fn engine() -> Option<Engine> {
    let dir: PathBuf = fedattn::default_artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("weights.npz").exists() {
        eprintln!("SKIP: artifacts not found (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir, "weights.npz").unwrap())
}

const ALL_POLICIES: [(&str, KvExchangePolicy); 6] = [
    ("full", KvExchangePolicy::Full),
    ("random", KvExchangePolicy::Random { ratio: 0.5 }),
    ("publisher-priority", KvExchangePolicy::PublisherPriority { remote_ratio: 0.5 }),
    ("recent-budget", KvExchangePolicy::RecentBudget { budget_rows: 8 }),
    ("top-k-relevance", KvExchangePolicy::TopKRelevance { budget_rows: 8 }),
    ("byte-budget", KvExchangePolicy::ByteBudget { bytes_per_round: 8192 }),
];

#[derive(Clone, Copy)]
enum Mode {
    /// Fully in-process (`FedSession`).
    InProcess,
    /// `TransportDriver` over in-memory channel pairs.
    Channel,
    /// `TransportDriver` over TCP loopback sockets.
    Tcp,
}

#[derive(Clone, Copy)]
struct RunCfg {
    policy: KvExchangePolicy,
    name: &'static str,
    workers: usize,
    decode_all: bool,
    dropout: f64,
    deadline: Option<f64>,
    /// Schedule override: `None` = the session_golden uniform H=2.
    never_sync: bool,
    /// Delta-encoded downlink frames (the default).  `false` ships and
    /// bills full broadcast frames — the pre-delta baseline.
    delta: bool,
    /// Wire precision of the KV data plane (`F32` = the legacy layout
    /// every golden fixture is pinned to).
    precision: KvPrecision,
    /// Liveness heartbeat interval; `None` (the default everywhere a
    /// golden fixture is compared) disarms the heartbeat plane.
    heartbeat: Option<f64>,
}

impl RunCfg {
    fn new(name: &'static str, policy: KvExchangePolicy) -> Self {
        Self {
            policy,
            name,
            workers: 1,
            decode_all: false,
            dropout: 0.0,
            deadline: None,
            never_sync: false,
            delta: true,
            precision: KvPrecision::F32,
            heartbeat: None,
        }
    }
}

/// Spawn one node host per participant, returning the driver-side
/// transports and the host threads (joined after the session to surface
/// node-side failures).
fn spawn_hosts(
    engine: &Engine,
    n: usize,
    mode: Mode,
) -> (Vec<Box<dyn Transport>>, Vec<JoinHandle<()>>) {
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for p in 0..n {
        match mode {
            Mode::InProcess => unreachable!("no hosts for in-process runs"),
            Mode::Channel => {
                let (driver_end, node_end) = ChannelTransport::pair();
                let engine = engine.clone();
                handles.push(std::thread::spawn(move || {
                    NodeHost::new(engine, Box::new(node_end))
                        .serve()
                        .unwrap_or_else(|e| panic!("channel node host {p} failed: {e:#}"));
                }));
                transports.push(Box::new(driver_end));
            }
            Mode::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap();
                let engine = engine.clone();
                handles.push(std::thread::spawn(move || {
                    let (stream, _) = listener.accept().unwrap();
                    let t = TcpTransport::from_stream(stream).unwrap();
                    NodeHost::new(engine, Box::new(t))
                        .serve()
                        .unwrap_or_else(|e| panic!("tcp node host {p} failed: {e:#}"));
                }));
                transports.push(Box::new(TcpTransport::connect(addr).unwrap()));
            }
        }
    }
    (transports, handles)
}

/// Run one deterministic session in the exact `session_golden` workload
/// shape (same episode, seeds, links), in-process or over a transport,
/// returning the full report for byte-level comparisons.
fn run_session(engine: &Engine, mode: Mode, rc: RunCfg) -> SessionReport {
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let schedule = if rc.never_sync {
        SyncSchedule::never(md.n_layers, n)
    } else {
        SyncSchedule::uniform(md.n_layers, n, 2)
    };
    let mut cfg = SessionConfig::new(schedule);
    cfg.kv_policy = rc.policy;
    cfg.seed = 11;
    cfg.workers = rc.workers;
    cfg.decode_all = rc.decode_all;
    cfg.dropout_prob = rc.dropout;
    cfg.round_deadline_ms = rc.deadline;
    cfg.delta_frames = rc.delta;
    cfg.kv_precision = rc.precision;
    cfg.heartbeat_ms = rc.heartbeat;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    let (rep, hosts) = match mode {
        Mode::InProcess => {
            (FedSession::new(engine, &part, cfg, net).unwrap().run().unwrap(), Vec::new())
        }
        _ => {
            let (transports, hosts) = spawn_hosts(engine, n, mode);
            let rep = TransportDriver::new(engine, &part, cfg, net, transports)
                .unwrap()
                .run()
                .unwrap();
            (rep, hosts)
        }
    };
    for h in hosts {
        h.join().expect("node host thread panicked");
    }
    rep
}

/// One deterministic session fingerprint in the exact `session_golden`
/// shape (same workload, seeds, links, and JSON key order), run either
/// in-process or over a transport.
fn fingerprint(engine: &Engine, mode: Mode, rc: RunCfg) -> Json {
    let rep = run_session(engine, mode, rc);
    let mut b = JsonBuilder::new()
        .str("policy", rc.name)
        .str("answer", &rep.answer)
        .num("generated_tokens", rep.generated_tokens as f64)
        .num("rounds", rep.net.rounds as f64)
        .arr_num(
            "tx_bytes",
            &rep.net.tx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "rx_bytes",
            &rep.net.rx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "round_bytes",
            &rep.net.round_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        );
    if rc.decode_all {
        let answers: Vec<Json> = rep
            .answers
            .iter()
            .map(|a| Json::Str(a.clone().unwrap_or_default()))
            .collect();
        b = b.set("answers", Json::Arr(answers));
    }
    b.build()
}

/// Channel transport ≡ in-process, all six policies × workers {1, 4},
/// with every participant decoding (`decode_all`) so the full answer
/// transcript — publisher and peers — is compared, not just one stream.
#[test]
fn channel_transport_matches_in_process_for_all_policies() {
    let Some(engine) = engine() else { return };
    for (name, policy) in ALL_POLICIES {
        for workers in [1usize, 4] {
            let mut rc = RunCfg::new(name, policy);
            rc.workers = workers;
            rc.decode_all = true;
            let local = fingerprint(&engine, Mode::InProcess, rc);
            let wire = fingerprint(&engine, Mode::Channel, rc);
            assert_eq!(
                local.to_string_compact(),
                wire.to_string_compact(),
                "channel transport diverged from in-process under {name}, workers={workers}"
            );
        }
    }
}

/// TCP loopback ≡ in-process for all six policies, and — when the
/// `session_golden` fixture exists — the wire transcripts must match the
/// very records the in-process session is pinned to (same shape, same
/// order), proving sockets change nothing end-to-end.
#[test]
fn tcp_loopback_matches_in_process_and_golden_fixture() {
    let Some(engine) = engine() else { return };
    let mut wire_records = Vec::new();
    for (name, policy) in ALL_POLICIES {
        let rc = RunCfg::new(name, policy);
        let local = fingerprint(&engine, Mode::InProcess, rc);
        let wire = fingerprint(&engine, Mode::Tcp, rc);
        assert_eq!(
            local.to_string_compact(),
            wire.to_string_compact(),
            "tcp transport diverged from in-process under {name}"
        );
        wire_records.push(wire);
    }
    let golden = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/session_golden.json");
    if golden.exists() {
        let want = std::fs::read_to_string(&golden).unwrap();
        let got = Json::Arr(wire_records).to_string_compact();
        assert_eq!(
            got.trim(),
            want.trim(),
            "TCP-loopback transcripts drifted from the session_golden fixture"
        );
    } else {
        eprintln!("note: no session_golden fixture to cross-check (run session_golden first)");
    }
}

/// The deadline knob off (`None`) and effectively infinite (huge finite
/// value, zero-jitter links) are byte-identical — including when dropout
/// is active, pinning that deadline scheduling never perturbs the
/// dropout RNG stream — and the same holds over the wire.
#[test]
fn dropout_composes_with_deadline_knob() {
    let Some(engine) = engine() else { return };
    let mut base = RunCfg::new("full", KvExchangePolicy::Full);
    base.dropout = 0.3;
    let mut with_deadline = base;
    with_deadline.deadline = Some(1e12);

    let off = fingerprint(&engine, Mode::InProcess, base);
    let inf = fingerprint(&engine, Mode::InProcess, with_deadline);
    assert_eq!(
        off.to_string_compact(),
        inf.to_string_compact(),
        "an infinite deadline must not change a dropout session"
    );
    let wire = fingerprint(&engine, Mode::Channel, with_deadline);
    assert_eq!(
        off.to_string_compact(),
        wire.to_string_compact(),
        "wire + infinite deadline must match in-process + no deadline"
    );
}

/// A zero deadline (every contribution late — the default link has 5 ms
/// of latency, so nothing can arrive by 0) degrades every sync round to
/// local attention *exactly* like a never-syncing schedule: same answer,
/// zero rounds, zero bytes.
#[test]
fn deadline_zero_degrades_like_never_syncing() {
    let Some(engine) = engine() else { return };
    let mut all_late = RunCfg::new("full", KvExchangePolicy::Full);
    all_late.deadline = Some(0.0);
    let mut never = RunCfg::new("full", KvExchangePolicy::Full);
    never.never_sync = true;

    let a = fingerprint(&engine, Mode::InProcess, all_late);
    let b = fingerprint(&engine, Mode::InProcess, never);
    assert_eq!(
        a.to_string_compact(),
        b.to_string_compact(),
        "an all-late session must equal a never-syncing one"
    );
}

/// A delta downlink frame survives both transports bit-exactly and
/// reassembles into the full frame it was cut from (host-side; no
/// artifacts needed — the engine-gated differentials below pin the same
/// thing end-to-end).
#[test]
fn delta_frame_survives_channel_and_tcp() {
    let mut k0 = HostTensor::zeros(&[2, 1, 2]);
    let mut k1 = HostTensor::zeros(&[2, 1, 2]);
    for (i, x) in k0.data_mut().iter_mut().enumerate() {
        *x = i as f32 + 0.25;
    }
    for (i, x) in k1.data_mut().iter_mut().enumerate() {
        *x = -(i as f32) - 0.5;
    }
    let g = GlobalKv::pack(
        &[
            (&k0, &k0.clone(), &[0, 1][..], 2, &[true, false][..]),
            (&k1, &k1.clone(), &[2, 3][..], 2, &[true, true][..]),
        ],
        4,
    )
    .unwrap();
    let frame = GlobalKvFrame::from_global(1, &g);
    let d = GlobalKvDeltaFrame::from_frame(&frame, 0, 0);
    assert_eq!(d.payload_bytes(), frame.payload_bytes_for(0));
    assert!(d.payload_bytes() < frame.full_payload_bytes());
    let bytes = d.encode();

    // Channel pair.
    let (mut a, mut b) = ChannelTransport::pair();
    a.send(&bytes).unwrap();
    let got = GlobalKvDeltaFrame::decode(&b.recv().unwrap()).unwrap();
    assert_eq!(got, d);

    // TCP loopback.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let payload = bytes.clone();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::from_stream(stream).unwrap();
        t.send(&payload).unwrap();
    });
    let mut client = TcpTransport::connect(addr).unwrap();
    let got = GlobalKvDeltaFrame::decode(&client.recv().unwrap()).unwrap();
    server.join().unwrap();
    assert_eq!(got, d);

    // Reassembly against attendee 0's own rows restores every visible
    // row of the original frame.
    let re = got.reassemble(k0.data(), k0.data(), 2).unwrap();
    assert_eq!(re.meta, frame.meta);
    assert_eq!(re.k, frame.k);
}

/// The tentpole differential: delta-frame sessions (channel *and* TCP)
/// decode byte-identically to full-frame sessions across all six KV
/// policies × workers {1, 4} — every participant's answer, not just the
/// publisher's — while billing strictly fewer downlink bytes on every
/// executed round (no cache miss ever occurs in-session: an attendee
/// always contributed the round's fresh KV before its frame arrives, so
/// equality could only appear on a cache-miss fallback round).
#[test]
fn delta_sessions_match_full_transcripts_and_shrink_downlink() {
    let Some(engine) = engine() else { return };
    for mode in [Mode::Channel, Mode::Tcp] {
        let mode_name = match mode {
            Mode::Channel => "channel",
            _ => "tcp",
        };
        for (name, policy) in ALL_POLICIES {
            for workers in [1usize, 4] {
                let mut rc = RunCfg::new(name, policy);
                rc.workers = workers;
                rc.decode_all = true;
                let mut full_rc = rc;
                full_rc.delta = false;
                let d = run_session(&engine, mode, rc);
                let f = run_session(&engine, mode, full_rc);
                let tag = format!("{mode_name}/{name}/workers={workers}");

                // Decoded transcripts are byte-identical.
                assert_eq!(d.answer, f.answer, "{tag}: publisher answer diverged");
                assert_eq!(d.answers, f.answers, "{tag}: peer answers diverged");
                assert_eq!(
                    d.generated_tokens, f.generated_tokens,
                    "{tag}: token count diverged"
                );

                // Uplink accounting is untouched by the downlink encoding.
                assert_eq!(d.net.tx_bytes, f.net.tx_bytes, "{tag}: uplink diverged");
                assert_eq!(d.net.round_bytes, f.net.round_bytes, "{tag}: round bytes diverged");
                assert_eq!(d.net.rounds, f.net.rounds, "{tag}: round count diverged");
                assert!(d.net.rounds > 0, "{tag}: no rounds executed");

                // Downlink: delta ≤ full per round, strictly (attendees
                // always re-receive at least their own never-empty
                // contribution under full frames).
                assert_eq!(d.net.round_rx_bytes.len(), f.net.round_rx_bytes.len(), "{tag}");
                for (i, (dr, fr)) in
                    d.net.round_rx_bytes.iter().zip(&f.net.round_rx_bytes).enumerate()
                {
                    assert!(
                        dr < fr,
                        "{tag}: round {i} delta downlink {dr} not below full {fr}"
                    );
                }
                for p in 0..d.net.rx_bytes.len() {
                    assert!(
                        d.net.rx_bytes[p] <= f.net.rx_bytes[p],
                        "{tag}: participant {p} delta rx exceeds full"
                    );
                }
            }
        }
    }
}

/// Delta frames on (the default) change nothing against the pre-delta
/// in-process session: the default wire fingerprint — including every
/// byte of the billing — still matches in-process exactly, and the full
/// (non-delta) mode is itself wire ≡ in-process consistent.
#[test]
fn delta_default_keeps_wire_in_process_equivalence() {
    let Some(engine) = engine() else { return };
    for delta in [true, false] {
        let mut rc = RunCfg::new("random", KvExchangePolicy::Random { ratio: 0.5 });
        rc.decode_all = true;
        rc.delta = delta;
        let local = fingerprint(&engine, Mode::InProcess, rc);
        let wire = fingerprint(&engine, Mode::Channel, rc);
        assert_eq!(
            local.to_string_compact(),
            wire.to_string_compact(),
            "wire diverged from in-process with delta={delta}"
        );
    }
}

/// Quantized wire sessions (`kv_precision`): at every reduced precision
/// the transports decode byte-identically to the in-process session —
/// channel *and* TCP, stateless and relevance-tracking policies, delta
/// frames on and off.  (The `f32` default is pinned separately: every
/// golden-fixture differential above runs at `KvPrecision::F32` and must
/// stay byte-identical to the pre-quantization transcripts.)
#[test]
fn quantized_wire_matches_in_process_at_every_precision() {
    let Some(engine) = engine() else { return };
    for precision in [KvPrecision::F16, KvPrecision::Int8] {
        for (name, policy) in [
            ("full", KvExchangePolicy::Full),
            ("top-k-relevance", KvExchangePolicy::TopKRelevance { budget_rows: 8 }),
        ] {
            for delta in [true, false] {
                let mut rc = RunCfg::new(name, policy);
                rc.decode_all = true;
                rc.delta = delta;
                rc.precision = precision;
                let local = fingerprint(&engine, Mode::InProcess, rc);
                for (mode, mode_name) in [(Mode::Channel, "channel"), (Mode::Tcp, "tcp")] {
                    let wire = fingerprint(&engine, mode, rc);
                    assert_eq!(
                        local.to_string_compact(),
                        wire.to_string_compact(),
                        "{mode_name} diverged from in-process at \
                         {precision:?}/{name}/delta={delta}"
                    );
                }
            }
        }
    }
}

/// The savings are real on the billed wire: under the `full` policy the
/// same rows ship at every precision, so int8 cuts every executed
/// round's KV bytes at least 3.5× below the f32 baseline and f16 cuts
/// them exactly 2× — while the session still decodes.
#[test]
fn int8_cuts_wire_kv_bytes_at_least_3_5x() {
    let Some(engine) = engine() else { return };
    let base = RunCfg::new("full", KvExchangePolicy::Full);
    let f32_rep = run_session(&engine, Mode::InProcess, base);
    let mut rc16 = base;
    rc16.precision = KvPrecision::F16;
    let f16_rep = run_session(&engine, Mode::InProcess, rc16);
    let mut rc8 = base;
    rc8.precision = KvPrecision::Int8;
    let i8_rep = run_session(&engine, Mode::InProcess, rc8);

    assert!(i8_rep.generated_tokens > 0, "int8 session produced no tokens");
    assert!(f32_rep.net.rounds > 0, "baseline executed no rounds");
    assert_eq!(f32_rep.net.rounds, i8_rep.net.rounds, "round count changed with precision");
    assert_eq!(f32_rep.net.round_bytes.len(), i8_rep.net.round_bytes.len());
    for (i, ((&fr, &hr), &qr)) in f32_rep
        .net
        .round_bytes
        .iter()
        .zip(&f16_rep.net.round_bytes)
        .zip(&i8_rep.net.round_bytes)
        .enumerate()
    {
        assert_eq!(hr * 2, fr, "round {i}: f16 bytes {hr} not half of f32 {fr}");
        // qr ≤ fr / 3.5, in exact integer arithmetic.
        assert!(
            qr * 7 <= fr * 2,
            "round {i}: int8 bytes {qr} not ≥ 3.5× below f32 {fr}"
        );
        assert!(qr > 0, "round {i}: int8 round billed zero bytes");
    }
}

/// A deadline can only shrink communication relative to no deadline:
/// with the `full` policy every round's candidate payloads are fixed, so
/// any finite deadline bills a subset of the undeadlined bytes and
/// records at most as many rounds — while the session still decodes (it
/// degrades to local attention, it does not fail).  A zero deadline on a
/// latency-bearing link silences every round.
#[test]
fn deadlines_shrink_communication_and_degrade_gracefully() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let run = |deadline: Option<f64>| {
        let mut rng = SplitMix64::new(31);
        let ep = gen_episode(&mut rng, 4);
        let part = partition(&ep, n, Segmentation::SemQEx);
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
        cfg.seed = 11;
        cfg.round_deadline_ms = deadline;
        let link = LinkSpec { bandwidth_mbps: 8.0, latency_ms: 4.0, jitter: 0.3 };
        let net = NetSim::uniform(Topology::Star, n, link, 11);
        let rep = FedSession::new(&engine, &part, cfg, net).unwrap().run().unwrap();
        (rep.net.total_bytes(), rep.net.rounds, rep.generated_tokens)
    };
    let (bytes_inf, rounds_inf, tokens_inf) = run(None);
    assert!(tokens_inf > 0);
    for d in [40.0, 15.0, 6.0, 0.0] {
        let (bytes, rounds, tokens) = run(Some(d));
        assert!(
            bytes <= bytes_inf,
            "deadline {d} ms grew bytes: {bytes} > {bytes_inf}"
        );
        assert!(
            rounds <= rounds_inf,
            "deadline {d} ms grew rounds: {rounds} > {rounds_inf}"
        );
        assert!(tokens > 0, "deadline {d} ms produced no tokens");
    }
    // Zero deadline on a 4 ms-latency link: nothing arrives in time.
    let (bytes0, rounds0, _) = run(Some(0.0));
    assert_eq!((bytes0, rounds0), (0, 0), "zero deadline must silence every round");
}

// ---------------------------------------------------------------------------
// Node-resident compute: wire capture, churn, and edge-case regressions
// ---------------------------------------------------------------------------

/// Records every frame that crosses it, in both directions, while
/// forwarding to an inner channel transport.  `sent` is driver → node,
/// `recvd` is node → driver.
struct CapturingTransport {
    inner: ChannelTransport,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
    recvd: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl Transport for CapturingTransport {
    fn send(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        self.sent.lock().unwrap().push(frame.to_vec());
        self.inner.send(frame)
    }
    fn recv(&mut self) -> Result<Vec<u8>, TransportError> {
        let frame = self.inner.recv()?;
        self.recvd.lock().unwrap().push(frame.clone());
        Ok(frame)
    }
    fn set_recv_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        self.inner.set_recv_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.inner.peer()
    }
}

/// Forwards to an inner channel transport for a fixed number of
/// operations, then drops the channel (so the node host sees a clean
/// close) and fails every further call — a node crashing mid-session.
struct DyingTransport {
    inner: Option<ChannelTransport>,
    ops_left: usize,
}

impl DyingTransport {
    fn live(&mut self) -> Result<&mut ChannelTransport, TransportError> {
        if self.ops_left == 0 {
            self.inner = None;
            return Err(TransportError::Closed);
        }
        self.ops_left -= 1;
        self.inner.as_mut().ok_or(TransportError::Closed)
    }
}

impl Transport for DyingTransport {
    fn send(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        self.live()?.send(frame)
    }
    fn recv(&mut self) -> Result<Vec<u8>, TransportError> {
        self.live()?.recv()
    }
    fn set_recv_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(t) => t.set_recv_timeout(timeout),
            None => Err(TransportError::Closed),
        }
    }
    fn peer(&self) -> String {
        "dying-channel".into()
    }
}

/// The privacy boundary, asserted on the actual bytes: every frame that
/// crosses the wire in a node-resident session is either a control
/// message or a protocol frame (contribution / downlink frame / decode
/// tail / token broadcast) — there is no message type that could carry a
/// hidden state or a token embedding, and every untransmitted row in a
/// downlink frame is all-zero (the un-shipped KV values never left the
/// driver).  Runs both full-frame and delta downlinks.
#[test]
fn wire_carries_only_protocol_messages_no_hidden_state() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    for delta in [false, true] {
        let mut rng = SplitMix64::new(31);
        let ep = gen_episode(&mut rng, 4);
        let part = partition(&ep, n, Segmentation::SemQEx);
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
        cfg.kv_policy = KvExchangePolicy::Random { ratio: 0.5 };
        cfg.seed = 11;
        cfg.decode_all = true;
        cfg.delta_frames = delta;
        let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

        let sent = Arc::new(Mutex::new(Vec::new()));
        let recvd = Arc::new(Mutex::new(Vec::new()));
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        let mut hosts = Vec::with_capacity(n);
        for p in 0..n {
            let (driver_end, node_end) = ChannelTransport::pair();
            let engine = engine.clone();
            hosts.push(std::thread::spawn(move || {
                NodeHost::new(engine, Box::new(node_end))
                    .serve()
                    .unwrap_or_else(|e| panic!("node host {p} failed: {e:#}"));
            }));
            transports.push(Box::new(CapturingTransport {
                inner: driver_end,
                sent: Arc::clone(&sent),
                recvd: Arc::clone(&recvd),
            }));
        }
        let rep = TransportDriver::new(&engine, &part, cfg, net, transports)
            .unwrap()
            .run()
            .unwrap();
        for h in hosts {
            h.join().expect("node host thread panicked");
        }
        assert!(rep.generated_tokens > 0);

        let sent = sent.lock().unwrap();
        let recvd = recvd.lock().unwrap();
        assert!(!sent.is_empty() && !recvd.is_empty());
        let (mut contributions, mut frames, mut tokens) = (0usize, 0usize, 0usize);
        for (dir, frame) in sent
            .iter()
            .map(|f| ("driver->node", f))
            .chain(recvd.iter().map(|f| ("node->driver", f)))
        {
            if CtrlMsg::decode(frame).is_ok() {
                continue; // Typed control message: no tensor payload fields.
            }
            match wire_kind(frame) {
                Some(WireKind::Contribution) => {
                    KvContribution::decode(frame).unwrap();
                    contributions += 1;
                }
                Some(WireKind::Frame) => {
                    let f = GlobalKvFrame::decode(frame).unwrap();
                    let row_len = f.kv_heads * f.head_dim;
                    for (i, m) in f.meta.iter().enumerate() {
                        if m.transmitted {
                            continue;
                        }
                        let zeros = |d: &[f32]| {
                            d[i * row_len..(i + 1) * row_len].iter().all(|&x| x == 0.0)
                        };
                        assert!(
                            zeros(&f.k) && zeros(&f.v),
                            "untransmitted row {i} (owner {}) carries data on the wire",
                            m.owner
                        );
                    }
                    frames += 1;
                }
                Some(WireKind::DeltaFrame) => {
                    GlobalKvDeltaFrame::decode(frame).unwrap();
                    frames += 1;
                }
                Some(WireKind::Token) | Some(WireKind::DecodeTail) => tokens += 1,
                None => panic!("unclassifiable {dir} frame ({} bytes): neither a control message nor a protocol frame", frame.len()),
            }
        }
        assert!(contributions > 0, "no KV contributions captured (delta={delta})");
        assert!(frames > 0, "no downlink frames captured (delta={delta})");
        assert!(tokens > 0, "no decode traffic captured (delta={delta})");
    }
}

/// A node whose transport dies mid-session is demoted — excluded from
/// rounds and decode like a deadline miss — while the survivors finish
/// the session and the publisher still answers.
#[test]
fn node_churn_demotes_without_killing_session() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let publisher = part.publisher();
    let dead = (publisher + 1) % n;
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.seed = 11;
    cfg.decode_all = true;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    let mut hosts = Vec::with_capacity(n);
    for p in 0..n {
        let (driver_end, node_end) = ChannelTransport::pair();
        let engine = engine.clone();
        // The dying node's host may exit with a clean close (Ok) or a
        // mid-frame truncation, depending on where the cut lands.
        let tolerant = p == dead;
        hosts.push(std::thread::spawn(move || {
            let res = NodeHost::new(engine, Box::new(node_end)).serve();
            if !tolerant {
                res.unwrap_or_else(|e| panic!("surviving node host {p} failed: {e:#}"));
            }
        }));
        if p == dead {
            // 8 transport operations: past the 2-op Join handshake, into
            // the prefill rounds.
            transports.push(Box::new(DyingTransport { inner: Some(driver_end), ops_left: 8 }));
        } else {
            transports.push(Box::new(driver_end));
        }
    }
    let rep = TransportDriver::new(&engine, &part, cfg, net, transports)
        .unwrap()
        .run()
        .unwrap();
    for h in hosts {
        h.join().expect("node host thread panicked");
    }
    assert!(rep.answers[dead].is_none(), "dead node must not produce an answer");
    assert!(rep.answers[publisher].is_some(), "publisher must still decode");
    assert!(!rep.answer.is_empty(), "session answer must survive the churn");
    assert!(rep.generated_tokens > 0);
}

/// A hostile `AdvanceLocal` with an out-of-range block index — the
/// mutated-control-message attack on the old `self.caches[block]` panic
/// site — draws a `Fault` reply and a clean error from the host, not a
/// panic.
#[test]
fn node_host_faults_on_hostile_block_index() {
    let Some(engine) = engine() else { return };
    let (mut driver_end, node_end) = ChannelTransport::pair();
    let host = std::thread::spawn(move || NodeHost::new(engine, Box::new(node_end)).serve());

    let join = CtrlMsg::Join {
        id: 0,
        keep_caches: true,
        round_deadline_ms: None,
        ids: vec![1, 2, 3],
        pos: vec![0, 1, 2],
        kv_precision: KvPrecision::F32,
    };
    driver_end.send(&join.encode()).unwrap();
    let ack = CtrlMsg::decode(&driver_end.recv().unwrap()).unwrap();
    assert!(
        matches!(ack, CtrlMsg::JoinAck { id: 0, valid: 3, .. }),
        "unexpected handshake reply: {ack:?}"
    );

    driver_end.send(&CtrlMsg::AdvanceLocal { block: 9999 }.encode()).unwrap();
    match CtrlMsg::decode(&driver_end.recv().unwrap()).unwrap() {
        CtrlMsg::Fault { message } => {
            assert!(message.contains("9999"), "fault does not name the bad block: {message}")
        }
        other => panic!("expected a fault, got {other:?}"),
    }
    assert!(
        host.join().unwrap().is_err(),
        "host must stop with an error after a hostile block index"
    );
}

/// The node derives its read timeout from the deadline announced in the
/// `Join` handshake (deadline + grace) instead of keeping whatever the
/// transport was created with: a node armed with a 150 ms timeout must
/// survive a 500 ms idle gap once the driver has announced a 60 s round
/// deadline.
#[test]
fn node_read_timeout_derives_from_announced_deadline() {
    let Some(engine) = engine() else { return };
    let (mut driver_end, node_end) = ChannelTransport::pair();
    let node_end = node_end.with_timeout(Duration::from_millis(150));
    let host = std::thread::spawn(move || NodeHost::new(engine, Box::new(node_end)).serve());

    let join = CtrlMsg::Join {
        id: 0,
        keep_caches: false,
        round_deadline_ms: Some(60_000.0),
        ids: vec![1, 2, 3],
        pos: vec![0, 1, 2],
        kv_precision: KvPrecision::F32,
    };
    driver_end.send(&join.encode()).unwrap();
    let ack = CtrlMsg::decode(&driver_end.recv().unwrap()).unwrap();
    assert!(matches!(ack, CtrlMsg::JoinAck { .. }), "unexpected handshake reply: {ack:?}");

    // Longer than the initial 150 ms arm; within the re-armed deadline +
    // grace window.  Without the Join-time re-arm the host times out here.
    std::thread::sleep(Duration::from_millis(500));
    driver_end.send(&CtrlMsg::AdvanceLocal { block: 0 }.encode()).unwrap();
    driver_end.send(&CtrlMsg::Shutdown.encode()).unwrap();
    host.join()
        .unwrap()
        .expect("host must outlive an idle gap longer than its initial timeout");
}

/// A participant whose shard is empty (zero valid rows) is carried
/// through the session without panicking — the old `last_hidden`
/// underflow — and is skipped at decode while the publisher still
/// answers, across local-sparsity presets.
#[test]
fn zero_valid_row_participant_is_skipped_not_panicked() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    for ratio in [1.0, 0.6, 0.2] {
        let mut rng = SplitMix64::new(31);
        let ep = gen_episode(&mut rng, 4);
        let mut part = partition(&ep, n, Segmentation::SemQEx);
        // Empty participant 0's shard outright: local sparsity always
        // keeps at least one token, so the zero-valid case only arises
        // from an empty shard — the regression's trigger.
        part.spans[0] = (part.spans[0].0, part.spans[0].0);
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
        cfg.seed = 11;
        cfg.decode_all = true;
        cfg.local_sparsity = LocalSparsity { ratio };
        let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);
        let rep = FedSession::new(&engine, &part, cfg, net).unwrap().run().unwrap();
        assert!(
            rep.answers[0].is_none(),
            "zero-valid participant must be skipped at decode (ratio {ratio})"
        );
        assert!(
            rep.answers[part.publisher()].is_some(),
            "publisher must still decode (ratio {ratio})"
        );
        assert!(!rep.answer.is_empty(), "publisher answer empty (ratio {ratio})");
    }
}

// ---------------------------------------------------------------------------
// Churn recovery: rejoin differential, chaos property, mid-decode churn
// ---------------------------------------------------------------------------

/// Transcript + billing fingerprint for the churn differentials: every
/// field a rejoined world must reproduce byte-for-byte.  Churn counters
/// (`demotions`/`rejoins`/`resync_bytes`) are deliberately excluded —
/// they are *supposed* to differ between a cut-and-readmitted world and
/// the deadline-miss world it must otherwise equal.
fn session_fp(rep: &SessionReport) -> String {
    let answers: Vec<Json> = rep
        .answers
        .iter()
        .map(|a| Json::Str(a.clone().unwrap_or_default()))
        .collect();
    JsonBuilder::new()
        .str("answer", &rep.answer)
        .num("generated_tokens", rep.generated_tokens as f64)
        .num("rounds", rep.net.rounds as f64)
        .arr_num(
            "tx_bytes",
            &rep.net.tx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "rx_bytes",
            &rep.net.rx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "round_bytes",
            &rep.net.round_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .set("answers", Json::Arr(answers))
        .build()
        .to_string_compact()
}

/// `session_fp` plus the churn counters: the determinism fingerprint for
/// chaos runs, where the *events* themselves must replay identically.
fn chaos_fp(rep: &SessionReport) -> String {
    format!(
        "{}|demotions={} rejoins={} retries={} resync_bytes={}",
        session_fp(rep),
        rep.net.demotions,
        rep.net.rejoins,
        rep.net.retries,
        rep.net.resync_bytes
    )
}

/// Cuts the driver→node link on the Nth `AdvanceSync` the driver sends
/// (1-based), dropping the inner transport so the node host sees a clean
/// close — a node crash aligned to a specific executed sync round.
struct KillOnNthAdvanceSync {
    inner: Option<Box<dyn Transport>>,
    sync_sends_left: usize,
}

impl Transport for KillOnNthAdvanceSync {
    fn send(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        if self.inner.is_some() {
            if let Ok(CtrlMsg::AdvanceSync { .. }) = CtrlMsg::decode(frame) {
                self.sync_sends_left -= 1;
                if self.sync_sends_left == 0 {
                    self.inner = None;
                    return Err(TransportError::Closed);
                }
            }
        }
        match self.inner.as_mut() {
            Some(t) => t.send(frame),
            None => Err(TransportError::Closed),
        }
    }
    fn recv(&mut self) -> Result<Vec<u8>, TransportError> {
        match self.inner.as_mut() {
            Some(t) => t.recv(),
            None => Err(TransportError::Closed),
        }
    }
    fn set_recv_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(t) => t.set_recv_timeout(timeout),
            None => Err(TransportError::Closed),
        }
    }
    fn peer(&self) -> String {
        "kill-on-advance-sync".into()
    }
}

/// The two worlds of the rejoin differential.
#[derive(Clone, Copy)]
enum ChurnWorld {
    /// Cut the victim's link on its `kill_on`-th `AdvanceSync` (1-based)
    /// and let it rejoin at the next round boundary.
    Cut { kill_on: usize },
    /// Never cut anything: force the victim late at `kill_block` via the
    /// RNG-free `late_overrides` fixture — the deadline-miss reference.
    Late { kill_block: usize },
}

/// One session in the `session_golden` workload shape with the victim
/// either cut-and-rejoined or merely deadline-missed at the same round.
fn run_rejoin_world(
    engine: &Engine,
    mode: Mode,
    policy: KvExchangePolicy,
    delta: bool,
    victim: usize,
    world: ChurnWorld,
) -> SessionReport {
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.kv_policy = policy;
    cfg.seed = 11;
    cfg.decode_all = true;
    cfg.delta_frames = delta;
    match world {
        ChurnWorld::Cut { .. } => {
            cfg.rejoin = true;
            cfg.rejoin_max_attempts = 3;
        }
        ChurnWorld::Late { kill_block } => {
            cfg.late_overrides = Some(vec![(kill_block, victim)]);
        }
    }
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    // Host threads grow past `n` when the reconnector spawns replacement
    // hosts, so the list lives behind a shared handle.
    let handles: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    for p in 0..n {
        let cut_here = matches!(world, ChurnWorld::Cut { .. }) && p == victim;
        let raw: Box<dyn Transport> = match mode {
            Mode::InProcess => unreachable!("no hosts for in-process runs"),
            Mode::Channel => {
                let (driver_end, node_end) = ChannelTransport::pair();
                let engine_c = engine.clone();
                handles.lock().unwrap().push(std::thread::spawn(move || {
                    // The cut node's host may exit with a clean close or a
                    // truncation, depending on where the cut lands.
                    let res = NodeHost::new(engine_c, Box::new(node_end)).serve();
                    if !cut_here {
                        res.unwrap_or_else(|e| panic!("channel node host {p} failed: {e:#}"));
                    }
                }));
                Box::new(driver_end)
            }
            Mode::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap();
                let engine_c = engine.clone();
                handles.lock().unwrap().push(std::thread::spawn(move || {
                    let (stream, _) = listener.accept().unwrap();
                    let t = TcpTransport::from_stream(stream).unwrap();
                    let res = NodeHost::new(engine_c, Box::new(t)).serve();
                    if !cut_here {
                        res.unwrap_or_else(|e| panic!("tcp node host {p} failed: {e:#}"));
                    }
                }));
                Box::new(TcpTransport::connect(addr).unwrap())
            }
        };
        transports.push(if cut_here {
            let ChurnWorld::Cut { kill_on } = world else { unreachable!() };
            Box::new(KillOnNthAdvanceSync { inner: Some(raw), sync_sends_left: kill_on })
        } else {
            raw
        });
    }

    let mut driver = TransportDriver::new(engine, &part, cfg, net, transports).unwrap();
    if matches!(world, ChurnWorld::Cut { .. }) {
        let handles2 = Arc::clone(&handles);
        let engine2 = engine.clone();
        driver = driver.with_reconnector(Box::new(move |p| {
            assert_eq!(p, victim, "only the cut node should retry");
            Ok(match mode {
                Mode::InProcess => unreachable!("no hosts for in-process runs"),
                Mode::Channel => {
                    let (driver_end, node_end) = ChannelTransport::pair();
                    let engine_c = engine2.clone();
                    handles2.lock().unwrap().push(std::thread::spawn(move || {
                        // A rejoined host ends with a clean shutdown — or a
                        // closed link if the session finishes without it.
                        let _ = NodeHost::new(engine_c, Box::new(node_end)).serve();
                    }));
                    Box::new(driver_end) as Box<dyn Transport>
                }
                Mode::Tcp => {
                    let listener = TcpListener::bind("127.0.0.1:0")?;
                    let addr = listener.local_addr()?;
                    let engine_c = engine2.clone();
                    handles2.lock().unwrap().push(std::thread::spawn(move || {
                        if let Ok((stream, _)) = listener.accept() {
                            if let Ok(t) = TcpTransport::from_stream(stream) {
                                let _ = NodeHost::new(engine_c, Box::new(t)).serve();
                            }
                        }
                    }));
                    Box::new(TcpTransport::connect(addr)?) as Box<dyn Transport>
                }
            })
        }));
    }
    let rep = driver.run().unwrap();
    let hs: Vec<JoinHandle<()>> = std::mem::take(&mut *handles.lock().unwrap());
    for h in hs {
        h.join().expect("node host thread panicked");
    }
    rep
}

/// The rejoin differential: a node whose link is cut at an executed sync
/// round and readmitted through `Rejoin`/`Resync` at the next round
/// boundary produces a session — every answer, every billed byte —
/// byte-identical to a world where the same node merely missed that one
/// round as a deadline miss.  Across two KV policies (stateless and
/// relevance-tracking) × delta frames on/off × channel and TCP.
#[test]
fn rejoin_resync_matches_deadline_miss_world() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let victim = (part.publisher() + 1) % n;
    let sched = SyncSchedule::uniform(md.n_layers, n, 2);
    let sync_blocks: Vec<usize> = (0..md.n_layers)
        .filter(|&m| sched.attend[m].iter().any(|&b| b))
        .collect();
    assert!(sync_blocks.len() >= 2, "workload too small for a mid-session cut");
    // Cut at the second executed sync round (so the victim has one
    // attended round to resync) when a later round boundary remains for
    // readmission; otherwise fall back to the first.
    let (kill_idx, kill_block) = if sync_blocks[1] + 1 < md.n_layers {
        (1usize, sync_blocks[1])
    } else {
        (0usize, sync_blocks[0])
    };
    assert!(kill_block + 1 < md.n_layers, "no round boundary left to rejoin at");

    for mode in [Mode::Channel, Mode::Tcp] {
        let mode_name = match mode {
            Mode::Channel => "channel",
            _ => "tcp",
        };
        for (name, policy) in [
            ("full", KvExchangePolicy::Full),
            ("top-k-relevance", KvExchangePolicy::TopKRelevance { budget_rows: 8 }),
        ] {
            for delta in [true, false] {
                let tag = format!("{mode_name}/{name}/delta={delta}");
                let churn = run_rejoin_world(
                    &engine,
                    mode,
                    policy,
                    delta,
                    victim,
                    ChurnWorld::Cut { kill_on: kill_idx + 1 },
                );
                let late = run_rejoin_world(
                    &engine,
                    mode,
                    policy,
                    delta,
                    victim,
                    ChurnWorld::Late { kill_block },
                );
                assert_eq!(
                    session_fp(&churn),
                    session_fp(&late),
                    "{tag}: rejoined world diverged from the deadline-miss world"
                );
                assert_eq!(churn.answers, late.answers, "{tag}: transcripts diverged");
                assert!(
                    churn.answers[victim].is_some(),
                    "{tag}: the rejoined node must decode"
                );
                assert_eq!(churn.net.rejoins, 1, "{tag}: expected exactly one rejoin");
                assert_eq!(churn.net.demotions, 0, "{tag}: readmission must not demote");
                assert_eq!(churn.net.retries, 0, "{tag}: first reconnect must succeed");
                if kill_idx == 1 {
                    assert!(
                        churn.net.resync_bytes > 0,
                        "{tag}: an attended round must ship resync bytes"
                    );
                }
                assert_eq!(
                    (late.net.demotions, late.net.rejoins, late.net.resync_bytes),
                    (0, 0, 0),
                    "{tag}: the deadline-miss world must record no churn"
                );
            }
        }
    }
}

/// A seeded fault schedule with the 2-op `Join` handshake (send + ack)
/// left clean: a session that cannot even admit a node is a setup error,
/// not churn.
fn chaos_schedule(seed: u64, rate: f64) -> FaultSchedule {
    const HORIZON: u64 = 600;
    let raw = FaultSchedule::from_seed(seed, rate, HORIZON);
    let mut s = FaultSchedule::none();
    for op in 2..HORIZON {
        if let Some(f) = raw.at(op) {
            s = s.with_fault(op, f);
        }
    }
    s
}

/// One chaos session: both non-publisher links wrapped in a seeded
/// [`ChaosTransport`], the publisher clean (a demoted publisher is
/// correctly fatal and not this property's subject).
fn run_chaos(engine: &Engine, chaos_seed: u64, rate: f64, rejoin: bool) -> SessionReport {
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let publisher = part.publisher();
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.kv_policy = KvExchangePolicy::Full;
    cfg.seed = 11;
    cfg.rejoin = rejoin;
    cfg.rejoin_max_attempts = 3;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    let handles: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    for p in 0..n {
        let (driver_end, node_end) = ChannelTransport::pair();
        let engine_c = engine.clone();
        let strict = p == publisher;
        handles.lock().unwrap().push(std::thread::spawn(move || {
            let res = NodeHost::new(engine_c, Box::new(node_end)).serve();
            if strict {
                res.unwrap_or_else(|e| panic!("publisher node host {p} failed: {e:#}"));
            }
        }));
        if p == publisher {
            transports.push(Box::new(driver_end));
        } else {
            transports.push(Box::new(ChaosTransport::new(
                driver_end,
                chaos_schedule(chaos_seed ^ p as u64, rate),
            )));
        }
    }

    let mut driver = TransportDriver::new(engine, &part, cfg, net, transports).unwrap();
    if rejoin {
        let handles2 = Arc::clone(&handles);
        let engine2 = engine.clone();
        driver = driver.with_reconnector(Box::new(move |_p| {
            // Replacement links are clean: chaos models the *old* link's
            // failure, and a deterministic schedule on a reconnect whose
            // timing depends on the fault pattern would be circular.
            let (driver_end, node_end) = ChannelTransport::pair();
            let engine_c = engine2.clone();
            handles2.lock().unwrap().push(std::thread::spawn(move || {
                let _ = NodeHost::new(engine_c, Box::new(node_end)).serve();
            }));
            Ok(Box::new(driver_end) as Box<dyn Transport>)
        }));
    }
    let rep = driver.run().unwrap();
    let hs: Vec<JoinHandle<()>> = std::mem::take(&mut *handles.lock().unwrap());
    for h in hs {
        h.join().expect("node host thread panicked");
    }
    rep
}

/// The chaos property, across three fault-schedule seeds: a session with
/// seeded faults on every non-publisher link (drops, truncations,
/// duplicates, corrupt bytes) completes without panicking — churn is
/// absorbed, never fatal — and is byte-identical across reruns of the
/// same seed, with and without rejoin.  A zero-rate chaos wrapper is a
/// transparent pass-through: byte-identical to the unwrapped session and
/// free of churn events.
#[test]
fn chaos_sessions_complete_and_are_deterministic() {
    let Some(engine) = engine() else { return };
    const RATE: f64 = 0.07;
    for seed in [101u64, 202, 303] {
        for rejoin in [false, true] {
            let a = run_chaos(&engine, seed, RATE, rejoin);
            assert!(
                a.generated_tokens > 0,
                "seed {seed} rejoin={rejoin}: no tokens decoded under chaos"
            );
            assert!(
                !a.answer.is_empty(),
                "seed {seed} rejoin={rejoin}: empty answer under chaos"
            );
            let b = run_chaos(&engine, seed, RATE, rejoin);
            assert_eq!(
                chaos_fp(&a),
                chaos_fp(&b),
                "seed {seed} rejoin={rejoin}: chaos session not deterministic"
            );
        }
        let quiet = run_chaos(&engine, seed, 0.0, false);
        let clean = run_session(&engine, Mode::Channel, RunCfg::new("full", KvExchangePolicy::Full));
        assert_eq!(
            chaos_fp(&quiet),
            chaos_fp(&clean),
            "seed {seed}: a zero-rate chaos wrapper must change nothing"
        );
        assert_eq!(
            (quiet.net.demotions, quiet.net.rejoins, quiet.net.retries),
            (0, 0, 0),
            "seed {seed}: a zero-rate chaos run must record no churn"
        );
    }
}

/// Passes everything through until the second `TokenBroadcast` it
/// receives, then drops the link: a node dying *between* token
/// broadcasts, mid-decode.
struct DyingMidDecode {
    inner: Option<ChannelTransport>,
    tokens_seen: usize,
}

impl Transport for DyingMidDecode {
    fn send(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(t) => t.send(frame),
            None => Err(TransportError::Closed),
        }
    }
    fn recv(&mut self) -> Result<Vec<u8>, TransportError> {
        let t = self.inner.as_mut().ok_or(TransportError::Closed)?;
        let frame = t.recv()?;
        if wire_kind(&frame) == Some(WireKind::Token) {
            self.tokens_seen += 1;
            if self.tokens_seen == 2 {
                self.inner = None;
                return Err(TransportError::Closed);
            }
        }
        Ok(frame)
    }
    fn set_recv_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        match self.inner.as_mut() {
            Some(t) => t.set_recv_timeout(timeout),
            None => Err(TransportError::Closed),
        }
    }
    fn peer(&self) -> String {
        "dying-mid-decode".into()
    }
}

/// Mid-decode churn: a non-publisher node whose link dies between
/// `TokenBroadcast` frames of its own decode is demoted — its answer
/// absent — while the session completes and the publisher's transcript
/// is byte-identical to an undisturbed run.
#[test]
fn mid_decode_churn_leaves_answer_absent_not_fatal() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let publisher = part.publisher();
    let dead = (publisher + 1) % n;
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.seed = 11;
    cfg.decode_all = true;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    let mut hosts = Vec::with_capacity(n);
    for p in 0..n {
        let (driver_end, node_end) = ChannelTransport::pair();
        let engine_c = engine.clone();
        // The dying node's host fails when its token stream hits the
        // dropped link; every other host must finish cleanly.
        let tolerant = p == dead;
        hosts.push(std::thread::spawn(move || {
            let res = NodeHost::new(engine_c, Box::new(node_end)).serve();
            if !tolerant {
                res.unwrap_or_else(|e| panic!("surviving node host {p} failed: {e:#}"));
            }
        }));
        if p == dead {
            transports.push(Box::new(DyingMidDecode { inner: Some(driver_end), tokens_seen: 0 }));
        } else {
            transports.push(Box::new(driver_end));
        }
    }
    let rep = TransportDriver::new(&engine, &part, cfg, net, transports)
        .unwrap()
        .run()
        .unwrap();
    for h in hosts {
        h.join().expect("node host thread panicked");
    }

    let mut rc = RunCfg::new("full", KvExchangePolicy::Full);
    rc.decode_all = true;
    let clean = run_session(&engine, Mode::Channel, rc);

    assert!(rep.answers[dead].is_none(), "dead node's answer must be absent");
    assert!(clean.answers[dead].is_some(), "the victim decodes in the clean world");
    assert_eq!(rep.answer, clean.answer, "publisher answer disturbed by mid-decode churn");
    assert_eq!(rep.answers[publisher], clean.answers[publisher]);
    assert_eq!(rep.generated_tokens, clean.generated_tokens);
    assert!(rep.generated_tokens > 0);
    assert_eq!(rep.net.demotions, 1, "a mid-decode death is one demotion");
    assert_eq!(rep.net.rejoins, 0, "no rejoin window during decode");
    // Prefill billing is untouched by a decode-phase death.
    assert_eq!(rep.net.tx_bytes, clean.net.tx_bytes);
    assert_eq!(rep.net.round_bytes, clean.net.round_bytes);
}

// ---------------------------------------------------------------------------
// Liveness heartbeats
// ---------------------------------------------------------------------------

/// Heartbeats are pure control-plane traffic: a wire session where every
/// ping is answered (hosts always pong; the window is generous) must be
/// byte-identical — answers, billed bytes, churn counters — to the same
/// session with the heartbeat plane disarmed.
#[test]
fn heartbeat_on_healthy_links_changes_nothing() {
    let Some(engine) = engine() else { return };
    let mut off = RunCfg::new("full", KvExchangePolicy::Full);
    off.decode_all = true;
    let mut on = off;
    on.heartbeat = Some(5_000.0);

    let quiet = run_session(&engine, Mode::Channel, off);
    let beating = run_session(&engine, Mode::Channel, on);
    assert_eq!(
        chaos_fp(&quiet),
        chaos_fp(&beating),
        "an answered heartbeat stream must not change the session"
    );
    assert_eq!(
        (beating.net.demotions, beating.net.rejoins, beating.net.retries),
        (0, 0, 0),
        "healthy heartbeats must record no churn"
    );
}

/// Swallows driver→node `Ping` frames (pretending they were sent) so the
/// driver's pong wait times out: a host that is reachable but wedged —
/// exactly what the heartbeat plane exists to catch.
struct PingBlackhole {
    inner: ChannelTransport,
}

impl Transport for PingBlackhole {
    fn send(&mut self, frame: &[u8]) -> Result<(), TransportError> {
        if let Ok(CtrlMsg::Ping { .. }) = CtrlMsg::decode(frame) {
            return Ok(());
        }
        self.inner.send(frame)
    }
    fn recv(&mut self) -> Result<Vec<u8>, TransportError> {
        self.inner.recv()
    }
    fn set_recv_timeout(&mut self, timeout: Duration) -> Result<(), TransportError> {
        self.inner.set_recv_timeout(timeout)
    }
    fn peer(&self) -> String {
        "ping-blackhole".into()
    }
}

/// A node that never answers heartbeats is demoted after
/// `heartbeat_max_missed` consecutive missed beats — before it can stall
/// a single protocol turn — and the session completes without it: its
/// answer absent, its uplink never billed, the publisher still decoding.
#[test]
fn muted_node_misses_heartbeats_and_is_demoted() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let publisher = part.publisher();
    let muted = (publisher + 1) % n;
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.seed = 11;
    cfg.decode_all = true;
    // A short window keeps the one demotion fast; once the node is out
    // of `Alive` the heartbeat loop never probes it again.
    cfg.heartbeat_ms = Some(40.0);
    cfg.heartbeat_max_missed = 2;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);

    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    let mut hosts = Vec::with_capacity(n);
    for p in 0..n {
        let (driver_end, node_end) = ChannelTransport::pair();
        let engine_c = engine.clone();
        // The muted node's host is abandoned mid-session (its channel
        // closes when the driver drops it); every other host must finish
        // cleanly.
        let tolerant = p == muted;
        hosts.push(std::thread::spawn(move || {
            let res = NodeHost::new(engine_c, Box::new(node_end)).serve();
            if !tolerant {
                res.unwrap_or_else(|e| panic!("answering node host {p} failed: {e:#}"));
            }
        }));
        if p == muted {
            transports.push(Box::new(PingBlackhole { inner: driver_end }));
        } else {
            transports.push(Box::new(driver_end));
        }
    }
    let rep = TransportDriver::new(&engine, &part, cfg, net, transports)
        .unwrap()
        .run()
        .unwrap();
    for h in hosts {
        h.join().expect("node host thread panicked");
    }

    assert_eq!(rep.net.demotions, 1, "a muted node is exactly one demotion");
    assert_eq!(rep.net.rejoins, 0, "no rejoin armed: demotion is final");
    assert!(rep.answers[muted].is_none(), "the muted node must not decode");
    assert!(!rep.answer.is_empty(), "publisher answer must survive the demotion");
    assert!(rep.answers[publisher].is_some());
    assert!(rep.generated_tokens > 0);
    // Demoted before its first sync round: never billed a byte of uplink.
    assert_eq!(rep.net.tx_bytes[muted], 0, "a pre-sync demotion must not bill uplink");
}
