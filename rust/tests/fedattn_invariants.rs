//! End-to-end algorithmic invariants on live artifacts (trained weights).
//! Skipped with a notice when artifacts are absent.

use std::path::PathBuf;

use fedattn::data::{gen_episode, partition, Segmentation};
use fedattn::fedattn::{
    FedSession, KvExchangePolicy, SessionConfig, SyncSchedule,
};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::util::prng::SplitMix64;

fn engine() -> Option<Engine> {
    let dir: PathBuf = fedattn::default_artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("weights.npz").exists() {
        eprintln!("SKIP: artifacts not found (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir, "weights.npz").unwrap())
}

fn net(n: usize) -> NetSim {
    NetSim::uniform(Topology::Star, n, LinkSpec::default(), 9)
}

/// H=1 FedAttn must equal CenAttn on every token's final hidden state —
/// the keystone correctness invariant (exercises positions, masks, packing
/// and artifact plumbing at once).
#[test]
fn h1_equals_cenattn() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(21);
    for seg in [Segmentation::TokQAg, Segmentation::SemQEx] {
        let ep = gen_episode(&mut rng, 4);
        let part = partition(&ep, 3, seg);
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 3, 1));
        cfg.record_hidden = true;
        let fed = FedSession::new(&engine, &part, cfg, net(3))
            .unwrap()
            .run_prefill_only()
            .unwrap();

        let cen_part = partition(&ep, 1, Segmentation::TokQAg);
        let mut ccfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 1, 1));
        ccfg.record_hidden = true;
        let cen = FedSession::new(&engine, &cen_part, ccfg, net(1))
            .unwrap()
            .run_prefill_only()
            .unwrap();
        let cen_h = cen.hidden[0].as_ref().unwrap();

        let mut max_diff = 0f32;
        for (p, h) in fed.hidden.iter().enumerate() {
            let h = h.as_ref().unwrap();
            for (i, &gpos) in fed.positions[p].iter().enumerate() {
                for (a, b) in h.row(i).iter().zip(cen_h.row(gpos as usize)) {
                    max_diff = max_diff.max((a - b).abs());
                }
            }
        }
        assert!(max_diff < 2e-4, "{seg:?}: H=1 vs CenAttn diff {max_diff}");
    }
}

/// Deviation from CenAttn grows with H (Remark 4's monotonicity, measured).
#[test]
fn deviation_monotone_in_h() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(22);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, 3, Segmentation::SemQEx);

    let cen_part = partition(&ep, 1, Segmentation::TokQAg);
    let mut ccfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 1, 1));
    ccfg.record_hidden = true;
    let cen = FedSession::new(&engine, &cen_part, ccfg, net(1))
        .unwrap()
        .run_prefill_only()
        .unwrap();
    let cen_h = cen.hidden[0].as_ref().unwrap();

    let mut devs = Vec::new();
    for h in [1usize, 2, 4, 8] {
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 3, h));
        cfg.record_hidden = true;
        let fed = FedSession::new(&engine, &part, cfg, net(3))
            .unwrap()
            .run_prefill_only()
            .unwrap();
        let mut sq = 0f64;
        for (p, hh) in fed.hidden.iter().enumerate() {
            let hh = hh.as_ref().unwrap();
            for (i, &gpos) in fed.positions[p].iter().enumerate() {
                for (a, b) in hh.row(i).iter().zip(cen_h.row(gpos as usize)) {
                    let d = (*a - *b) as f64;
                    sq += d * d;
                }
            }
        }
        devs.push(sq.sqrt());
    }
    assert!(devs[0] < 1e-2, "H=1 deviation should be ~0: {devs:?}");
    for w in devs.windows(2) {
        assert!(w[1] >= w[0] * 0.5, "deviation trend violated: {devs:?}");
    }
    assert!(
        devs.last().unwrap() > &(devs[0] + 1e-3),
        "H=M must deviate more than H=1: {devs:?}"
    );
}

/// Sparse KV exchange with ratio 1.0 must be identical to Full.
#[test]
fn kv_ratio_one_equals_full() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(23);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, 3, Segmentation::SemQEx);

    let run = |policy: KvExchangePolicy| {
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 3, 2));
        cfg.kv_policy = policy;
        cfg.record_hidden = true;
        cfg.seed = 5;
        FedSession::new(&engine, &part, cfg, net(3))
            .unwrap()
            .run_prefill_only()
            .unwrap()
    };
    let a = run(KvExchangePolicy::Full);
    let b = run(KvExchangePolicy::Random { ratio: 1.0 });
    for (x, y) in a.hidden.iter().zip(&b.hidden) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert!(x.max_abs_diff(y) == 0.0, "ratio-1.0 sparse must be bit-identical");
    }
}

/// Communication accounting matches the closed-form payload size.
#[test]
fn comm_bytes_match_formula() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(24);
    let ep = gen_episode(&mut rng, 4);
    let n = 3;
    let part = partition(&ep, n, Segmentation::TokQAg);
    let h = 2usize;
    let cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, h));
    let out = FedSession::new(&engine, &part, cfg, net(n))
        .unwrap()
        .run_prefill_only()
        .unwrap();

    let rounds = md.n_layers / h;
    let row_bytes = md.kv_row_bytes() as u64;
    let total_rows: u64 = part.ids.len() as u64;
    // Uplink: every participant sends all its rows each round.
    let expect_tx: u64 = rounds as u64 * total_rows * row_bytes;
    let got_tx: u64 = out.net.tx_bytes.iter().sum();
    assert_eq!(got_tx, expect_tx);
    // Downlink per attendee: total minus its own rows.
    for p in 0..n {
        let own = part.span_len(p) as u64;
        let expect_rx = rounds as u64 * (total_rows - own) * row_bytes;
        assert_eq!(out.net.rx_bytes[p], expect_rx, "participant {p}");
    }
    assert_eq!(out.net.rounds, rounds);
}

/// decode_all produces an answer for every participant; the publisher's
/// equals the canonical `answer`.
#[test]
fn decode_all_answers() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(25);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, 3, Segmentation::SemQEx);
    let publisher = part.publisher();
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 3, 2));
    cfg.decode_all = true;
    let rep = FedSession::new(&engine, &part, cfg, net(3)).unwrap().run().unwrap();
    assert!(rep.answers.iter().all(Option::is_some));
    assert_eq!(rep.answers[publisher].as_deref(), Some(rep.answer.as_str()));
}

/// Local sparsity at ratio 1.0 must not change anything; lower ratios must
/// reduce the tokens entering the session (observable through comm bytes).
#[test]
fn local_sparsity_reduces_comm() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(26);
    let ep = gen_episode(&mut rng, 5);
    let part = partition(&ep, 3, Segmentation::TokQAg);
    let run = |ratio: f64| {
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 3, 2));
        cfg.local_sparsity = fedattn::fedattn::LocalSparsity { ratio };
        cfg.seed = 7;
        FedSession::new(&engine, &part, cfg, net(3))
            .unwrap()
            .run_prefill_only()
            .unwrap()
    };
    let full = run(1.0);
    let sparse = run(0.5);
    let full_tx: u64 = full.net.tx_bytes.iter().sum();
    let sparse_tx: u64 = sparse.net.tx_bytes.iter().sum();
    assert!(
        sparse_tx < full_tx,
        "dropping half the tokens must shrink KV payloads ({sparse_tx} vs {full_tx})"
    );
}
