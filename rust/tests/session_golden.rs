//! Golden-fixture regression for `FedSession`: fixed seed + config must
//! produce byte-identical answers, comm bytes, and per-round tx byte
//! counts, so refactors of the sync loop can't silently drift.
//!
//! Two layers of protection:
//! 1. **Determinism** — every configuration is run twice in-process and
//!    the fingerprints must match exactly.
//! 2. **Golden file** — fingerprints are compared against
//!    `tests/golden/session_golden.json`.  On first run (or with
//!    `FEDATTN_UPDATE_GOLDEN=1`) the file is (re)written instead.
//!
//! Skipped with a notice when artifacts are absent (run `make artifacts`).

use std::path::PathBuf;

use fedattn::data::{gen_episode, partition, Segmentation};
use fedattn::fedattn::{FedSession, KvExchangePolicy, SessionConfig, SyncSchedule};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::prng::SplitMix64;

fn engine() -> Option<Engine> {
    let dir: PathBuf = fedattn::default_artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("weights.npz").exists() {
        eprintln!("SKIP: artifacts not found (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir, "weights.npz").unwrap())
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/session_golden.json")
}

/// One deterministic session fingerprint: integer byte counts and the
/// decoded answers only (no floats, no timings).  `workers > 1` runs the
/// per-participant loops on the session pool; `decode_all` decodes every
/// participant so the fingerprint covers all answer streams.
fn fingerprint_with(
    engine: &Engine,
    name: &str,
    policy: KvExchangePolicy,
    workers: usize,
    decode_all: bool,
) -> Json {
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(31);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
    cfg.kv_policy = policy;
    cfg.seed = 11;
    cfg.workers = workers;
    cfg.decode_all = decode_all;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);
    let rep = FedSession::new(engine, &part, cfg, net).unwrap().run().unwrap();
    let answers: Vec<String> = rep
        .answers
        .iter()
        .map(|a| a.clone().unwrap_or_default())
        .collect();
    let mut b = JsonBuilder::new()
        .str("policy", name)
        .str("answer", &rep.answer)
        .num("generated_tokens", rep.generated_tokens as f64)
        .num("rounds", rep.net.rounds as f64)
        .arr_num(
            "tx_bytes",
            &rep.net.tx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "rx_bytes",
            &rep.net.rx_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
        .arr_num(
            "round_bytes",
            &rep.net.round_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        );
    if decode_all {
        b = b.set(
            "answers",
            Json::Arr(answers.iter().map(|a| Json::Str(a.clone())).collect()),
        );
    }
    b.build()
}

fn fingerprint(engine: &Engine, name: &str, policy: KvExchangePolicy) -> Json {
    fingerprint_with(engine, name, policy, 1, false)
}

#[test]
fn session_deterministic_and_matches_golden() {
    let Some(engine) = engine() else { return };
    let policies = [
        ("full", KvExchangePolicy::Full),
        ("random", KvExchangePolicy::Random { ratio: 0.5 }),
        ("publisher-priority", KvExchangePolicy::PublisherPriority { remote_ratio: 0.5 }),
        ("recent-budget", KvExchangePolicy::RecentBudget { budget_rows: 8 }),
        ("top-k-relevance", KvExchangePolicy::TopKRelevance { budget_rows: 8 }),
        ("byte-budget", KvExchangePolicy::ByteBudget { bytes_per_round: 8192 }),
    ];

    let mut records = Vec::new();
    for (name, policy) in policies {
        let a = fingerprint(&engine, name, policy);
        let b = fingerprint(&engine, name, policy);
        assert_eq!(
            a.to_string_compact(),
            b.to_string_compact(),
            "session not deterministic under {name}"
        );
        records.push(a);
    }
    let got = Json::Arr(records).to_string_compact();

    let path = golden_path();
    let update = std::env::var("FEDATTN_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &got).unwrap();
        eprintln!("golden fixture written to {path:?} — commit it to pin the behaviour");
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got.trim(),
        want.trim(),
        "session fingerprint drifted from {path:?}; if the change is \
         intentional, regenerate with FEDATTN_UPDATE_GOLDEN=1"
    );
}

/// A `workers > 1` session must be byte-identical to the sequential one —
/// answers (all participants, via `decode_all`), comm report, and the
/// relevance-driven transmission byte counts (the `top-k-relevance`
/// fingerprint's tx/round bytes are a function of the accumulated
/// relevance scores, so score drift would surface here).
#[test]
fn parallel_session_is_byte_identical_to_sequential() {
    let Some(engine) = engine() else { return };
    let policies = [
        ("full", KvExchangePolicy::Full),
        ("random", KvExchangePolicy::Random { ratio: 0.5 }),
        ("top-k-relevance", KvExchangePolicy::TopKRelevance { budget_rows: 8 }),
    ];
    for (name, policy) in policies {
        let seq = fingerprint_with(&engine, name, policy, 1, true);
        let par = fingerprint_with(&engine, name, policy, 4, true);
        assert_eq!(
            seq.to_string_compact(),
            par.to_string_compact(),
            "workers=4 session diverged from sequential under {name}"
        );
    }
}

/// Attendance dropout is a deterministic schedule input: the same seed
/// produces the same masked schedule, rounds, and answers, and dropout
/// can only *remove* exchange rounds relative to the undroped session.
/// (`dropout_prob = 0.0` is pinned byte-identical to the pre-dropout
/// session by the golden fixture above — the knob draws from its own RNG
/// stream, never the session's.)
#[test]
fn dropout_session_deterministic_and_only_removes_rounds() {
    let Some(engine) = engine() else { return };
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let run = |dropout: f64| {
        let mut rng = SplitMix64::new(31);
        let ep = gen_episode(&mut rng, 4);
        let part = partition(&ep, n, Segmentation::SemQEx);
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
        cfg.seed = 11;
        cfg.dropout_prob = dropout;
        let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 11);
        let rep = FedSession::new(&engine, &part, cfg, net).unwrap().run().unwrap();
        (rep.answer, rep.net.rounds, rep.net.round_bytes)
    };
    let (_, base_rounds, _) = run(0.0);
    let a = run(0.4);
    let b = run(0.4);
    assert_eq!(a, b, "dropout session must be deterministic in the seed");
    assert!(
        a.1 <= base_rounds,
        "dropout added rounds: {} > {base_rounds}",
        a.1
    );
}
