//! Device-resident execution invariants, asserted via `EngineStats`
//! counters:
//!
//! 1. A shared device KV handle produces the same output as the host
//!    upload path, and repeated `attn_ffn_dev` calls re-upload nothing.
//! 2. In a full session, the packed global KV is uploaded once per sync
//!    round regardless of attendee count (every attendee call lands in
//!    `upload_bytes_saved` instead of `bytes_uploaded`).
//! 3. With decode-tail artifacts, per-decode-step upload bytes are a
//!    function of (d, R) only — independent of the cache capacity `C`.
//!
//! Engine-gated: skipped with a notice when artifacts are absent.

use std::collections::HashMap;
use std::path::PathBuf;

use fedattn::data::{gen_episode, partition, Segmentation};
use fedattn::fedattn::{FedSession, SessionConfig, SyncSchedule};
use fedattn::model::{Manifest, Weights};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::tensor::HostTensor;
use fedattn::util::prng::SplitMix64;
use xla::FromRawBytes;

fn artifacts() -> Option<PathBuf> {
    let dir = fedattn::default_artifacts_dir();
    if dir.join("manifest.json").exists() && dir.join("fixtures.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/fixtures not found (run `make artifacts`)");
        None
    }
}

struct Fx {
    map: HashMap<String, xla::Literal>,
}

impl Fx {
    fn load(dir: &std::path::Path) -> Self {
        let pairs = xla::Literal::read_npz(dir.join("fixtures.npz"), &()).unwrap();
        Self { map: pairs.into_iter().collect() }
    }

    fn tensor(&self, name: &str) -> HostTensor {
        HostTensor::from_literal(
            self.map.get(name).unwrap_or_else(|| panic!("fixture {name}")),
        )
        .unwrap()
    }
}

fn fixture_engine(dir: &std::path::Path) -> Engine {
    let manifest = Manifest::load(dir).unwrap();
    let weights = Weights::load(&dir.join("fixture_weights.npz")).unwrap();
    Engine::new(manifest, weights).unwrap()
}

#[test]
fn shared_kv_handles_match_host_path_and_skip_reupload() {
    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    let engine = fixture_engine(&dir);
    let x = fx.tensor("bf.x");
    let q = fx.tensor("af.q");
    let kg = fx.tensor("af.kg");
    let vg = fx.tensor("af.vg");
    let mask = fx.tensor("af.mask");

    // Host path (uploads K/V itself) vs shared device handles.
    let host_out = engine.attn_ffn(0, &x, &q, &kg, &vg, &mask).unwrap();
    let kd = engine.upload(&kg).unwrap();
    let vd = engine.upload(&vg).unwrap();
    let kv_bytes = kd.byte_len() + vd.byte_len();

    let before = engine.stats.view();
    let calls = 3u64;
    for _ in 0..calls {
        let dev_out = engine.attn_ffn_dev(0, &x, &q, &kd, &vd, &mask).unwrap();
        assert_eq!(dev_out, host_out, "shared-handle output must match host path");
    }
    let after = engine.stats.view();

    // Each call uploaded only x + q + mask; the K/V bytes were saved.
    let per_call_upload = 4 * (x.numel() + q.numel() + mask.numel()) as u64;
    assert_eq!(
        after.bytes_uploaded - before.bytes_uploaded,
        calls * per_call_upload,
        "shared K/V must not be re-uploaded per call"
    );
    assert_eq!(
        after.upload_bytes_saved - before.upload_bytes_saved,
        calls * kv_bytes,
        "every dev call must account the avoided K/V upload"
    );
    assert_eq!(after.exec_attn_ffn - before.exec_attn_ffn, calls);
}

#[test]
fn sync_round_kv_uploads_once_regardless_of_attendees() {
    let Some(dir) = artifacts() else { return };
    let engine = fixture_engine(&dir);
    let md = engine.manifest.model.clone();
    let n = 3usize;

    let mut rng = SplitMix64::new(17);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);
    let schedule = SyncSchedule::uniform(md.n_layers, n, 2);

    // Expected accounting under full attendance + dense local attention:
    // one KV upload per sync round, one avoided re-upload per attendee.
    let g_pad = engine.manifest.pick_g(part.len()).unwrap();
    let kv_bytes = 2 * 4 * (g_pad * md.n_kv_heads * md.head_dim) as u64;
    let sync_rounds = schedule
        .attend
        .iter()
        .filter(|row| row.iter().any(|&b| b))
        .count() as u64;
    let attendee_calls: u64 = schedule
        .attend
        .iter()
        .map(|row| row.iter().filter(|&&b| b).count() as u64)
        .sum();
    assert!(sync_rounds > 0 && attendee_calls > sync_rounds, "schedule not exercising sharing");

    let cfg = SessionConfig::new(schedule);
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 7);
    let before = engine.stats.view();
    FedSession::new(&engine, &part, cfg, net)
        .unwrap()
        .run_prefill_only()
        .unwrap();
    let after = engine.stats.view();

    assert_eq!(
        after.exec_attn_ffn - before.exec_attn_ffn,
        attendee_calls,
        "one attn_ffn execution per attendee per round"
    );
    assert_eq!(
        after.upload_bytes_saved - before.upload_bytes_saved,
        attendee_calls * kv_bytes,
        "every attendee must reuse the round's shared KV upload"
    );
    // The hypothetical no-sharing engine would have uploaded the KV once
    // per attendee; with sharing, the per-round upload is attendee-count
    // independent.  (uploaded + saved) / attendee_calls == kv_bytes holds
    // only for the KV component, so assert the sharing ratio directly:
    let saved = after.upload_bytes_saved - before.upload_bytes_saved;
    assert_eq!(saved / kv_bytes, attendee_calls, "sharing must scale with attendees");
}

#[test]
fn decode_step_upload_bytes_independent_of_cache_capacity() {
    let Some(dir) = artifacts() else { return };
    let engine = fixture_engine(&dir);
    let md = engine.manifest.model.clone();
    let c = engine.manifest.decode_cache;
    let Some(r) = engine.manifest.pick_decode_tail(4) else {
        eprintln!("SKIP: no decode-tail variants (re-run `make artifacts`)");
        return;
    };

    let kc = engine.upload(&HostTensor::zeros(&[c, md.n_kv_heads, md.head_dim])).unwrap();
    let vc = engine.upload(&HostTensor::zeros(&[c, md.n_kv_heads, md.head_dim])).unwrap();
    let mc = engine.upload(&HostTensor::zeros(&[1, c])).unwrap();
    let x = HostTensor::zeros(&[1, md.d_model]);
    let kt = HostTensor::zeros(&[r, md.n_kv_heads, md.head_dim]);
    let vt = kt.clone();
    let tmask = HostTensor::zeros(&[1, r]);

    // Warm up (compile) outside the measured window.
    engine
        .decode_block_tail(0, &x, 0, &kc, &vc, &mc, &kt, &vt, &tmask)
        .unwrap();

    let before = engine.stats.view();
    let steps = 4u64;
    for s in 0..steps {
        engine
            .decode_block_tail(0, &x, s as i32, &kc, &vc, &mc, &kt, &vt, &tmask)
            .unwrap();
    }
    let after = engine.stats.view();

    // Per step: x [1,d] + pos [1] + tail K/V [R,Hkv,hd] + tail mask [1,R].
    // No term involves C — the frozen cache ships zero bytes per step.
    let per_step = 4 * (md.d_model + 1 + 2 * r * md.n_kv_heads * md.head_dim + r) as u64;
    let cache_bytes = 4 * (2 * c * md.n_kv_heads * md.head_dim + c) as u64;
    assert_eq!(after.bytes_uploaded - before.bytes_uploaded, steps * per_step);
    assert_eq!(
        after.upload_bytes_saved - before.upload_bytes_saved,
        steps * cache_bytes,
        "each step must account the frozen cache it did not upload"
    );
    assert!(
        per_step < cache_bytes / 4,
        "tail upload ({per_step} B) must be far below the full-cache path ({cache_bytes} B)"
    );
    assert_eq!(after.exec_decode_tail - before.exec_decode_tail, steps);
}

#[test]
fn device_decode_session_matches_host_decode_session() {
    // The decode answer must not depend on which cache path ran.  (The
    // two paths differ only by masked-out zero terms in the attention
    // reduction; greedy argmax decoding absorbs float-level noise.)
    let Some(dir) = artifacts() else { return };
    let engine = fixture_engine(&dir);
    if engine.manifest.pick_decode_tail(12).is_none() {
        eprintln!("SKIP: no decode-tail variants (re-run `make artifacts`)");
        return;
    }
    let md = engine.manifest.model.clone();
    let n = 3usize;
    let mut rng = SplitMix64::new(23);
    let ep = gen_episode(&mut rng, 4);
    let part = partition(&ep, n, Segmentation::SemQEx);

    let run = |device_decode: bool| {
        let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 2));
        cfg.seed = 5;
        cfg.decode_all = true;
        cfg.device_decode = device_decode;
        let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 5);
        FedSession::new(&engine, &part, cfg, net).unwrap().run().unwrap()
    };
    let dev = run(true);
    let host = run(false);
    assert_eq!(dev.answers, host.answers, "device decode changed the answers");
    assert_eq!(dev.generated_tokens, host.generated_tokens);
}
