//! Integration tests that need no artifacts: config plumbing, CLI parsing,
//! workload generation, schedules and cost models working together.

use fedattn::baselines::{CommCost, ParallelismKind};
use fedattn::cli::Args;
use fedattn::config::{SystemConfig, TomlDoc};
use fedattn::data::{gen_episode, partition, Segmentation, TraceConfig, WorkloadTrace};
use fedattn::fedattn::{Scheme, SyncSchedule};
use fedattn::metrics::CostModel;
use fedattn::model::ModelDims;
use fedattn::tokenizer;
use fedattn::util::prng::SplitMix64;

fn dims() -> ModelDims {
    ModelDims {
        name: "test".into(),
        vocab_size: 128,
        d_model: 96,
        n_layers: 8,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 24,
        d_ff: 256,
        rope_theta: 1e4,
        rms_eps: 1e-6,
    }
}

#[test]
fn episode_partitions_are_token_exact_across_settings() {
    // Decoding each participant's slice and concatenating must reproduce
    // the full prompt, for every segmentation setting.
    let mut rng = SplitMix64::new(11);
    for _ in 0..20 {
        let ep = gen_episode(&mut rng, 4);
        let full = {
            let ids = tokenizer::encode_with_bos(&ep.prompt());
            tokenizer::decode(&ids)
        };
        for seg in Segmentation::ALL {
            let p = partition(&ep, 3, seg);
            let mut recon = String::new();
            for &(s, e) in &p.spans {
                recon.push_str(&tokenizer::decode(&p.ids[s..e]));
            }
            assert_eq!(recon, full, "{seg:?}");
        }
    }
}

#[test]
fn config_cli_overrides_compose() {
    let doc = TomlDoc::parse(
        "[federation]\nparticipants = 5\nsync_h = 4\n[network]\nbandwidth_mbps = 50.0",
    )
    .unwrap();
    let sc = SystemConfig::from_toml(&doc).unwrap();
    assert_eq!(sc.federation.participants, 5);
    assert_eq!(sc.network.link.bandwidth_mbps, 50.0);

    let args = Args::parse(
        ["run", "--participants", "2", "--h=8", "--kv-ratio", "0.5"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert_eq!(args.usize_or("participants", 0), 2);
    assert_eq!(args.usize_or("h", 0), 8);
    assert_eq!(args.f64_or("kv-ratio", 1.0), 0.5);
}

#[test]
fn schedule_comm_rounds_match_expected_budget() {
    // Fig. 7 fairness: all four placement schemes spend the same number of
    // sync rounds.
    for m in [8usize, 12, 16] {
        let budgets: Vec<usize> = [
            Scheme::ShallowHalf { rounds: 4 },
            Scheme::DeepHalf { rounds: 4 },
            Scheme::Progressive { rounds: 4 },
            Scheme::Regressive { rounds: 4 },
        ]
        .iter()
        .map(|s| s.sync_blocks(m).len())
        .collect();
        assert!(budgets.iter().all(|&b| b == 4), "m={m}: {budgets:?}");
    }
}

#[test]
fn trace_generation_respects_load_parameter() {
    let fast = WorkloadTrace::generate(&TraceConfig {
        seed: 1,
        n_tasks: 200,
        mean_interarrival_ms: 10.0,
        ..Default::default()
    });
    let slow = WorkloadTrace::generate(&TraceConfig {
        seed: 1,
        n_tasks: 200,
        mean_interarrival_ms: 100.0,
        ..Default::default()
    });
    assert!(slow.tasks.last().unwrap().arrival_ms > fast.tasks.last().unwrap().arrival_ms * 5.0);
}

#[test]
fn fedattn_comm_advantage_holds_across_scales() {
    // The paper's §II claim, as a property over the config space: FedAttn
    // moves fewer bytes than tensor parallelism whenever H >= 1, and the
    // advantage grows with H.
    let cc = CommCost::default();
    let md = dims();
    for &l in &[128usize, 512, 2048] {
        for &n in &[2usize, 4, 8] {
            let tensor = cc.prefill_bytes(ParallelismKind::Tensor, &md, l, n, 1);
            let mut last = f64::INFINITY;
            for &h in &[1usize, 2, 4, 8] {
                let fa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, l, n, h);
                assert!(fa < tensor, "l={l} n={n} h={h}");
                assert!(fa <= last);
                last = fa;
            }
        }
    }
}

#[test]
fn cost_model_prefill_matches_paper_complexity() {
    // O(L d^2 + L^2 d): doubling L with visibility fixed scales < 4x;
    // doubling both L and G scales between 2x and 4x.
    let cm = CostModel::new(dims());
    let base = cm.block_flops(64, 64);
    let wide = cm.block_flops(128, 128);
    assert!(wide / base > 2.0 && wide / base < 4.0);
    let deep = cm.prefill_cost(64, 256, 6, 2);
    assert!(deep.flops > 0.0 && deep.peak_mem_bytes > 0.0);
}

#[test]
fn per_participant_schedule_totals() {
    let s = SyncSchedule::per_participant(8, &[1, 2, 4, 8]);
    assert_eq!(s.total_attendances(), 8 + 4 + 2 + 1);
    // every block has participant 0 attending
    assert!(s.attend.iter().all(|row| row[0]));
}
