//! Serving-layer stress: the coordinator's bounded `TaskQueue` under a
//! bursty arrival trace.  No compiled engine needed — the queue and the
//! latency machinery are exactly what `Coordinator::serve_trace` runs on,
//! so CI exercises the backpressure path on every push.
//!
//! Asserts: (1) no task is ever dropped or duplicated, (2) the queue never
//! holds more than its capacity (backpressure engaged), (3) queue-latency
//! percentiles are finite and ordered p50 <= p95 <= p99.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedattn::coordinator::TaskQueue;
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::util::stats::percentile;

#[test]
fn bursty_trace_backpressure_no_drops_ordered_percentiles() {
    const CAPACITY: usize = 8;
    const WORKERS: usize = 4;
    const TASKS: usize = 200;

    // A bursty trace: essentially simultaneous arrivals, far faster than
    // the simulated service rate, so the queue saturates immediately.
    let trace = WorkloadTrace::generate(&TraceConfig {
        seed: 3,
        n_tasks: TASKS,
        mean_interarrival_ms: 0.001,
        ..Default::default()
    });

    let queue: Arc<TaskQueue<(usize, Instant)>> = Arc::new(TaskQueue::new(CAPACITY));
    let done: Arc<Mutex<Vec<(usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let max_depth = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|s| {
        for _ in 0..WORKERS {
            let queue = Arc::clone(&queue);
            let done = Arc::clone(&done);
            let max_depth = Arc::clone(&max_depth);
            s.spawn(move || {
                while let Some((id, enqueued)) = queue.pop() {
                    max_depth.fetch_max(queue.len(), Ordering::Relaxed);
                    let queue_ms = enqueued.elapsed().as_secs_f64() * 1e3;
                    // Simulated service time keeps the queue under pressure.
                    std::thread::sleep(Duration::from_micros(200));
                    done.lock().unwrap().push((id, queue_ms));
                }
            });
        }
        for task in &trace.tasks {
            queue.push((task.id, Instant::now()));
            max_depth.fetch_max(queue.len(), Ordering::Relaxed);
        }
        queue.close();
    });

    // (1) Nothing dropped, nothing duplicated.
    let results = Arc::try_unwrap(done).unwrap().into_inner().unwrap();
    assert_eq!(results.len(), TASKS, "tasks lost under backpressure");
    let mut ids: Vec<usize> = results.iter().map(|&(id, _)| id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..TASKS).collect::<Vec<_>>(), "duplicate/missing ids");

    // (2) The bounded queue actually bounded (and actually filled up —
    // otherwise this test would not be exercising backpressure at all).
    let depth = max_depth.load(Ordering::Relaxed);
    assert!(depth <= CAPACITY, "queue depth {depth} exceeded capacity {CAPACITY}");
    assert!(depth >= CAPACITY / 2, "burst never pressured the queue (depth {depth})");

    // (3) Latency percentiles finite and ordered.
    let lats: Vec<f64> = results.iter().map(|&(_, l)| l).collect();
    let p50 = percentile(&lats, 50.0);
    let p95 = percentile(&lats, 95.0);
    let p99 = percentile(&lats, 99.0);
    assert!(p50.is_finite() && p95.is_finite() && p99.is_finite(), "{p50} {p95} {p99}");
    assert!(p50 <= p95 && p95 <= p99, "percentiles out of order: {p50} {p95} {p99}");
    assert!(lats.iter().all(|l| l.is_finite() && *l >= 0.0));
}

/// Closing an empty queue releases blocked consumers; closing a non-empty
/// queue still drains every item first.
#[test]
fn close_drains_remaining_items() {
    let q: TaskQueue<u32> = TaskQueue::new(16);
    for i in 0..5 {
        q.push(i);
    }
    q.close();
    let mut got = Vec::new();
    while let Some(x) = q.pop() {
        got.push(x);
    }
    assert_eq!(got, vec![0, 1, 2, 3, 4]);
}
