//! Serving-layer stress: the coordinator's bounded `TaskQueue` under a
//! bursty arrival trace.  No compiled engine needed — the queue and the
//! latency machinery are exactly what `Coordinator::serve_trace` runs on,
//! so CI exercises the backpressure path on every push.
//!
//! Asserts: (1) no task is ever dropped or duplicated, (2) the queue never
//! holds more than its capacity (backpressure engaged), (3) queue-latency
//! percentiles are finite and ordered p50 <= p95 <= p99.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedattn::coordinator::TaskQueue;
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::util::stats::percentile;

#[test]
fn bursty_trace_backpressure_no_drops_ordered_percentiles() {
    const CAPACITY: usize = 8;
    const WORKERS: usize = 4;
    const TASKS: usize = 200;

    // A bursty trace: essentially simultaneous arrivals, far faster than
    // the simulated service rate, so the queue saturates immediately.
    let trace = WorkloadTrace::generate(&TraceConfig {
        seed: 3,
        n_tasks: TASKS,
        mean_interarrival_ms: 0.001,
        ..Default::default()
    });

    let queue: Arc<TaskQueue<(usize, Instant)>> = Arc::new(TaskQueue::new(CAPACITY));
    let done: Arc<Mutex<Vec<(usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let max_depth = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|s| {
        for _ in 0..WORKERS {
            let queue = Arc::clone(&queue);
            let done = Arc::clone(&done);
            let max_depth = Arc::clone(&max_depth);
            s.spawn(move || {
                while let Some((id, enqueued)) = queue.pop() {
                    max_depth.fetch_max(queue.len(), Ordering::Relaxed);
                    let queue_ms = enqueued.elapsed().as_secs_f64() * 1e3;
                    // Simulated service time keeps the queue under pressure.
                    std::thread::sleep(Duration::from_micros(200));
                    done.lock().unwrap().push((id, queue_ms));
                }
            });
        }
        for task in &trace.tasks {
            queue.push((task.id, Instant::now()));
            max_depth.fetch_max(queue.len(), Ordering::Relaxed);
        }
        queue.close();
    });

    // (1) Nothing dropped, nothing duplicated.
    let results = Arc::try_unwrap(done).unwrap().into_inner().unwrap();
    assert_eq!(results.len(), TASKS, "tasks lost under backpressure");
    let mut ids: Vec<usize> = results.iter().map(|&(id, _)| id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..TASKS).collect::<Vec<_>>(), "duplicate/missing ids");

    // (2) The bounded queue actually bounded (and actually filled up —
    // otherwise this test would not be exercising backpressure at all).
    let depth = max_depth.load(Ordering::Relaxed);
    assert!(depth <= CAPACITY, "queue depth {depth} exceeded capacity {CAPACITY}");
    assert!(depth >= CAPACITY / 2, "burst never pressured the queue (depth {depth})");

    // (3) Latency percentiles finite and ordered.
    let lats: Vec<f64> = results.iter().map(|&(_, l)| l).collect();
    let p50 = percentile(&lats, 50.0);
    let p95 = percentile(&lats, 95.0);
    let p99 = percentile(&lats, 99.0);
    assert!(p50.is_finite() && p95.is_finite() && p99.is_finite(), "{p50} {p95} {p99}");
    assert!(p50 <= p95 && p95 <= p99, "percentiles out of order: {p50} {p95} {p99}");
    assert!(lats.iter().all(|l| l.is_finite() && *l >= 0.0));
}

/// Closing an empty queue releases blocked consumers; closing a non-empty
/// queue still drains every item first.
#[test]
fn close_drains_remaining_items() {
    let q: TaskQueue<u32> = TaskQueue::new(16);
    for i in 0..5 {
        q.push(i);
    }
    q.close();
    let mut got = Vec::new();
    while let Some(x) = q.pop() {
        got.push(x);
    }
    assert_eq!(got, vec![0, 1, 2, 3, 4]);
}

// ---------------------------------------------------------------------------
// Diurnal trace: admission control under a daily load cycle
// ---------------------------------------------------------------------------

/// Arrivals per "hour" over one compressed day: quiet nights, a steep
/// daytime peak that exceeds steady-state service capacity.
const DIURNAL: [usize; 12] = [2, 2, 4, 6, 10, 12, 12, 10, 6, 4, 2, 2];

/// Deterministic single-threaded replay of a diurnal day against the
/// shed-oldest admission policy: each bucket offers its arrivals, then the
/// service side drains up to `capacity` tasks.  Returns (completed, shed).
fn replay_diurnal_shed(capacity: usize, days: usize) -> (usize, usize) {
    use fedattn::serve::{AdmissionController, AdmissionPolicy};
    let adm: AdmissionController<usize> =
        AdmissionController::new(AdmissionPolicy::ShedOldest, 12, 1);
    let mut completed = 0usize;
    let mut id = 0usize;
    for _ in 0..days {
        for &arrivals in &DIURNAL {
            for _ in 0..arrivals {
                assert!(adm.offer(id, id), "shed-oldest never refuses the new arrival");
                id += 1;
            }
            for _ in 0..capacity {
                if adm.take().is_some() {
                    completed += 1;
                }
            }
        }
    }
    // Off-hours drain: whatever survived the day still completes.
    while adm.take().is_some() {
        completed += 1;
    }
    let shed = adm.take_dropped().len();
    assert_eq!(completed + shed, id, "every offered task completes or is shed");
    (completed, shed)
}

/// Shrinking service capacity can only shed more: the offer/take sequence
/// is identical across runs, so queue occupancy — and therefore shedding —
/// is pointwise monotone in capacity.
#[test]
fn diurnal_shed_counts_monotone_in_service_capacity() {
    let sheds: Vec<usize> =
        [1usize, 2, 4, 6, 12].iter().map(|&c| replay_diurnal_shed(c, 2).1).collect();
    for w in sheds.windows(2) {
        assert!(w[0] >= w[1], "sheds must not grow with capacity: {sheds:?}");
    }
    assert!(sheds[0] > 0, "capacity 1 must shed under the diurnal peak: {sheds:?}");
    assert_eq!(sheds[4], 0, "capacity >= peak arrival rate sheds nothing: {sheds:?}");
}

/// Mock fabric session for the threaded diurnal run: two decode steps
/// after a timed prefill, no engine required.
struct DiurnalTask {
    id: usize,
    dispatched: usize,
    pending: bool,
}

impl fedattn::serve::FabricTask for DiurnalTask {
    fn task_id(&self) -> usize {
        self.id
    }

    fn prefill(&mut self) -> anyhow::Result<()> {
        std::thread::sleep(Duration::from_micros(300));
        Ok(())
    }

    fn poll(&mut self) -> fedattn::fedattn::DecodeStep {
        use fedattn::fedattn::DecodeStep;
        if self.dispatched >= 2 {
            DecodeStep::Done
        } else if self.pending {
            DecodeStep::NeedsDispatch
        } else {
            self.pending = true;
            DecodeStep::Ready { token: self.dispatched as i32 }
        }
    }

    fn dispatch(&mut self) -> anyhow::Result<()> {
        self.dispatched += 1;
        self.pending = false;
        Ok(())
    }

    fn decode_handle(&mut self) -> Option<&mut fedattn::fedattn::DecodeHandle> {
        None
    }

    fn into_result(self: Box<Self>) -> anyhow::Result<fedattn::coordinator::TaskResult> {
        Ok(fedattn::coordinator::TaskResult {
            task_id: self.id,
            answer: String::new(),
            gold: String::new(),
            em: false,
            queue_ms: 0.0,
            service_ms: 1.0,
            latency_ms: 1.0,
            comm_bytes: 0,
            comm_time_ms: 0.0,
            generated_tokens: 2,
            demotions: 0,
            rejoins: 0,
            retries: 0,
        })
    }
}

/// The full fabric under a compressed diurnal day with the blocking
/// policy: arrivals bunch at the peak, backpressure holds, and nothing is
/// ever lost — in-flight stays within `max_inflight` the whole time.
#[test]
fn diurnal_fabric_block_policy_bounds_inflight_and_loses_nothing() {
    use fedattn::serve::{run_fabric, AdmissionPolicy, FabricConfig, FabricTask};

    let mut tasks: Vec<(f64, Box<dyn FabricTask + 'static>)> = Vec::new();
    let mut t = 0.0f64;
    let mut id = 0usize;
    for &arrivals in &DIURNAL {
        // One "hour" per bucket; arrivals spread evenly inside it.
        for k in 0..arrivals {
            let at = t + 60_000.0 * (k as f64 / arrivals as f64);
            tasks.push((at, Box::new(DiurnalTask { id, dispatched: 0, pending: false }) as _));
            id += 1;
        }
        t += 60_000.0;
    }
    let total = tasks.len();

    let cfg = FabricConfig {
        engines: 2,
        queue_depth: 6,
        max_inflight: 3,
        admission: AdmissionPolicy::Block,
        batching: false,
        time_scale: 1e6, // compress the day to microseconds
        ..FabricConfig::default()
    };
    let out = run_fabric(None, &cfg, tasks).unwrap();
    assert_eq!(out.results.len(), total, "block policy lost tasks");
    assert!(out.failed.is_empty(), "unexpected failures: {:?}", out.failed);
    assert!(out.dropped.is_empty(), "block policy must never drop");
    assert!(
        out.peak_inflight <= 3,
        "peak in-flight {} exceeded max_inflight 3",
        out.peak_inflight
    );
    let mut ids: Vec<usize> = out.results.iter().map(|r| r.task_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total).collect::<Vec<_>>(), "duplicate/missing ids");
}
