//! Integration tests for the serving fabric (`fedattn::serve`).
//!
//! Engine-free half: mock `FabricTask`s drive `run_fabric` through the
//! public API and pin the admission accounting invariant — every offered
//! task ends up in exactly one of `results` / `failed` / `dropped`.
//!
//! Engine-gated half (skips with a notice when artifacts are absent):
//! the fabric serve path must produce byte-identical answers to the
//! legacy thread-per-task path across KV exchange policies.  Both paths
//! seed each task as `cfg.seed + task_id`, so any scheduling-dependent
//! divergence shows up as a differing answer string.  When the manifest
//! carries no batched decode variants the fabric runs singleton
//! fallback cohorts — the identity must hold there too, and the outcome
//! counters prove which path executed.

use std::path::PathBuf;

use anyhow::Result;
use fedattn::config::SystemConfig;
use fedattn::coordinator::{Coordinator, CoordinatorConfig, TaskResult};
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::fedattn::{DecodeHandle, DecodeStep, KvExchangePolicy};
use fedattn::runtime::Engine;
use fedattn::serve::{run_fabric, AdmissionPolicy, DropReason, FabricConfig, FabricTask};

// ---------------------------------------------------------------------------
// Engine-free: admission accounting over the public API
// ---------------------------------------------------------------------------

/// Minimal mock session: `steps` decode dispatches after a timed prefill.
struct MockTask {
    id: usize,
    steps: usize,
    dispatched: usize,
    pending: bool,
    prefill_us: u64,
}

impl FabricTask for MockTask {
    fn task_id(&self) -> usize {
        self.id
    }

    fn prefill(&mut self) -> Result<()> {
        std::thread::sleep(std::time::Duration::from_micros(self.prefill_us));
        Ok(())
    }

    fn poll(&mut self) -> DecodeStep {
        if self.dispatched >= self.steps {
            DecodeStep::Done
        } else if self.pending {
            DecodeStep::NeedsDispatch
        } else {
            self.pending = true;
            DecodeStep::Ready { token: self.dispatched as i32 }
        }
    }

    fn dispatch(&mut self) -> Result<()> {
        self.dispatched += 1;
        self.pending = false;
        Ok(())
    }

    fn decode_handle(&mut self) -> Option<&mut DecodeHandle> {
        None
    }

    fn into_result(self: Box<Self>) -> Result<TaskResult> {
        Ok(TaskResult {
            task_id: self.id,
            answer: format!("mock-{}", self.id),
            gold: String::new(),
            em: false,
            queue_ms: 0.0,
            service_ms: 1.0,
            latency_ms: 1.0,
            comm_bytes: 0,
            comm_time_ms: 0.0,
            generated_tokens: self.steps,
            demotions: 0,
            rejoins: 0,
            retries: 0,
        })
    }
}

fn mock_tasks(
    n: usize,
    gap_ms: f64,
    prefill_us: u64,
) -> Vec<(f64, Box<dyn FabricTask + 'static>)> {
    (0..n)
        .map(|i| {
            let t = MockTask { id: i, steps: 2, dispatched: 0, pending: false, prefill_us };
            (i as f64 * gap_ms, Box::new(t) as _)
        })
        .collect()
}

#[test]
fn reject_over_slo_accounts_every_offered_task() {
    // One engine, one in-flight slot, 4ms prefills against 2ms arrival
    // gaps: once the first completion seeds the service-time EMA, the
    // predicted wait for a backed-up queue exceeds the 0.5ms SLO and
    // later arrivals are rejected at the door.  (Arrivals must be spread
    // in real time — a simultaneous burst would all be admitted blind,
    // before the predictor has seen any completion.)
    let cfg = FabricConfig {
        engines: 1,
        queue_depth: 32,
        max_inflight: 1,
        admission: AdmissionPolicy::RejectOverSlo { slo_ms: 0.5 },
        batching: false,
        time_scale: 1.0,
        ..FabricConfig::default()
    };
    let n = 16;
    let out = run_fabric(None, &cfg, mock_tasks(n, 2.0, 4000)).unwrap();
    assert_eq!(
        out.results.len() + out.failed.len() + out.dropped.len(),
        n,
        "every offered task lands in exactly one bucket"
    );
    assert!(out.failed.is_empty(), "mock tasks never error: {:?}", out.failed);
    assert!(
        !out.dropped.is_empty(),
        "0.5ms SLO with 4ms prefills must reject some arrivals"
    );
    for d in &out.dropped {
        assert_eq!(d.reason, DropReason::Rejected, "SLO policy rejects, never sheds");
    }
    // No task appears twice across buckets.
    let mut seen: Vec<usize> = out
        .results
        .iter()
        .map(|r| r.task_id)
        .chain(out.dropped.iter().map(|d| d.task_id))
        .collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), n, "no duplicate task ids across buckets");
}

#[test]
fn block_policy_completes_everything_in_arrival_independent_set() {
    let cfg = FabricConfig {
        engines: 2,
        queue_depth: 4,
        max_inflight: 3,
        admission: AdmissionPolicy::Block,
        batching: false,
        time_scale: 1e6,
        ..FabricConfig::default()
    };
    let n = 20;
    let out = run_fabric(None, &cfg, mock_tasks(n, 0.01, 300)).unwrap();
    assert_eq!(out.results.len(), n);
    assert!(out.dropped.is_empty() && out.failed.is_empty());
    assert!(out.peak_inflight <= 3, "peak {} > max_inflight 3", out.peak_inflight);
    // Mock tasks expose no decode handle, so every step is a fallback step.
    assert_eq!(out.batched_steps, 0);
    assert_eq!(out.fallback_steps, (n * 2) as u64);
}

// ---------------------------------------------------------------------------
// Engine-gated: fabric vs thread-per-task differential
// ---------------------------------------------------------------------------

fn engine() -> Option<Engine> {
    let dir: PathBuf = fedattn::default_artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("weights.npz").exists() {
        eprintln!("SKIP: artifacts not found (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir, "weights.npz").unwrap())
}

fn base_cfg(kv_policy: KvExchangePolicy) -> CoordinatorConfig {
    let mut c = CoordinatorConfig::from_system(&SystemConfig::default());
    c.engines = 2;
    c.queue_depth = 8;
    c.participants = 3;
    c.kv_policy = kv_policy;
    c.max_new_tokens = 6;
    c.seed = 23;
    c.time_scale = 1e6; // compress trace think-time
    c
}

fn trace() -> WorkloadTrace {
    WorkloadTrace::generate(&TraceConfig {
        seed: 71,
        n_tasks: 6,
        mean_interarrival_ms: 20.0,
        min_facts: 3,
        max_facts: 4,
    })
}

/// Answers keyed by task id, so reordering across serve modes is benign.
fn answers(results: &[TaskResult]) -> Vec<(usize, String, bool)> {
    let mut v: Vec<_> =
        results.iter().map(|r| (r.task_id, r.answer.clone(), r.em)).collect();
    v.sort_by_key(|(id, _, _)| *id);
    v
}

#[test]
fn fabric_serve_matches_thread_per_task_across_kv_policies() {
    let Some(_) = engine() else { return };
    let policies = [
        ("full", KvExchangePolicy::Full),
        ("topk", KvExchangePolicy::TopKRelevance { budget_rows: 48 }),
    ];
    let tr = trace();
    for (name, policy) in policies {
        // Fresh engine per coordinator: Engine is consumed by
        // Coordinator::new, and sharing would serialize the comparison.
        let legacy = {
            let cfg = base_cfg(policy);
            Coordinator::new(engine().unwrap(), cfg).serve_trace(&tr).unwrap()
        };
        let fabric = {
            let mut cfg = base_cfg(policy);
            cfg.fabric = true;
            Coordinator::new(engine().unwrap(), cfg).serve_trace(&tr).unwrap()
        };
        assert!(legacy.failed.is_empty(), "[{name}] legacy failures: {:?}", legacy.failed);
        assert!(fabric.failed.is_empty(), "[{name}] fabric failures: {:?}", fabric.failed);
        assert!(fabric.dropped.is_empty(), "[{name}] block policy must not drop");
        assert_eq!(
            answers(&legacy.results),
            answers(&fabric.results),
            "[{name}] fabric must be byte-identical to thread-per-task"
        );
    }
}

#[test]
fn fabric_serve_is_deterministic_under_tight_inflight() {
    // max_inflight 1 forces fully serialized admission — scheduling order
    // changes but per-task seeds don't, so answers still match a wide run.
    let Some(_) = engine() else { return };
    let tr = trace();
    let wide = {
        let mut cfg = base_cfg(KvExchangePolicy::Full);
        cfg.fabric = true;
        Coordinator::new(engine().unwrap(), cfg).serve_trace(&tr).unwrap()
    };
    let tight = {
        let mut cfg = base_cfg(KvExchangePolicy::Full);
        cfg.fabric = true;
        cfg.max_inflight = Some(1);
        Coordinator::new(engine().unwrap(), cfg).serve_trace(&tr).unwrap()
    };
    assert_eq!(answers(&wide.results), answers(&tight.results));
    assert!(wide.failed.is_empty() && tight.failed.is_empty());
}
