//! Cross-language numerics: the Rust runtime executing the AOT artifacts
//! must reproduce the JAX reference outputs dumped by
//! `python -m compile.aot --fixtures` (random fixture weights, so these
//! tests are independent of training).
//!
//! Skipped (with a notice) when artifacts are absent — run `make artifacts`.

use std::collections::HashMap;
use std::path::PathBuf;

use fedattn::model::{Manifest, Weights};
use fedattn::runtime::Engine;
use fedattn::tensor::HostTensor;
use xla::FromRawBytes;

fn artifacts() -> Option<PathBuf> {
    let dir = fedattn::default_artifacts_dir();
    if dir.join("manifest.json").exists() && dir.join("fixtures.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/fixtures not found (run `make artifacts`)");
        None
    }
}

struct Fx {
    map: HashMap<String, xla::Literal>,
}

impl Fx {
    fn load(dir: &std::path::Path) -> Self {
        let pairs = xla::Literal::read_npz(dir.join("fixtures.npz"), &()).unwrap();
        Self { map: pairs.into_iter().collect() }
    }

    fn tensor(&self, name: &str) -> HostTensor {
        HostTensor::from_literal(self.map.get(name).unwrap_or_else(|| panic!("fixture {name}")))
            .unwrap()
    }

    fn i32s(&self, name: &str) -> Vec<i32> {
        self.map.get(name).unwrap().to_vec::<i32>().unwrap()
    }
}

fn fixture_engine(dir: &std::path::Path) -> Engine {
    let manifest = Manifest::load(dir).unwrap();
    let weights = Weights::load(&dir.join("fixture_weights.npz")).unwrap();
    Engine::new(manifest, weights).unwrap()
}

fn assert_close(got: &HostTensor, want: &HostTensor, tol: f32, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    let d = got.max_abs_diff(want);
    assert!(d < tol, "{what}: max abs diff {d} >= {tol}");
}

#[test]
fn block_fused_matches_jax() {
    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    let engine = fixture_engine(&dir);
    let x = fx.tensor("bf.x");
    let pos = fx.i32s("bf.pos");
    let mask = fx.tensor("bf.mask");
    let (xo, k, v) = engine.block_fused(0, &x, &pos, &mask).unwrap();
    assert_close(&xo, &fx.tensor("bf.x_out"), 1e-4, "block_fused x_out");
    assert_close(&k, &fx.tensor("bf.k"), 1e-4, "block_fused k");
    assert_close(&v, &fx.tensor("bf.v"), 1e-4, "block_fused v");
}

#[test]
fn qkv_and_attn_ffn_match_jax() {
    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    let engine = fixture_engine(&dir);
    let x = fx.tensor("bf.x");
    let pos = fx.i32s("bf.pos");
    let (q, k, v) = engine.qkv_project(0, &x, &pos).unwrap();
    assert_close(&q, &fx.tensor("af.q"), 1e-4, "qkv q");
    assert_close(&k, &fx.tensor("qkv.k"), 1e-4, "qkv k");
    assert_close(&v, &fx.tensor("qkv.v"), 1e-4, "qkv v");

    let xo = engine
        .attn_ffn(0, &x, &q, &fx.tensor("af.kg"), &fx.tensor("af.vg"), &fx.tensor("af.mask"))
        .unwrap();
    assert_close(&xo, &fx.tensor("af.x_out"), 1e-4, "attn_ffn x_out");
}

#[test]
fn decode_block_matches_jax() {
    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    let engine = fixture_engine(&dir);
    let x = fx.tensor("dec.x");
    let pos = fx.i32s("dec.pos")[0];
    let (xo, kn, vn) = engine
        .decode_block(0, &x, pos, &fx.tensor("dec.kc"), &fx.tensor("dec.vc"), &fx.tensor("dec.mask"))
        .unwrap();
    assert_close(&xo, &fx.tensor("dec.x_out"), 1e-4, "decode x_out");
    assert_close(&kn, &fx.tensor("dec.k_new"), 1e-4, "decode k_new");
    assert_close(&vn, &fx.tensor("dec.v_new"), 1e-4, "decode v_new");
}

#[test]
fn decode_block_tail_matches_jax() {
    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    if !fx.map.contains_key("dt.x_out") {
        eprintln!("SKIP: decode-tail fixtures absent (re-run `make artifacts`)");
        return;
    }
    let engine = fixture_engine(&dir);
    // The frozen half rides as device handles (uploaded once).
    let kc = engine.upload(&fx.tensor("dec.kc")).unwrap();
    let vc = engine.upload(&fx.tensor("dec.vc")).unwrap();
    let mc = engine.upload(&fx.tensor("dec.mask")).unwrap();
    let x = fx.tensor("dec.x");
    let pos = fx.i32s("dec.pos")[0];
    let (xo, kn, vn) = engine
        .decode_block_tail(
            0,
            &x,
            pos,
            &kc,
            &vc,
            &mc,
            &fx.tensor("dt.k_tail"),
            &fx.tensor("dt.v_tail"),
            &fx.tensor("dt.mask_tail"),
        )
        .unwrap();
    assert_close(&xo, &fx.tensor("dt.x_out"), 1e-4, "decode_tail x_out");
    assert_close(&kn, &fx.tensor("dt.k_new"), 1e-4, "decode_tail k_new");
    assert_close(&vn, &fx.tensor("dt.v_new"), 1e-4, "decode_tail v_new");
}

#[test]
fn full_fedattn_prefill_matches_python_reference() {
    // The big one: the Rust coordinator (schedules, masks, packing,
    // positions) must reproduce the pure-JAX FedAttn simulator on the same
    // weights — uniform H=2, 3 participants, matching fixture `fed.*`.
    use fedattn::data::Partition;
    use fedattn::fedattn::{FedSession, SessionConfig, SyncSchedule};
    use fedattn::net::{LinkSpec, NetSim, Topology};

    let Some(dir) = artifacts() else { return };
    let fx = Fx::load(&dir);
    let engine = fixture_engine(&dir);
    let md = engine.manifest.model.clone();

    let ids = fx.i32s("fed.ids");
    let owners = fx.i32s("fed.owners");
    let n = (*owners.iter().max().unwrap() + 1) as usize;
    // owners are contiguous spans by construction.
    let mut spans = Vec::new();
    let mut start = 0usize;
    for p in 0..n as i32 {
        let end = owners.iter().rposition(|&o| o == p).unwrap() + 1;
        spans.push((start, end));
        start = end;
    }
    let part = Partition { ids, spans };

    let h = fx.i32s("fed.h")[0] as usize;
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, h));
    cfg.record_hidden = true;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 3);
    let out = FedSession::new(&engine, &part, cfg, net)
        .unwrap()
        .run_prefill_only()
        .unwrap();

    let want = fx.tensor("fed.x_final");
    let mut max_diff = 0f32;
    for (p, h_opt) in out.hidden.iter().enumerate() {
        let h = h_opt.as_ref().unwrap();
        for (i, &gpos) in out.positions[p].iter().enumerate() {
            for (a, b) in h.row(i).iter().zip(want.row(gpos as usize)) {
                max_diff = max_diff.max((a - b).abs());
            }
        }
    }
    assert!(max_diff < 2e-4, "fedattn vs python reference: max diff {max_diff}");
}
