//! Fig. 6 — trade-off between response quality and computational cost.
//!
//! Sweeps the number of participants N (N = 1 is CenAttn): per-participant
//! prefill FLOPs and peak memory fall roughly quadratically (the sequence
//! dimension is sharded) while EM degrades — the paper's computational-
//! efficiency result.
//!
//!     cargo bench --bench fig6_quality_vs_compute

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::{partition, Segmentation};
use fedattn::fedattn::SyncSchedule;
use fedattn::util::json::Json;
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let cm = cost_model(&engine);
    let h = 2usize;
    let mut rows = Vec::new();

    println!("== Fig. 6: EM vs per-participant compute across N (H = {h}) ==");
    for seg in [Segmentation::TokQAg, Segmentation::SemQEx] {
        println!("\n-- segmentation {} --", seg.as_str());
        println!(
            "{:>4} {:>8} {:>8} {:>14} {:>12} {:>10}",
            "N", "EM pub", "EM mean", "prefill FLOPs", "peak mem", "wall ms"
        );
        for &n in &[1usize, 2, 4, 6] {
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
            cfg.n_facts = 5;
            let r = match run_point(&engine, &cfg) {
                Ok(r) => r,
                Err(e) => {
                    println!("{n:>4} skipped: {e}");
                    continue;
                }
            };
            // Analytic per-participant cost at the mean shard size.
            let eps = fixed_episodes(cfg.seed, 1, cfg.n_facts);
            let part = partition(&eps[0], n, seg);
            let l = part.max_span_len();
            let g = part.len();
            let rounds = m / h;
            let cost = cm.prefill_cost(l, g, m - rounds, rounds);
            println!(
                "{:>4} {:>8.3} {:>8.3} {:>14.3e} {:>12} {:>10.1}",
                n,
                r.em_publisher,
                r.em_mean,
                cost.flops,
                fmt_bytes(cost.peak_mem_bytes),
                r.prefill_ms + r.decode_ms
            );
            let mut j = point_json(&format!("{}:N{}", seg.as_str(), n), n as f64, &r);
            if let fedattn::util::json::Json::Obj(map) = &mut j {
                map.insert("prefill_flops".into(), Json::Num(cost.flops));
                map.insert("peak_mem_bytes".into(), Json::Num(cost.peak_mem_bytes));
            }
            rows.push(j);
        }
    }
    write_json("fig6_quality_vs_compute", Json::Arr(rows));
    Ok(())
}
