//! Fig. 9 — response quality under sparse *local* attention.
//!
//! Participants randomly drop input tokens before inference.  Information
//! loss is irreversible, so EM decays monotonically with the drop rate —
//! in contrast to sparse KV exchange (Fig. 10).
//!
//!     cargo bench --bench fig9_sparse_local

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::SyncSchedule;
use fedattn::util::json::Json;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    let h = 2usize;
    let ratios = [1.0f64, 0.9, 0.75, 0.5, 0.25];
    let mut rows = Vec::new();

    println!("== Fig. 9: sparse local attention (uniform H = {h}, N = {n}) ==");
    for seg in [Segmentation::SemQAg, Segmentation::SemQEx, Segmentation::TokQEx] {
        println!("\n-- segmentation {} --", seg.as_str());
        println!("{:>8} {:>10} {:>10}", "keep", "EM (pub)", "EM mean");
        for &ratio in &ratios {
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
            cfg.local_ratio = ratio;
            let r = run_point(&engine, &cfg)?;
            println!("{:>8.2} {:>10.3} {:>10.3}", ratio, r.em_publisher, r.em_mean);
            rows.push(point_json(&format!("{}:r{}", seg.as_str(), ratio), ratio, &r));
        }
    }
    write_json("fig9_sparse_local", Json::Arr(rows));
    Ok(())
}
