//! §II comparison — communication cost of FedAttn vs pipeline / tensor
//! parallelism, analytic per-inference bytes (the paper's motivating
//! table), across sequence lengths and participant counts — plus the
//! full-frame vs delta-frame downlink comparison across sync intervals
//! (written to `BENCH_comm_delta.json` at the repo root) and the
//! quantized-wire quality-vs-bytes sweep (`kv_precision`; written to
//! `BENCH_comm_quant.json`).
//!
//!     cargo bench --bench comm_baselines

mod common;

use anyhow::Result;
use common::*;
use fedattn::baselines::{CommCost, ParallelismKind};
use fedattn::data::Segmentation;
use fedattn::fedattn::{KvPrecision, SyncSchedule};
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let cc = CommCost::default();
    let mut rows = Vec::new();

    println!("== Comm cost per prefill: FedAttn vs model parallelism ==");
    println!("(architecture: {} — {} layers, d {}, kv_dim {})",
        md.name, md.n_layers, md.d_model, md.kv_dim());
    println!(
        "\n{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>10}",
        "L", "N", "H", "pipeline", "tensor", "fedattn", "TP/FA"
    );
    for &l in &[256usize, 1024, 4096] {
        for &n in &[2usize, 4, 8] {
            for &h in &[2usize, 4] {
                let pp = cc.prefill_bytes(ParallelismKind::Pipeline, &md, l, n, h);
                let tp = cc.prefill_bytes(ParallelismKind::Tensor, &md, l, n, h);
                let fa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, l, n, h);
                println!(
                    "{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>9.1}x",
                    l,
                    n,
                    h,
                    fmt_bytes(pp),
                    fmt_bytes(tp),
                    fmt_bytes(fa),
                    tp / fa
                );
                rows.push(
                    JsonBuilder::new()
                        .num("l", l as f64)
                        .num("n", n as f64)
                        .num("h", h as f64)
                        .num("pipeline", pp)
                        .num("tensor", tp)
                        .num("fedattn", fa)
                        .build(),
                );
            }
        }
    }
    println!(
        "\nGQA sensitivity: kv_dim {} of q_dim {} -> FedAttn payload shrinks {}x vs MHA",
        md.kv_dim(),
        md.q_dim(),
        md.q_dim() / md.kv_dim()
    );
    write_json("comm_baselines", Json::Arr(rows));

    // ------------------------------------------------------------------
    // Full-frame vs delta-frame downlink across sync intervals.
    //
    // Analytic, like the table above: per attendee per sync round, a full
    // broadcast re-ships every packed row (`L x row_bytes`) while a delta
    // frame ships only the transmitted rows of *other* participants
    // (`ratio x (L - L/N) x row_bytes` — own rows ride as a retain-list,
    // untransmitted remote rows are elided).  Sync interval H sets how
    // many such rounds one prefill executes (n_layers / H).  The same
    // numbers are measured end-to-end by `NetReport.round_rx_bytes` in
    // the delta differential tests; this sweep writes the trajectory
    // series to BENCH_comm_delta.json at the repo root.
    // ------------------------------------------------------------------
    let row_bytes = (2 * md.n_kv_heads * md.head_dim * 4) as f64;
    let n = 4usize; // participants
    let l = 256usize; // total packed rows per round
    let own = l / n;
    println!("\n== Downlink per attendee: full frames vs delta frames (N = {n}, L = {l}) ==");
    println!(
        "{:>4} {:>7} {:>6} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "H", "rounds", "ratio", "full/round", "delta/round", "full total", "delta total", "saved"
    );
    let mut delta_points = Vec::new();
    for &h in &[1usize, 2, 4, 8] {
        let rounds = (md.n_layers / h).max(1);
        for &ratio in &[1.0f64, 0.5] {
            let full_round = l as f64 * row_bytes;
            let delta_round = ratio * (l - own) as f64 * row_bytes;
            let full_total = full_round * rounds as f64;
            let delta_total = delta_round * rounds as f64;
            let savings = 1.0 - delta_total / full_total;
            println!(
                "{:>4} {:>7} {:>6.2} {:>12} {:>12} {:>12} {:>12} {:>7.1}%",
                h,
                rounds,
                ratio,
                fmt_bytes(full_round),
                fmt_bytes(delta_round),
                fmt_bytes(full_total),
                fmt_bytes(delta_total),
                savings * 100.0
            );
            delta_points.push(
                JsonBuilder::new()
                    .num("h", h as f64)
                    .num("rounds", rounds as f64)
                    .num("ratio", ratio)
                    .num("full_bytes_per_round", full_round)
                    .num("delta_bytes_per_round", delta_round)
                    .num("full_total_bytes", full_total)
                    .num("delta_total_bytes", delta_total)
                    .num("savings", savings)
                    .build(),
            );
        }
    }
    let report = JsonBuilder::new()
        .str("bench", "comm_delta")
        .num("row_bytes", row_bytes)
        .num("participants", n as f64)
        .num("l", l as f64)
        .num("n_layers", md.n_layers as f64)
        .set("points", Json::Arr(delta_points))
        .build();
    write_bench_json("comm_delta", report);

    // ------------------------------------------------------------------
    // Quantized wire rows (`kv_precision`): quality vs bytes.
    //
    // Measured end-to-end first — EM across precisions at the golden
    // H = 2 schedule, so the quality side of the trade-off is a real
    // decode, not an estimate.  Then the analytic uplink sweep across
    // precision × transmit ratio × participants (same shape as the delta
    // table above: per round every participant ships `ratio × own` rows,
    // so a round's uplink is `ratio × L × row_bytes(precision)`), written
    // to BENCH_comm_quant.json at the repo root.  `ByteBudget` is
    // deliberately absent: its row budget divides by the precision-aware
    // row size, so shrinking rows adds rows back and bytes stop being
    // comparable across precisions.
    // ------------------------------------------------------------------
    const PRECISIONS: [KvPrecision; 3] =
        [KvPrecision::F32, KvPrecision::F16, KvPrecision::Int8];
    println!("\n== Quantized KV wire rows: EM vs precision (N = 4, H = 2, full policy) ==");
    println!(
        "{:>6} {:>10} {:>8} {:>8} {:>14}",
        "prec", "row bytes", "EM pub", "EM mean", "tx/participant"
    );
    for precision in PRECISIONS {
        let mut cfg = PointCfg::new(
            4,
            Segmentation::SemQEx,
            SyncSchedule::uniform(md.n_layers, 4, 2),
        );
        cfg.kv_precision = precision;
        cfg.decode_all = true;
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>6} {:>10} {:>8.3} {:>8.3} {:>14}",
            precision.as_str(),
            precision.wire_row_bytes(md.n_kv_heads, md.head_dim),
            r.em_publisher,
            r.em_mean,
            fmt_bytes(r.avg_tx_bytes)
        );
    }

    println!("\n== Uplink per round: precision x ratio x participants (L = {l}) ==");
    println!(
        "{:>6} {:>6} {:>4} {:>12} {:>14} {:>12} {:>8}",
        "prec", "ratio", "N", "round total", "per participant", "sweep total", "vs f32"
    );
    let f32_row = KvPrecision::F32.wire_row_bytes(md.n_kv_heads, md.head_dim) as f64;
    let h = 2usize;
    let rounds = (md.n_layers / h).max(1);
    let mut quant_points = Vec::new();
    for &np in &[2usize, 4, 8] {
        for &ratio in &[1.0f64, 0.5] {
            for precision in PRECISIONS {
                let rb = precision.wire_row_bytes(md.n_kv_heads, md.head_dim) as f64;
                let per_round = ratio * l as f64 * rb;
                let per_participant = ratio * (l / np) as f64 * rb;
                let total = per_round * rounds as f64;
                let reduction = f32_row / rb;
                println!(
                    "{:>6} {:>6.2} {:>4} {:>12} {:>14} {:>12} {:>7.2}x",
                    precision.as_str(),
                    ratio,
                    np,
                    fmt_bytes(per_round),
                    fmt_bytes(per_participant),
                    fmt_bytes(total),
                    reduction
                );
                quant_points.push(
                    JsonBuilder::new()
                        .str("precision", precision.as_str())
                        .num("ratio", ratio)
                        .num("n", np as f64)
                        .num("row_bytes", rb)
                        .num("uplink_bytes_per_round", per_round)
                        .num("bytes_per_participant_per_round", per_participant)
                        .num("total_bytes", total)
                        .num("reduction_vs_f32", reduction)
                        .build(),
                );
            }
        }
    }
    let quant_report = JsonBuilder::new()
        .str("bench", "comm_quant")
        .num("l", l as f64)
        .num("kv_heads", md.n_kv_heads as f64)
        .num("head_dim", md.head_dim as f64)
        .num("h", h as f64)
        .num("rounds", rounds as f64)
        .num("n_layers", md.n_layers as f64)
        .set("points", Json::Arr(quant_points))
        .build();
    write_bench_json("comm_quant", quant_report);
    Ok(())
}
