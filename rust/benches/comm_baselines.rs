//! §II comparison — communication cost of FedAttn vs pipeline / tensor
//! parallelism, analytic per-inference bytes (the paper's motivating
//! table), across sequence lengths and participant counts.
//!
//!     cargo bench --bench comm_baselines

mod common;

use anyhow::Result;
use common::*;
use fedattn::baselines::{CommCost, ParallelismKind};
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let cc = CommCost::default();
    let mut rows = Vec::new();

    println!("== Comm cost per prefill: FedAttn vs model parallelism ==");
    println!("(architecture: {} — {} layers, d {}, kv_dim {})",
        md.name, md.n_layers, md.d_model, md.kv_dim());
    println!(
        "\n{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>10}",
        "L", "N", "H", "pipeline", "tensor", "fedattn", "TP/FA"
    );
    for &l in &[256usize, 1024, 4096] {
        for &n in &[2usize, 4, 8] {
            for &h in &[2usize, 4] {
                let pp = cc.prefill_bytes(ParallelismKind::Pipeline, &md, l, n, h);
                let tp = cc.prefill_bytes(ParallelismKind::Tensor, &md, l, n, h);
                let fa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, l, n, h);
                println!(
                    "{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>9.1}x",
                    l,
                    n,
                    h,
                    fmt_bytes(pp),
                    fmt_bytes(tp),
                    fmt_bytes(fa),
                    tp / fa
                );
                rows.push(
                    JsonBuilder::new()
                        .num("l", l as f64)
                        .num("n", n as f64)
                        .num("h", h as f64)
                        .num("pipeline", pp)
                        .num("tensor", tp)
                        .num("fedattn", fa)
                        .build(),
                );
            }
        }
    }
    println!(
        "\nGQA sensitivity: kv_dim {} of q_dim {} -> FedAttn payload shrinks {}x vs MHA",
        md.kv_dim(),
        md.q_dim(),
        md.q_dim() / md.kv_dim()
    );
    write_json("comm_baselines", Json::Arr(rows));
    Ok(())
}
