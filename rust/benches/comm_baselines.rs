//! §II comparison — communication cost of FedAttn vs pipeline / tensor
//! parallelism, analytic per-inference bytes (the paper's motivating
//! table), across sequence lengths and participant counts — plus the
//! full-frame vs delta-frame downlink comparison across sync intervals
//! (written to `BENCH_comm_delta.json` at the repo root).
//!
//!     cargo bench --bench comm_baselines

mod common;

use anyhow::Result;
use common::*;
use fedattn::baselines::{CommCost, ParallelismKind};
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let cc = CommCost::default();
    let mut rows = Vec::new();

    println!("== Comm cost per prefill: FedAttn vs model parallelism ==");
    println!("(architecture: {} — {} layers, d {}, kv_dim {})",
        md.name, md.n_layers, md.d_model, md.kv_dim());
    println!(
        "\n{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>10}",
        "L", "N", "H", "pipeline", "tensor", "fedattn", "TP/FA"
    );
    for &l in &[256usize, 1024, 4096] {
        for &n in &[2usize, 4, 8] {
            for &h in &[2usize, 4] {
                let pp = cc.prefill_bytes(ParallelismKind::Pipeline, &md, l, n, h);
                let tp = cc.prefill_bytes(ParallelismKind::Tensor, &md, l, n, h);
                let fa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, l, n, h);
                println!(
                    "{:>6} {:>4} {:>4} {:>12} {:>12} {:>12} {:>9.1}x",
                    l,
                    n,
                    h,
                    fmt_bytes(pp),
                    fmt_bytes(tp),
                    fmt_bytes(fa),
                    tp / fa
                );
                rows.push(
                    JsonBuilder::new()
                        .num("l", l as f64)
                        .num("n", n as f64)
                        .num("h", h as f64)
                        .num("pipeline", pp)
                        .num("tensor", tp)
                        .num("fedattn", fa)
                        .build(),
                );
            }
        }
    }
    println!(
        "\nGQA sensitivity: kv_dim {} of q_dim {} -> FedAttn payload shrinks {}x vs MHA",
        md.kv_dim(),
        md.q_dim(),
        md.q_dim() / md.kv_dim()
    );
    write_json("comm_baselines", Json::Arr(rows));

    // ------------------------------------------------------------------
    // Full-frame vs delta-frame downlink across sync intervals.
    //
    // Analytic, like the table above: per attendee per sync round, a full
    // broadcast re-ships every packed row (`L x row_bytes`) while a delta
    // frame ships only the transmitted rows of *other* participants
    // (`ratio x (L - L/N) x row_bytes` — own rows ride as a retain-list,
    // untransmitted remote rows are elided).  Sync interval H sets how
    // many such rounds one prefill executes (n_layers / H).  The same
    // numbers are measured end-to-end by `NetReport.round_rx_bytes` in
    // the delta differential tests; this sweep writes the trajectory
    // series to BENCH_comm_delta.json at the repo root.
    // ------------------------------------------------------------------
    let row_bytes = (2 * md.n_kv_heads * md.head_dim * 4) as f64;
    let n = 4usize; // participants
    let l = 256usize; // total packed rows per round
    let own = l / n;
    println!("\n== Downlink per attendee: full frames vs delta frames (N = {n}, L = {l}) ==");
    println!(
        "{:>4} {:>7} {:>6} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "H", "rounds", "ratio", "full/round", "delta/round", "full total", "delta total", "saved"
    );
    let mut delta_points = Vec::new();
    for &h in &[1usize, 2, 4, 8] {
        let rounds = (md.n_layers / h).max(1);
        for &ratio in &[1.0f64, 0.5] {
            let full_round = l as f64 * row_bytes;
            let delta_round = ratio * (l - own) as f64 * row_bytes;
            let full_total = full_round * rounds as f64;
            let delta_total = delta_round * rounds as f64;
            let savings = 1.0 - delta_total / full_total;
            println!(
                "{:>4} {:>7} {:>6.2} {:>12} {:>12} {:>12} {:>12} {:>7.1}%",
                h,
                rounds,
                ratio,
                fmt_bytes(full_round),
                fmt_bytes(delta_round),
                fmt_bytes(full_total),
                fmt_bytes(delta_total),
                savings * 100.0
            );
            delta_points.push(
                JsonBuilder::new()
                    .num("h", h as f64)
                    .num("rounds", rounds as f64)
                    .num("ratio", ratio)
                    .num("full_bytes_per_round", full_round)
                    .num("delta_bytes_per_round", delta_round)
                    .num("full_total_bytes", full_total)
                    .num("delta_total_bytes", delta_total)
                    .num("savings", savings)
                    .build(),
            );
        }
    }
    let report = JsonBuilder::new()
        .str("bench", "comm_delta")
        .num("row_bytes", row_bytes)
        .num("participants", n as f64)
        .num("l", l as f64)
        .num("n_layers", md.n_layers as f64)
        .set("points", Json::Arr(delta_points))
        .build();
    write_bench_json("comm_delta", report);
    Ok(())
}
