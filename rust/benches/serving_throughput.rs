//! Serving-layer bench: throughput / latency of the coordinator under
//! Poisson load (the deployment-facing counterpart of the paper's
//! efficiency claims; no direct paper figure — see DESIGN.md §4).
//!
//! Sweeps the two parallelism knobs — `engines` (concurrent sessions) and
//! `workers` (per-session participant parallelism) — and reports the
//! device-resident-execution counters (activation bytes uploaded, bytes
//! saved by shared device handles) alongside tokens/s.  A machine-readable
//! trajectory report lands at the repo root (`BENCH_serving.json`).
//!
//!     cargo bench --bench serving_throughput

mod common;

use anyhow::Result;
use common::*;
use fedattn::config::SystemConfig;
use fedattn::coordinator::{Coordinator, CoordinatorConfig};
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::util::json::{Json, JsonBuilder};

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let mut rows = Vec::new();

    println!("== Serving throughput/latency under load ==");
    println!(
        "{:>8} {:>8} {:>12} {:>10} {:>10} {:>10} {:>10} {:>8} {:>12} {:>12}",
        "engines", "workers", "arrival ms", "thru t/s", "tok/s", "p50 ms", "p95 ms", "EM",
        "up MB", "saved MB"
    );
    for &engines in &[1usize, 2] {
        for &workers in &[1usize, 2] {
            for &inter_ms in &[800.0f64, 300.0] {
                let mut sc = SystemConfig::default();
                sc.federation.participants = 3;
                sc.serving.engines = engines;
                sc.serving.workers = workers;
                let mut ccfg = CoordinatorConfig::from_system(&sc);
                ccfg.time_scale = 4.0;
                let coord = Coordinator::new(engine.clone(), ccfg);
                let trace = WorkloadTrace::generate(&TraceConfig {
                    seed: 99,
                    n_tasks: 20,
                    mean_interarrival_ms: inter_ms,
                    ..Default::default()
                });
                let before = engine.stats.view();
                let rep = coord.serve_trace(&trace)?;
                let after = engine.stats.view();
                let up_bytes = after.bytes_uploaded - before.bytes_uploaded;
                let saved_bytes = after.upload_bytes_saved - before.upload_bytes_saved;
                let tokens: usize =
                    rep.results.iter().map(|r| r.generated_tokens).sum();
                let tokens_per_s = tokens as f64 / (rep.makespan_ms / 1e3).max(1e-9);
                println!(
                    "{:>8} {:>8} {:>12.0} {:>10.2} {:>10.2} {:>10.1} {:>10.1} {:>8.2} {:>12.2} {:>12.2}",
                    engines,
                    workers,
                    inter_ms,
                    rep.throughput_tasks_per_s(),
                    tokens_per_s,
                    rep.latency_percentile(50.0),
                    rep.latency_percentile(95.0),
                    rep.em_rate(),
                    up_bytes as f64 / 1e6,
                    saved_bytes as f64 / 1e6,
                );
                rows.push(
                    JsonBuilder::new()
                        .num("engines", engines as f64)
                        .num("workers", workers as f64)
                        .num("interarrival_ms", inter_ms)
                        .num("throughput", rep.throughput_tasks_per_s())
                        .num("tokens_per_s", tokens_per_s)
                        .num("p50_ms", rep.latency_percentile(50.0))
                        .num("p95_ms", rep.latency_percentile(95.0))
                        .num("em", rep.em_rate())
                        .num("bytes_uploaded", up_bytes as f64)
                        .num("upload_bytes_saved", saved_bytes as f64)
                        .build(),
                );
            }
        }
    }
    let stats = engine.stats.view();
    let report = JsonBuilder::new()
        .set("points", Json::Arr(rows.clone()))
        .num("total_bytes_uploaded", stats.bytes_uploaded as f64)
        .num("total_upload_bytes_saved", stats.upload_bytes_saved as f64)
        .num("weight_bytes_uploaded", stats.weight_bytes_uploaded as f64)
        .num("executions", stats.executions as f64)
        .build();
    write_json("serving_throughput", Json::Arr(rows));
    write_bench_json("serving", report);
    Ok(())
}
