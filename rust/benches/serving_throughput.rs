//! Serving-layer bench: throughput / latency of the coordinator under
//! Poisson load (the deployment-facing counterpart of the paper's
//! efficiency claims; no direct paper figure — see DESIGN.md §4).
//!
//! Three sections:
//! 1. the historical `engines` × `workers` sweep over the thread-per-task
//!    loop (device-resident-execution counters alongside tokens/s),
//! 2. a measured discipline comparison — the same trace served by the
//!    thread-per-task loop and by the session fabric (batched decode when
//!    the manifest carries `decode_tail_B*` variants, singleton fallback
//!    otherwise),
//! 3. the deterministic 3-way capacity curve from [`fedattn::serve`]'s
//!    analytic model (`thread-per-task` vs `fabric` vs `fabric-batched`).
//!
//! Sections 1–2 need artifacts and land in `bench_out/`.  Section 3 is
//! engine-free and byte-reproducible; it is what `BENCH_serving.json` at
//! the repo root carries, so CI can assert the curve shape on every push.
//!
//!     cargo bench --bench serving_throughput

mod common;

use anyhow::Result;
use common::*;
use fedattn::config::SystemConfig;
use fedattn::coordinator::{Coordinator, CoordinatorConfig};
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::serve::model::{SLO_DEADLINES_MS, SLO_GAPS_MS, SLO_SESSIONS};
use fedattn::serve::{capacity_curve, slo_curve, ModelParams, ServeMode};
use fedattn::util::json::{Json, JsonBuilder};

/// The session sweep pinned into `BENCH_serving.json`.
const CURVE_SWEEP: [usize; 4] = [4, 8, 16, 32];

/// Build the deterministic curve report.  Everything in here must stay
/// engine-free and host-independent: the committed JSON is regenerated
/// bit-for-bit by this bench and checked by CI.
fn curve_report() -> Json {
    let p = ModelParams::default();
    let curve = capacity_curve(&p, &CURVE_SWEEP);
    let rows: Vec<Json> = curve
        .iter()
        .map(|pt| {
            JsonBuilder::new()
                .num("sessions", pt.sessions as f64)
                .str("mode", pt.mode.name())
                .num("tokens_per_s", pt.tokens_per_s)
                .num("p50_ms", pt.p50_ms)
                .num("p95_ms", pt.p95_ms)
                .num("makespan_ms", pt.makespan_ms)
                .build()
        })
        .collect();
    JsonBuilder::new()
        .str("bench", "serving")
        .set(
            "modes",
            Json::Arr(ServeMode::ALL.iter().map(|m| Json::Str(m.name().into())).collect()),
        )
        .set(
            "params",
            JsonBuilder::new()
                .num("engines", p.engines as f64)
                .num("prefill_ms", p.prefill_ms)
                .num("step_ms", p.step_ms)
                .num("step_overhead_ms", p.step_overhead_ms)
                .num("handoff_ms", p.handoff_ms)
                .num("decode_steps", p.decode_steps as f64)
                .num("batch_max", p.batch_max as f64)
                .num("arrival_gap_ms", p.arrival_gap_ms)
                .build(),
        )
        .arr_num("sweep", &CURVE_SWEEP.map(|s| s as f64))
        .set("curve", Json::Arr(rows))
        .build()
}

/// Build the deterministic SLO-enforcement report (`BENCH_slo.json`):
/// the fabric discipline pushed through the deadline-enforcing DES over
/// the deadline × arrival-gap grid.  CI asserts on the committed copy
/// that every offered session is accounted (completed + killed) and
/// that the completion rate is monotone non-increasing in arrival rate
/// at each fixed deadline.
fn slo_report() -> Json {
    let p = ModelParams::default();
    let curve =
        slo_curve(&p, ServeMode::Fabric, SLO_SESSIONS, &SLO_DEADLINES_MS, &SLO_GAPS_MS);
    let rows: Vec<Json> = curve
        .iter()
        .map(|pt| {
            JsonBuilder::new()
                .num("deadline_ms", pt.deadline_ms)
                .num("arrival_gap_ms", pt.arrival_gap_ms)
                .num("sessions", pt.sessions as f64)
                .num("completed", pt.completed as f64)
                .num("killed", pt.killed as f64)
                .num("completion_rate", pt.completion_rate)
                .num("goodput_tokens_per_s", pt.goodput_tokens_per_s)
                .num("p95_ms", pt.p95_ms)
                .num("makespan_ms", pt.makespan_ms)
                .build()
        })
        .collect();
    JsonBuilder::new()
        .str("bench", "slo")
        .str("mode", ServeMode::Fabric.name())
        .num("sessions", SLO_SESSIONS as f64)
        .arr_num("deadlines_ms", &SLO_DEADLINES_MS)
        .arr_num("gaps_ms", &SLO_GAPS_MS)
        .set(
            "params",
            JsonBuilder::new()
                .num("engines", p.engines as f64)
                .num("prefill_ms", p.prefill_ms)
                .num("step_ms", p.step_ms)
                .num("step_overhead_ms", p.step_overhead_ms)
                .num("handoff_ms", p.handoff_ms)
                .num("decode_steps", p.decode_steps as f64)
                .num("batch_max", p.batch_max as f64)
                .build(),
        )
        .set("curve", Json::Arr(rows))
        .build()
}

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let mut rows = Vec::new();

    println!("== Serving throughput/latency under load ==");
    println!(
        "{:>8} {:>8} {:>12} {:>10} {:>10} {:>10} {:>10} {:>8} {:>12} {:>12}",
        "engines", "workers", "arrival ms", "thru t/s", "tok/s", "p50 ms", "p95 ms", "EM",
        "up MB", "saved MB"
    );
    for &engines in &[1usize, 2] {
        for &workers in &[1usize, 2] {
            for &inter_ms in &[800.0f64, 300.0] {
                let mut sc = SystemConfig::default();
                sc.federation.participants = 3;
                sc.serving.engines = engines;
                sc.serving.workers = workers;
                let mut ccfg = CoordinatorConfig::from_system(&sc);
                ccfg.time_scale = 4.0;
                let coord = Coordinator::new(engine.clone(), ccfg);
                let trace = WorkloadTrace::generate(&TraceConfig {
                    seed: 99,
                    n_tasks: 20,
                    mean_interarrival_ms: inter_ms,
                    ..Default::default()
                });
                let before = engine.stats.view();
                let rep = coord.serve_trace(&trace)?;
                let after = engine.stats.view();
                let up_bytes = after.bytes_uploaded - before.bytes_uploaded;
                let saved_bytes = after.upload_bytes_saved - before.upload_bytes_saved;
                let tokens: usize =
                    rep.results.iter().map(|r| r.generated_tokens).sum();
                let tokens_per_s = tokens as f64 / (rep.makespan_ms / 1e3).max(1e-9);
                println!(
                    "{:>8} {:>8} {:>12.0} {:>10.2} {:>10.2} {:>10.1} {:>10.1} {:>8.2} {:>12.2} {:>12.2}",
                    engines,
                    workers,
                    inter_ms,
                    rep.throughput_tasks_per_s(),
                    tokens_per_s,
                    rep.latency_percentile(50.0),
                    rep.latency_percentile(95.0),
                    rep.em_rate(),
                    up_bytes as f64 / 1e6,
                    saved_bytes as f64 / 1e6,
                );
                rows.push(
                    JsonBuilder::new()
                        .num("engines", engines as f64)
                        .num("workers", workers as f64)
                        .num("interarrival_ms", inter_ms)
                        .num("throughput", rep.throughput_tasks_per_s())
                        .num("tokens_per_s", tokens_per_s)
                        .num("p50_ms", rep.latency_percentile(50.0))
                        .num("p95_ms", rep.latency_percentile(95.0))
                        .num("em", rep.em_rate())
                        .num("bytes_uploaded", up_bytes as f64)
                        .num("upload_bytes_saved", saved_bytes as f64)
                        .build(),
                );
            }
        }
    }

    println!("\n== Serving discipline: thread-per-task vs session fabric ==");
    println!(
        "{:>16} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "discipline", "tok/s", "p50 ms", "p95 ms", "queue p95", "EM", "failed"
    );
    let mut disc_rows = Vec::new();
    let trace = WorkloadTrace::generate(&TraceConfig {
        seed: 99,
        n_tasks: 16,
        mean_interarrival_ms: 300.0,
        ..Default::default()
    });
    for fabric in [false, true] {
        let mut sc = SystemConfig::default();
        sc.federation.participants = 3;
        sc.serving.engines = 2;
        sc.serving.fabric = fabric;
        let mut ccfg = CoordinatorConfig::from_system(&sc);
        ccfg.time_scale = 4.0;
        let coord = Coordinator::new(engine.clone(), ccfg);
        let rep = coord.serve_trace(&trace)?;
        let tokens: usize = rep.results.iter().map(|r| r.generated_tokens).sum();
        let tokens_per_s = tokens as f64 / (rep.makespan_ms / 1e3).max(1e-9);
        let name = if fabric { "fabric" } else { "thread-per-task" };
        println!(
            "{:>16} {:>10.2} {:>10.1} {:>10.1} {:>10.1} {:>8.2} {:>8}",
            name,
            tokens_per_s,
            rep.latency_percentile(50.0),
            rep.latency_percentile(95.0),
            rep.queue_percentile(95.0),
            rep.em_rate(),
            rep.failed_count(),
        );
        disc_rows.push(
            JsonBuilder::new()
                .str("discipline", name)
                .num("tokens_per_s", tokens_per_s)
                .num("p50_ms", rep.latency_percentile(50.0))
                .num("p95_ms", rep.latency_percentile(95.0))
                .num("queue_p95_ms", rep.queue_percentile(95.0))
                .num("em", rep.em_rate())
                .num("failed", rep.failed_count() as f64)
                .num("dropped", rep.dropped.len() as f64)
                .build(),
        );
    }

    println!("\n== Analytic 3-way capacity curve (BENCH_serving.json) ==");
    let p = ModelParams::default();
    println!(
        "{:>10} {:>16} {:>12} {:>10} {:>10}",
        "sessions", "mode", "tok/s", "p50 ms", "p95 ms"
    );
    for pt in capacity_curve(&p, &CURVE_SWEEP) {
        println!(
            "{:>10} {:>16} {:>12.2} {:>10.1} {:>10.1}",
            pt.sessions,
            pt.mode.name(),
            pt.tokens_per_s,
            pt.p50_ms,
            pt.p95_ms
        );
    }

    println!("\n== SLO enforcement: completion rate vs load (BENCH_slo.json) ==");
    println!(
        "{:>12} {:>10} {:>10} {:>8} {:>8} {:>12} {:>10}",
        "deadline ms", "gap ms", "completed", "killed", "rate", "goodput t/s", "p95 ms"
    );
    {
        let p = ModelParams::default();
        for pt in slo_curve(&p, ServeMode::Fabric, SLO_SESSIONS, &SLO_DEADLINES_MS, &SLO_GAPS_MS)
        {
            println!(
                "{:>12.0} {:>10.0} {:>10} {:>8} {:>8.3} {:>12.2} {:>10.1}",
                pt.deadline_ms,
                pt.arrival_gap_ms,
                pt.completed,
                pt.killed,
                pt.completion_rate,
                pt.goodput_tokens_per_s,
                pt.p95_ms
            );
        }
    }

    let stats = engine.stats.view();
    let measured = JsonBuilder::new()
        .set("points", Json::Arr(rows))
        .set("disciplines", Json::Arr(disc_rows))
        .num("total_bytes_uploaded", stats.bytes_uploaded as f64)
        .num("total_upload_bytes_saved", stats.upload_bytes_saved as f64)
        .num("weight_bytes_uploaded", stats.weight_bytes_uploaded as f64)
        .num("executions", stats.executions as f64)
        .build();
    write_json("serving_throughput", measured);
    write_bench_json("serving", curve_report());
    write_bench_json("slo", slo_report());
    Ok(())
}
