//! Serving-layer bench: throughput / latency of the coordinator under
//! Poisson load (the deployment-facing counterpart of the paper's
//! efficiency claims; no direct paper figure — see DESIGN.md §4).
//!
//!     cargo bench --bench serving_throughput

mod common;

use anyhow::Result;
use common::*;
use fedattn::config::SystemConfig;
use fedattn::coordinator::{Coordinator, CoordinatorConfig};
use fedattn::data::{TraceConfig, WorkloadTrace};
use fedattn::util::json::{Json, JsonBuilder};

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let mut rows = Vec::new();

    println!("== Serving throughput/latency under load ==");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "engines", "arrival ms", "thru t/s", "p50 ms", "p95 ms", "EM"
    );
    for &engines in &[1usize, 2] {
        for &inter_ms in &[800.0f64, 300.0] {
            let mut sc = SystemConfig::default();
            sc.federation.participants = 3;
            sc.serving.engines = engines;
            let mut ccfg = CoordinatorConfig::from_system(&sc);
            ccfg.time_scale = 4.0;
            let coord = Coordinator::new(engine.clone(), ccfg);
            let trace = WorkloadTrace::generate(&TraceConfig {
                seed: 99,
                n_tasks: 20,
                mean_interarrival_ms: inter_ms,
                ..Default::default()
            });
            let rep = coord.serve_trace(&trace)?;
            println!(
                "{:>8} {:>12.0} {:>10.2} {:>10.1} {:>10.1} {:>8.2}",
                engines,
                inter_ms,
                rep.throughput_tasks_per_s(),
                rep.latency_percentile(50.0),
                rep.latency_percentile(95.0),
                rep.em_rate()
            );
            rows.push(
                JsonBuilder::new()
                    .num("engines", engines as f64)
                    .num("interarrival_ms", inter_ms)
                    .num("throughput", rep.throughput_tasks_per_s())
                    .num("p50_ms", rep.latency_percentile(50.0))
                    .num("p95_ms", rep.latency_percentile(95.0))
                    .num("em", rep.em_rate())
                    .build(),
            );
        }
    }
    write_json("serving_throughput", Json::Arr(rows));
    Ok(())
}
