//! Fig. 5 — trade-off between response quality and communication cost.
//!
//! Sweeps the number of local forwards H ∈ {1, 2, 4, 8(=M)} plus the
//! fully-local LocAttn limit across the four input-segmentation settings,
//! reporting mean/min/max EM over participants and the mean bytes
//! transmitted per participant — the paper's primary efficacy–efficiency
//! result (Remark 4/5: EM falls and comm savings shrink as O(1/H²)).
//!
//!     cargo bench --bench fig5_quality_vs_comm

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::SyncSchedule;
use fedattn::util::json::Json;
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    let hs = [1usize, 2, 4, 8];
    let mut rows = Vec::new();

    println!("== Fig. 5: EM vs communication cost across local forwards H ==");
    println!("(N = {n}, {} episodes/point)", episodes_per_point());
    for seg in Segmentation::ALL {
        println!("\n-- segmentation {} --", seg.as_str());
        println!(
            "{:>8} {:>8} {:>8} {:>8} {:>14} {:>10}",
            "H", "EM mean", "EM min", "EM max", "tx/participant", "comm ms"
        );
        for &h in &hs {
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
            let r = run_point(&engine, &cfg)?;
            println!(
                "{:>8} {:>8.3} {:>8.3} {:>8.3} {:>14} {:>10.2}",
                h,
                r.em_mean,
                r.em_min,
                r.em_max,
                fmt_bytes(r.avg_tx_bytes),
                r.comm_time_ms
            );
            rows.push(point_json(&format!("{}:H{}", seg.as_str(), h), h as f64, &r));
        }
        // LocAttn limit: no KV exchange at all.
        let mut cfg = PointCfg::new(n, seg, SyncSchedule::never(m, n));
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>8} {:>8.3} {:>8.3} {:>8.3} {:>14} {:>10.2}",
            "loc",
            r.em_mean,
            r.em_min,
            r.em_max,
            fmt_bytes(r.avg_tx_bytes),
            r.comm_time_ms
        );
        rows.push(point_json(&format!("{}:loc", seg.as_str()), (m + 1) as f64, &r));
    }
    write_json("fig5_quality_vs_comm", Json::Arr(rows));
    Ok(())
}
