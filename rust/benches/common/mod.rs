//! Shared support for the paper-figure benches (criterion is unavailable
//! offline; every bench is a `harness = false` binary using this module).
//!
//! Each bench prints the paper's rows and writes a JSON series into
//! `bench_out/` for later plotting / EXPERIMENTS.md.

#![allow(dead_code)]

use std::path::PathBuf;

use anyhow::Result;
use fedattn::data::{gen_episode, partition, Episode, Segmentation};
use fedattn::fedattn::{
    FedSession, KvExchangePolicy, KvPrecision, LocalSparsity, SessionConfig, SyncSchedule,
};
use fedattn::metrics::{em_score, CostModel};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::prng::SplitMix64;

/// Episodes per sweep point (override: FEDATTN_BENCH_EPISODES).
pub fn episodes_per_point() -> usize {
    std::env::var("FEDATTN_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10)
}

pub fn load_engine() -> Result<Engine> {
    let dir = fedattn::default_artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts not found at {dir:?} — run `make artifacts` first"
    );
    Engine::load(&dir, "weights.npz")
}

/// One sweep-point configuration.
#[derive(Clone)]
pub struct PointCfg {
    pub n: usize,
    pub seg: Segmentation,
    pub schedule: SyncSchedule,
    pub kv_policy: KvExchangePolicy,
    pub local_ratio: f64,
    /// Per-node attendance dropout probability (0.0 = off).
    pub dropout_prob: f64,
    /// Per-sync-round contribution deadline in simulated ms (`None` =
    /// no deadline; late contributions are excluded from the round).
    pub round_deadline_ms: Option<f64>,
    /// Delta-encoded downlink frames (default on); off bills full
    /// broadcast frames — the pre-delta baseline for comm comparisons.
    pub delta_frames: bool,
    /// Wire precision of the KV data plane (default `F32`, the legacy
    /// layout; `F16`/`Int8` quantize every shipped row).
    pub kv_precision: KvPrecision,
    pub decode_all: bool,
    pub episodes: usize,
    pub seed: u64,
    pub n_facts: usize,
    pub link: LinkSpec,
}

impl PointCfg {
    pub fn new(n: usize, seg: Segmentation, schedule: SyncSchedule) -> Self {
        Self {
            n,
            seg,
            schedule,
            kv_policy: KvExchangePolicy::Full,
            local_ratio: 1.0,
            dropout_prob: 0.0,
            round_deadline_ms: None,
            delta_frames: true,
            kv_precision: KvPrecision::F32,
            decode_all: false,
            episodes: episodes_per_point(),
            seed: 1234,
            n_facts: 4,
            link: LinkSpec::default(),
        }
    }
}

/// Aggregated results for one sweep point.
#[derive(Debug, Clone, Default)]
pub struct PointResult {
    /// EM of the task publisher.
    pub em_publisher: f64,
    /// Mean / min / max per-participant EM (only when decode_all).
    pub em_mean: f64,
    pub em_min: f64,
    pub em_max: f64,
    /// Mean bytes *transmitted* per participant per task (Fig. 5 metric).
    pub avg_tx_bytes: f64,
    /// Mean simulated communication time per task (ms).
    pub comm_time_ms: f64,
    /// Mean executed exchange rounds per task (deadline starvation and
    /// dropout both shrink this below the scheduled count).
    pub rounds: f64,
    /// Total bytes / total executed rounds across the whole sweep point
    /// (0 when no round ran anywhere).  Computed over executed rounds —
    /// not per-episode means — so starved episodes reduce `rounds`
    /// without dragging the per-round payload toward zero.
    pub round_bytes_mean: f64,
    /// Mean wall-clock per task (ms).
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub episodes: usize,
}

/// Run `cfg.episodes` episodes and aggregate.
pub fn run_point(engine: &Engine, cfg: &PointCfg) -> Result<PointResult> {
    let mut rng = SplitMix64::new(cfg.seed);
    let mut em_pub = 0usize;
    let mut em_hits: Vec<usize> = vec![0; cfg.n];
    let mut em_counts: Vec<usize> = vec![0; cfg.n];
    let mut tx_sum = 0f64;
    let mut commt = 0f64;
    let mut rounds_sum = 0f64;
    let mut round_bytes_sum = 0f64;
    let mut pre_ms = 0f64;
    let mut dec_ms = 0f64;
    for e in 0..cfg.episodes {
        let ep = gen_episode(&mut rng, cfg.n_facts);
        let part = partition(&ep, cfg.n, cfg.seg);
        let mut scfg = SessionConfig::new(cfg.schedule.clone());
        scfg.kv_policy = cfg.kv_policy;
        scfg.local_sparsity = LocalSparsity { ratio: cfg.local_ratio };
        scfg.dropout_prob = cfg.dropout_prob;
        scfg.round_deadline_ms = cfg.round_deadline_ms;
        scfg.delta_frames = cfg.delta_frames;
        scfg.kv_precision = cfg.kv_precision;
        scfg.decode_all = cfg.decode_all;
        scfg.seed = cfg.seed ^ (e as u64).wrapping_mul(0x9E37);
        let net = NetSim::uniform(Topology::Star, cfg.n, cfg.link, scfg.seed);
        let rep = FedSession::new(engine, &part, scfg, net)?.run()?;
        if em_score(&rep.answer, &ep.answer) {
            em_pub += 1;
        }
        for (p, ans) in rep.answers.iter().enumerate() {
            if let Some(a) = ans {
                em_counts[p] += 1;
                if em_score(a, &ep.answer) {
                    em_hits[p] += 1;
                }
            }
        }
        tx_sum += rep.net.avg_tx_bytes_per_participant();
        commt += rep.net.comm_time_ms;
        rounds_sum += rep.net.rounds as f64;
        round_bytes_sum += rep.net.round_bytes.iter().sum::<u64>() as f64;
        pre_ms += rep.prefill_ms;
        dec_ms += rep.decode_ms;
    }
    let per_part: Vec<f64> = em_hits
        .iter()
        .zip(&em_counts)
        .filter(|(_, &c)| c > 0)
        .map(|(&h, &c)| h as f64 / c as f64)
        .collect();
    let ne = cfg.episodes as f64;
    Ok(PointResult {
        em_publisher: em_pub as f64 / ne,
        em_mean: mean(&per_part),
        em_min: per_part.iter().copied().fold(f64::INFINITY, f64::min),
        em_max: per_part.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        avg_tx_bytes: tx_sum / ne,
        comm_time_ms: commt / ne,
        rounds: rounds_sum / ne,
        round_bytes_mean: if rounds_sum > 0.0 { round_bytes_sum / rounds_sum } else { 0.0 },
        prefill_ms: pre_ms / ne,
        decode_ms: dec_ms / ne,
        episodes: cfg.episodes,
    })
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Write a bench's JSON output under bench_out/.
pub fn write_json(name: &str, value: Json) {
    let dir = repo_root().join("bench_out");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, value.to_string_compact()) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        eprintln!("(series written to {path:?})");
    }
}

/// Write a machine-readable trajectory report at the repo root
/// (`BENCH_<name>.json`), so per-PR perf deltas (tokens/s, upload bytes)
/// are diffable from the repo's top level.
pub fn write_bench_json(name: &str, value: Json) {
    let path = repo_root().join(format!("BENCH_{name}.json"));
    if let Err(e) = std::fs::write(&path, value.to_string_compact()) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        eprintln!("(trajectory report written to {path:?})");
    }
}

fn repo_root() -> PathBuf {
    // Walk to the *outermost* Cargo.toml: cargo runs bench binaries with
    // cwd = the crate root (`rust/`), but the trajectory reports and
    // `bench_out/` belong at the workspace root — where the committed
    // BENCH_*.json copies and CI's schema assertions live.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut root = None;
    for _ in 0..5 {
        if dir.join("Cargo.toml").exists() {
            root = Some(dir.clone());
        }
        if !dir.pop() {
            break;
        }
    }
    root.unwrap_or_else(|| PathBuf::from("."))
}

/// JSON row helper for sweep points.
pub fn point_json(label: &str, x: f64, r: &PointResult) -> Json {
    JsonBuilder::new()
        .str("label", label)
        .num("x", x)
        .num("em_publisher", r.em_publisher)
        .num("em_mean", r.em_mean)
        .num("em_min", r.em_min)
        .num("em_max", r.em_max)
        .num("avg_tx_bytes", r.avg_tx_bytes)
        .num("comm_time_ms", r.comm_time_ms)
        .num("rounds", r.rounds)
        .num("round_bytes_mean", r.round_bytes_mean)
        .num("prefill_ms", r.prefill_ms)
        .num("decode_ms", r.decode_ms)
        .build()
}

/// Representative cost model for the loaded engine.
pub fn cost_model(engine: &Engine) -> CostModel {
    CostModel::new(engine.manifest.model.clone())
}

/// Fixed evaluation episodes shared across points of a sweep (paired
/// comparison reduces variance).
pub fn fixed_episodes(seed: u64, n: usize, n_facts: usize) -> Vec<Episode> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| gen_episode(&mut rng, n_facts)).collect()
}

/// Micro-bench timing helper: median of `iters` runs after `warmup`.
pub fn time_median_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t = std::time::Instant::now();
            f();
            t.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}
