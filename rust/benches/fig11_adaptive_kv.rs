//! Fig. 11 (extension) — adaptive KV aggregation at matched byte budgets.
//!
//! The paper's §V Obs. 4 names adaptive aggregation as the headline
//! optimization opportunity but only evaluates blind policies.  This bench
//! pits all five sparse `KvExchangePolicy` variants against each other at
//! the *same* transmitted-byte budget, so any EM difference is pure
//! selection quality:
//!
//! * `random`             — uniform keep-ratio f (Fig. 10 baseline)
//! * `publisher-priority` — publisher full, remotes thinned to match f
//! * `recent-budget`      — newest ⌈f·rows⌉ rows per participant
//! * `top-k-relevance`    — highest accumulated attention mass (adaptive)
//! * `byte-budget`        — relevance selection under a coordinator-split
//!                          byte budget (equal links ⇒ equal row budgets)
//!
//! plus the `full` reference.  Expected: `top-k-relevance` ≥ `random` EM
//! at equal comm bytes on the MicroFact workload.
//!
//!     cargo bench --bench fig11_adaptive_kv

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::{partition, Segmentation};
use fedattn::fedattn::{KvExchangePolicy, SyncSchedule};
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let m = md.n_layers;
    let n = 4usize;
    let h = 2usize;
    let seg = Segmentation::SemQEx;
    let row_bytes = md.kv_row_bytes();

    // Probe the evaluation episodes (same seed/stream as run_point) for
    // the mean per-participant row count, so row budgets match the random
    // policy's expected byte volume at each keep fraction.
    let eps = fixed_episodes(1234, episodes_per_point(), 4);
    let mean_rows: f64 = eps
        .iter()
        .map(|ep| partition(ep, n, seg).len() as f64 / n as f64)
        .sum::<f64>()
        / eps.len().max(1) as f64;

    println!("== Fig. 11: adaptive KV aggregation (H = {h}, N = {n}, {}) ==", seg.as_str());
    println!("mean rows/participant: {mean_rows:.1}  ({row_bytes} B/row)");
    println!(
        "\n{:>20} {:>6} {:>10} {:>14} {:>10}",
        "policy", "f", "EM (pub)", "tx/participant", "comm ms"
    );

    let mut rows_json = Vec::new();

    // Full-exchange reference.
    let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
    cfg.kv_policy = KvExchangePolicy::Full;
    let full = run_point(&engine, &cfg)?;
    println!(
        "{:>20} {:>6.2} {:>10.3} {:>14} {:>10.2}",
        "full",
        1.0,
        full.em_publisher,
        fmt_bytes(full.avg_tx_bytes),
        full.comm_time_ms
    );
    rows_json.push(point_json("full:f1", 1.0, &full));

    for &f in &[0.25f64, 0.5, 0.75] {
        let budget = ((mean_rows * f).round() as usize).max(1);
        let total_bytes = n * budget * row_bytes;
        // Publisher sends everything; thin the remotes so the *expected*
        // total matches f (assumes roughly equal spans).
        let remote_ratio = ((f * n as f64 - 1.0) / (n as f64 - 1.0)).clamp(0.0, 1.0);
        let policies = [
            KvExchangePolicy::Random { ratio: f },
            KvExchangePolicy::PublisherPriority { remote_ratio },
            KvExchangePolicy::RecentBudget { budget_rows: budget },
            KvExchangePolicy::TopKRelevance { budget_rows: budget },
            KvExchangePolicy::ByteBudget { bytes_per_round: total_bytes },
        ];
        println!("\n-- keep fraction {f} (budget {budget} rows, {} total/round) --",
            fmt_bytes(total_bytes as f64));
        let mut em_random = f64::NAN;
        let mut em_topk = f64::NAN;
        for policy in policies {
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
            cfg.kv_policy = policy;
            let r = run_point(&engine, &cfg)?;
            match policy {
                KvExchangePolicy::Random { .. } => em_random = r.em_publisher,
                KvExchangePolicy::TopKRelevance { .. } => em_topk = r.em_publisher,
                _ => {}
            }
            println!(
                "{:>20} {:>6.2} {:>10.3} {:>14} {:>10.2}",
                policy.as_str(),
                f,
                r.em_publisher,
                fmt_bytes(r.avg_tx_bytes),
                r.comm_time_ms
            );
            rows_json.push(point_json(&format!("{}:f{f}", policy.as_str()), f, &r));
        }
        let delta = em_topk - em_random;
        println!(
            "   => top-k-relevance vs random at matched bytes: {delta:+.3} EM {}",
            if delta >= 0.0 { "(adaptive wins/ties)" } else { "(adaptive LOSES - investigate)" }
        );
        rows_json.push(
            JsonBuilder::new()
                .str("label", &format!("summary:f{f}"))
                .num("x", f)
                .num("em_topk_minus_random", delta)
                .build(),
        );
    }

    // Trajectory report at the repo root: policy sweep plus the engine's
    // upload accounting (shared device KV handles vs host re-uploads).
    let s = engine.stats.view();
    let report = JsonBuilder::new()
        .set("points", Json::Arr(rows_json.clone()))
        .num("bytes_uploaded", s.bytes_uploaded as f64)
        .num("upload_bytes_saved", s.upload_bytes_saved as f64)
        .num("executions", s.executions as f64)
        .build();
    write_json("fig11_adaptive_kv", Json::Arr(rows_json));
    write_bench_json("fig11_adaptive_kv", report);
    Ok(())
}
