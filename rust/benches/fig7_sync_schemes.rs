//! Fig. 7 — response quality under four KV-exchange placement schemes.
//!
//! Shallow-Half vs Deep-Half and Progressive vs Regressive with 4 sync
//! rounds in M blocks, 4 participants.  The paper's headline experimental
//! surprise: deep placements win, contradicting the Theorem 2 prediction
//! under uniform constants (see the theory_validation bench for why).
//!
//!     cargo bench --bench fig7_sync_schemes

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::{Scheme, SyncSchedule};
use fedattn::util::json::Json;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    let rounds = 4usize;
    let schemes = [
        Scheme::Uniform { h: m / rounds },
        Scheme::ShallowHalf { rounds },
        Scheme::DeepHalf { rounds },
        Scheme::Progressive { rounds },
        Scheme::Regressive { rounds },
    ];
    let mut rows = Vec::new();

    println!("== Fig. 7: sync-placement schemes ({rounds} rounds, N = {n}) ==");
    for seg in [Segmentation::SemQEx, Segmentation::TokQAg] {
        println!("\n-- segmentation {} --", seg.as_str());
        println!("{:>14} {:>18} {:>8} {:>8} {:>8}", "scheme", "sync blocks", "EM mean", "EM min", "EM max");
        for scheme in schemes {
            let blocks = scheme.sync_blocks(m);
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::from_scheme(scheme, m, n));
            let r = run_point(&engine, &cfg)?;
            println!(
                "{:>14} {:>18} {:>8.3} {:>8.3} {:>8.3}",
                scheme.as_str(),
                format!("{blocks:?}"),
                r.em_mean,
                r.em_min,
                r.em_max
            );
            rows.push(point_json(
                &format!("{}:{}", seg.as_str(), scheme.as_str()),
                blocks.iter().sum::<usize>() as f64 / rounds as f64,
                &r,
            ));
        }
    }
    write_json("fig7_sync_schemes", Json::Arr(rows));
    Ok(())
}
