//! Microbenchmarks of the Layer-3 hot-path pieces: KV packing, mask
//! building, engine dispatch (block execution / logits), network-sim
//! rounds, thread-pool overhead, tokenizer and workload generation.
//! These feed the §Perf iteration log in EXPERIMENTS.md.
//!
//!     cargo bench --bench micro

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::gen_episode;
use fedattn::exec::Pool;
use fedattn::fedattn::{global_mask, local_mask, GlobalKv};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::tensor::HostTensor;
use fedattn::tokenizer;
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::prng::SplitMix64;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let mut rows: Vec<Json> = Vec::new();
    let mut emit = |name: &str, ms: f64, note: &str| {
        println!("{name:>28}: {ms:>10.4} ms  {note}");
        rows.push(JsonBuilder::new().str("name", name).num("ms", ms).str("note", note).build());
    };

    println!("== Layer-3 microbenchmarks (median of 20) ==");

    // KV packing: 4 participants x 64 rows.
    let k = HostTensor::zeros(&[64, md.n_kv_heads, md.head_dim]);
    let v = k.clone();
    let pos: Vec<i32> = (0..64).collect();
    let tx = vec![true; 64];
    let ms = time_median_ms(3, 20, || {
        let refs: Vec<_> = (0..4).map(|_| (&k, &v, &pos[..], 64usize, &tx[..])).collect();
        let g = GlobalKv::pack(&refs, 384).unwrap();
        std::hint::black_box(g.rows());
    });
    emit("kv_pack_4x64", ms, "[256 rows -> G=384]");

    // Mask builders.
    let pos_pad: Vec<i32> = (0..64).collect();
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(local_mask(&pos_pad, 60));
    });
    emit("local_mask_64", ms, "[64x64]");

    let kv_pos: Vec<i32> = (0..256).collect();
    let kv_owner: Vec<usize> = (0..256).map(|i| i / 64).collect();
    let kv_tx = vec![true; 256];
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(global_mask(&pos_pad, 60, 384, &kv_pos, &kv_owner, &kv_tx, 256, 1));
    });
    emit("global_mask_64x384", ms, "[64x384]");

    // Engine dispatch: logits (smallest artifact) = fixed overhead floor.
    let h = HostTensor::zeros(&[1, md.d_model]);
    let _ = engine.logits(&h)?; // compile
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(engine.logits(&h).unwrap());
    });
    emit("engine_logits", ms, "[upload + execute + download]");

    // One fused local block at L = 64.
    let l = 64usize;
    let x = HostTensor::zeros(&[l, md.d_model]);
    let posv: Vec<i32> = (0..l as i32).collect();
    let mask = local_mask(&posv, l);
    let _ = engine.block_fused(0, &x, &posv, &mask)?;
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(engine.block_fused(0, &x, &posv, &mask).unwrap());
    });
    emit("engine_block_fused_L64", ms, "[one Transformer block]");

    // Decode block.
    let c = engine.manifest.decode_cache;
    let x1 = HostTensor::zeros(&[1, md.d_model]);
    let kc = HostTensor::zeros(&[c, md.n_kv_heads, md.head_dim]);
    let vc = kc.clone();
    let dmask = HostTensor::zeros(&[1, c]);
    let _ = engine.decode_block(0, &x1, 0, &kc, &vc, &dmask)?;
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(engine.decode_block(0, &x1, 0, &kc, &vc, &dmask).unwrap());
    });
    emit("engine_decode_block", ms, &format!("[C={c}] full-cache upload/step"));

    // Device-resident decode: frozen cache handles + O(1) tail upload.
    if let Some(r) = engine.manifest.pick_decode_tail(8) {
        let kcd = engine.upload(&kc)?;
        let vcd = engine.upload(&vc)?;
        let dmd = engine.upload(&dmask)?;
        let kt = HostTensor::zeros(&[r, md.n_kv_heads, md.head_dim]);
        let vt = kt.clone();
        let tmask = HostTensor::zeros(&[1, r]);
        let _ = engine.decode_block_tail(0, &x1, 0, &kcd, &vcd, &dmd, &kt, &vt, &tmask)?;
        let ms = time_median_ms(3, 20, || {
            std::hint::black_box(
                engine
                    .decode_block_tail(0, &x1, 0, &kcd, &vcd, &dmd, &kt, &vt, &tmask)
                    .unwrap(),
            );
        });
        emit("engine_decode_tail", ms, &format!("[C={c} R={r}] tail upload/step"));
    } else {
        eprintln!("(decode-tail variants absent — re-run `make artifacts` to bench them)");
    }

    // Shared global KV: attn_ffn with per-call K/V upload vs shared
    // device handles (the once-per-sync-round upload path).
    let l = engine.manifest.l_variants[0];
    let g = engine.manifest.g_variants[0];
    let xg = HostTensor::zeros(&[l, md.d_model]);
    let qg = HostTensor::zeros(&[l, md.n_heads, md.head_dim]);
    let kg = HostTensor::zeros(&[g, md.n_kv_heads, md.head_dim]);
    let vg = kg.clone();
    let gmask = HostTensor::zeros(&[l, g]);
    let _ = engine.attn_ffn(0, &xg, &qg, &kg, &vg, &gmask)?;
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(engine.attn_ffn(0, &xg, &qg, &kg, &vg, &gmask).unwrap());
    });
    emit("engine_attn_ffn_host_kv", ms, &format!("[L={l} G={g}] K/V upload per call"));
    let kgd = engine.upload(&kg)?;
    let vgd = engine.upload(&vg)?;
    let ms = time_median_ms(3, 20, || {
        std::hint::black_box(engine.attn_ffn_dev(0, &xg, &qg, &kgd, &vgd, &gmask).unwrap());
    });
    emit("engine_attn_ffn_shared_kv", ms, &format!("[L={l} G={g}] shared device K/V"));

    // Network sim round.
    let ms = time_median_ms(3, 20, || {
        let mut net = NetSim::uniform(Topology::Star, 8, LinkSpec::default(), 1);
        for _ in 0..8 {
            net.exchange_round(&[10_000; 8], &[true; 8]);
        }
        std::hint::black_box(net.report().rounds);
    });
    emit("netsim_8rounds_8p", ms, "[accounting only]");

    // Thread-pool scope overhead.
    let pool = Pool::new(2);
    let ms = time_median_ms(3, 20, || {
        let out = pool.scope_map(16, |i| i * 2).unwrap();
        std::hint::black_box(out.len());
    });
    emit("pool_scope_map_16", ms, "[spawn+join 16 no-op tasks]");

    // Tokenizer + episode generation.
    let ms = time_median_ms(3, 20, || {
        let mut rng = SplitMix64::new(7);
        for _ in 0..100 {
            let ep = gen_episode(&mut rng, 4);
            std::hint::black_box(tokenizer::encode_with_bos(&ep.prompt()).len());
        }
    });
    emit("gen+tokenize_100eps", ms, "[workload generation]");

    // Engine dispatch/upload accounting for the whole bench run.
    let s = engine.stats.view();
    println!("\n== Engine counters (this run) ==");
    println!(
        "executions {} (block_fused {} qkv {} attn_ffn {} decode {} decode_tail {} logits {})",
        s.executions,
        s.exec_block_fused,
        s.exec_qkv_project,
        s.exec_attn_ffn,
        s.exec_decode_block,
        s.exec_decode_tail,
        s.exec_logits
    );
    println!(
        "uploaded {:.2} MB activations + {:.2} MB weights; {:.2} MB saved by device handles",
        s.bytes_uploaded as f64 / 1e6,
        s.weight_bytes_uploaded as f64 / 1e6,
        s.upload_bytes_saved as f64 / 1e6
    );
    rows.push(
        JsonBuilder::new()
            .str("name", "engine_stats")
            .num("executions", s.executions as f64)
            .num("exec_block_fused", s.exec_block_fused as f64)
            .num("exec_qkv_project", s.exec_qkv_project as f64)
            .num("exec_attn_ffn", s.exec_attn_ffn as f64)
            .num("exec_decode_block", s.exec_decode_block as f64)
            .num("exec_decode_tail", s.exec_decode_tail as f64)
            .num("exec_logits", s.exec_logits as f64)
            .num("bytes_uploaded", s.bytes_uploaded as f64)
            .num("weight_bytes_uploaded", s.weight_bytes_uploaded as f64)
            .num("upload_bytes_saved", s.upload_bytes_saved as f64)
            .build(),
    );

    write_json("micro", Json::Arr(rows));
    Ok(())
}
