//! Fig. 10 — response quality under sparse KV exchange.
//!
//! Participants transmit random KV subsets at each sync while keeping full
//! local self-attention.  The paper's counter-intuitive finding: moderate
//! sparsity preserves (or improves) quality while cutting communication —
//! remote-KV noise is filtered and attention entropy drops.
//!
//!     cargo bench --bench fig10_sparse_kv

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::{KvExchangePolicy, SyncSchedule};
use fedattn::util::json::Json;
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    let h = 2usize;
    let ratios = [1.0f64, 0.9, 0.75, 0.5, 0.25];
    let mut rows = Vec::new();

    println!("== Fig. 10: sparse KV exchange (uniform H = {h}, N = {n}) ==");
    for seg in [Segmentation::SemQAg, Segmentation::SemQEx, Segmentation::TokQEx] {
        println!("\n-- segmentation {} --", seg.as_str());
        println!(
            "{:>8} {:>10} {:>10} {:>14}",
            "keep", "EM (pub)", "EM mean", "tx/participant"
        );
        for &ratio in &ratios {
            let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
            cfg.kv_policy = if ratio >= 1.0 {
                KvExchangePolicy::Full
            } else {
                KvExchangePolicy::Random { ratio }
            };
            let r = run_point(&engine, &cfg)?;
            println!(
                "{:>8.2} {:>10.3} {:>10.3} {:>14}",
                ratio,
                r.em_publisher,
                r.em_mean,
                fmt_bytes(r.avg_tx_bytes)
            );
            rows.push(point_json(&format!("{}:r{}", seg.as_str(), ratio), ratio, &r));
        }
        // Adaptive aggregation (§V Obs. 4): publisher-priority policy.
        let mut cfg = PointCfg::new(n, seg, SyncSchedule::uniform(m, n, h));
        cfg.kv_policy = KvExchangePolicy::PublisherPriority { remote_ratio: 0.5 };
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>8} {:>10.3} {:>10.3} {:>14}   (publisher-priority 0.5)",
            "adapt",
            r.em_publisher,
            r.em_mean,
            fmt_bytes(r.avg_tx_bytes)
        );
        rows.push(point_json(&format!("{}:adaptive", seg.as_str()), 0.5, &r));
    }
    write_json("fig10_sparse_kv", Json::Arr(rows));
    Ok(())
}
