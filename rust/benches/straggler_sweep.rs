//! Straggler sweep — the FedAttn analogue of federated learning's
//! deadline/straggler trade-off (ROADMAP "Wire transport"): with jittery
//! edge links scheduling each uplink's arrival, how do response quality
//! (EM, the quality proxy) and communication (bytes per round, executed
//! rounds) degrade as the per-round deadline tightens from infinity to
//! zero?  A good deadline sheds the slowest stragglers' bytes while
//! keeping quality near the full-attendance line; deadline 0 is the
//! local-attention floor.
//!
//! Also sweeps deadline x dropout (the two attendance perturbations
//! compose: dropout masks the schedule, the deadline drops late
//! arrivals from the surviving rounds).
//!
//! Writes `bench_out/straggler_sweep.json` and the trajectory report
//! `BENCH_straggler.json` at the repo root.
//!
//!     cargo bench --bench straggler_sweep

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::SyncSchedule;
use fedattn::net::LinkSpec;
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    // Slow-ish jittery edge links: arrivals spread enough that finite
    // deadlines actually cut.
    let link = LinkSpec { bandwidth_mbps: 12.0, latency_ms: 4.0, jitter: 0.35 };
    let deadlines: [Option<f64>; 6] =
        [None, Some(60.0), Some(30.0), Some(15.0), Some(8.0), Some(0.0)];
    let fmt_deadline = |d: Option<f64>| match d {
        None => "inf".to_string(),
        Some(d) => format!("{d}"),
    };

    let mut rows = Vec::new();
    println!("== straggler sweep: round deadline vs quality + comm (uniform H = 2, N = {n}) ==");
    println!(
        "{:>10} {:>10} {:>12} {:>14} {:>8} {:>10}",
        "deadline", "EM (pub)", "bytes/round", "tx/participant", "rounds", "comm ms"
    );
    for &deadline in &deadlines {
        let mut cfg = PointCfg::new(n, Segmentation::SemQEx, SyncSchedule::uniform(m, n, 2));
        cfg.link = link;
        cfg.round_deadline_ms = deadline;
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>10} {:>10.3} {:>12} {:>14} {:>8.1} {:>10.2}",
            fmt_deadline(deadline),
            r.em_publisher,
            fmt_bytes(r.round_bytes_mean),
            fmt_bytes(r.avg_tx_bytes),
            r.rounds,
            r.comm_time_ms
        );
        // x = -1 marks the no-deadline baseline (JSON has no infinity).
        rows.push(point_json(
            &format!("deadline:{}", fmt_deadline(deadline)),
            deadline.unwrap_or(-1.0),
            &r,
        ));
    }

    // Composition sweep: a fixed moderate deadline under growing dropout.
    println!("\n== deadline 30 ms x dropout sweep ==");
    println!(
        "{:>10} {:>10} {:>12} {:>8}",
        "dropout", "EM (pub)", "bytes/round", "rounds"
    );
    for &p_drop in &[0.0f64, 0.1, 0.25, 0.5] {
        let mut cfg = PointCfg::new(n, Segmentation::SemQEx, SyncSchedule::uniform(m, n, 2));
        cfg.link = link;
        cfg.round_deadline_ms = Some(30.0);
        cfg.dropout_prob = p_drop;
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>10.2} {:>10.3} {:>12} {:>8.1}",
            p_drop,
            r.em_publisher,
            fmt_bytes(r.round_bytes_mean),
            r.rounds
        );
        rows.push(point_json(&format!("deadline30:dropout:{p_drop}"), p_drop, &r));
    }

    write_json("straggler_sweep", Json::Arr(rows.clone()));
    // Trajectory report at the repo root: quality proxy + round bytes vs
    // deadline, diffable per PR.
    let report = JsonBuilder::new()
        .str("bench", "straggler_sweep")
        .num("participants", n as f64)
        .num("episodes_per_point", episodes_per_point() as f64)
        .set("points", Json::Arr(rows))
        .build();
    write_bench_json("straggler", report);
    Ok(())
}
