//! Theory validation (§VI): measured ‖X^T − X*‖_F vs the Theorem 1 /
//! Corollary 1 bounds, and the per-block attention-deviation profile σ_m
//! that reconciles Theorem 2 with the Fig. 7 experiment.
//!
//! Three parts:
//!  1. Deviation vs H — FedAttn final hidden states against CenAttn;
//!     must be ~0 at H = 1 and grow monotonically (Remark 4).
//!  2. σ_m profile — at each block, the Frobenius gap between local and
//!     global attention outputs under identical inputs (Assumption 2's
//!     constant, measured).  The paper argues σ_m grows with depth.
//!  3. Theorem 2 bounds evaluated with the *measured* σ_m for the four
//!     Fig. 7 placement schemes — showing the bound ordering flips to
//!     match the experiment once σ_m is depth-dependent.
//!
//!     cargo bench --bench theory_validation

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::{gen_episode, partition, Segmentation};
use fedattn::fedattn::{
    global_mask, FedSession, GlobalKv, Scheme, SessionConfig, SyncSchedule,
};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::tensor::HostTensor;
use fedattn::theory::{corollary1_bound, theorem2_bound, BlockConstants};
use fedattn::util::json::{Json, JsonBuilder};
use fedattn::util::prng::SplitMix64;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let md = engine.manifest.model.clone();
    let m = md.n_layers;
    let n = 4usize;
    let mut rng = SplitMix64::new(99);
    let episodes: Vec<_> = (0..4).map(|_| gen_episode(&mut rng, 4)).collect();

    // ---- Part 1: deviation vs H --------------------------------------
    println!("== Part 1: measured ||X_fed - X_cen||_F vs H ==");
    println!("{:>6} {:>14} {:>14}", "H", "deviation", "corollary1");
    let mut rows = Vec::new();
    let seg = Segmentation::SemQEx;
    for &h in &[1usize, 2, 4, 8] {
        let mut dev_sum = 0.0;
        for ep in &episodes {
            let part = partition(ep, n, seg);
            // FedAttn run.
            let mut cfg = SessionConfig::new(SyncSchedule::uniform(m, n, h));
            cfg.record_hidden = true;
            let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 5);
            let fed = FedSession::new(&engine, &part, cfg, net)?.run_prefill_only()?;
            // CenAttn run.
            let cen_part = partition(ep, 1, Segmentation::TokQAg);
            let mut ccfg = SessionConfig::new(SyncSchedule::uniform(m, 1, 1));
            ccfg.record_hidden = true;
            let cnet = NetSim::uniform(Topology::Star, 1, LinkSpec::default(), 5);
            let cen = FedSession::new(&engine, &cen_part, ccfg, cnet)?.run_prefill_only()?;
            let cen_h = cen.hidden[0].as_ref().unwrap();
            // Frobenius distance over all tokens matched by global position.
            let mut sq = 0f64;
            for (p, h_opt) in fed.hidden.iter().enumerate() {
                let hh = h_opt.as_ref().unwrap();
                for (i, &gpos) in fed.positions[p].iter().enumerate() {
                    for (a, b) in hh.row(i).iter().zip(cen_h.row(gpos as usize)) {
                        let d = (*a - *b) as f64;
                        sq += d * d;
                    }
                }
            }
            dev_sum += sq.sqrt();
        }
        let dev = dev_sum / episodes.len() as f64;
        // Corollary 1 with representative constants (scale-matched below).
        let bound = corollary1_bound(0.06, 0.10, 1.0, m, h);
        println!("{h:>6} {dev:>14.4} {bound:>14.4}");
        rows.push(JsonBuilder::new().num("h", h as f64).num("deviation", dev).num("corollary1", bound).build());
    }

    // ---- Part 2: per-block sigma_m profile ----------------------------
    println!("\n== Part 2: measured per-block deviation sigma_m ==");
    let mut sigma = vec![0f64; m];
    for ep in &episodes {
        let part = partition(ep, n, seg);
        let s = measure_sigma_profile(&engine, &part)?;
        for (i, v) in s.iter().enumerate() {
            sigma[i] += v / episodes.len() as f64;
        }
    }
    println!("{:>6} {:>12}", "block", "sigma_m");
    for (i, s) in sigma.iter().enumerate() {
        println!("{i:>6} {s:>12.4}");
    }

    // ---- Part 3: Theorem 2 with measured sigma ------------------------
    println!("\n== Part 3: Theorem 2 bounds with measured sigma_m (Fig. 7 schemes) ==");
    let consts: Vec<BlockConstants> = sigma
        .iter()
        .map(|&s| BlockConstants { theta: 0.06, rho: 0.10, sigma_sum: s })
        .collect();
    println!("{:>14} {:>14}", "scheme", "T2 bound");
    let rounds = 4;
    for scheme in [
        Scheme::ShallowHalf { rounds },
        Scheme::DeepHalf { rounds },
        Scheme::Progressive { rounds },
        Scheme::Regressive { rounds },
    ] {
        let mut sync = vec![false; m];
        for b in scheme.sync_blocks(m) {
            sync[b] = true;
        }
        let bound = theorem2_bound(&consts, &sync);
        println!("{:>14} {:>14.4}", scheme.as_str(), bound);
        rows.push(
            JsonBuilder::new()
                .str("scheme", scheme.as_str())
                .num("t2_bound", bound)
                .build(),
        );
    }
    let sig_json = Json::Arr(sigma.iter().map(|&s| Json::Num(s)).collect());
    rows.push(JsonBuilder::new().set("sigma_profile", sig_json).build());
    write_json("theory_validation", Json::Arr(rows));
    Ok(())
}

/// Measure σ_m: at each block, run both local attention (block_fused) and
/// global attention (qkv + attn_ffn over the full aggregated KV) from the
/// *same* input state, record the Frobenius gap of the outputs, and
/// continue with the local branch (the LocAttn trajectory).
fn measure_sigma_profile(
    engine: &fedattn::runtime::Engine,
    part: &fedattn::data::Partition,
) -> Result<Vec<f64>> {
    let md = engine.manifest.model.clone();
    let n = part.n_participants();
    // Initialize participant states exactly like the session does.
    let mut xs = Vec::new();
    let mut poss = Vec::new();
    let mut valids = Vec::new();
    let mut lmasks = Vec::new();
    for p in 0..n {
        let (s, e) = part.spans[p];
        let ids = &part.ids[s..e];
        let pos: Vec<i32> = (s as i32..e as i32).collect();
        let l_pad = engine.manifest.pick_l(ids.len())?;
        let mut pos_pad = pos.clone();
        pos_pad.resize(l_pad, *pos.last().unwrap());
        let mut x = HostTensor::zeros(&[l_pad, md.d_model]);
        let emb = engine.embed(ids)?;
        x.copy_rows_from(&emb, 0..ids.len(), 0);
        lmasks.push(fedattn::fedattn::local_mask(&pos_pad, ids.len()));
        xs.push(x);
        poss.push(pos_pad);
        valids.push(ids.len());
    }
    let mut sigma = vec![0f64; md.n_layers];
    for m in 0..md.n_layers {
        let mut new_xs = Vec::new();
        // Project everyone, pack the full global KV.
        let mut qs = Vec::new();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for p in 0..n {
            let (q, k, v) = engine.qkv_project(m, &xs[p], &poss[p])?;
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        let tx: Vec<Vec<bool>> = valids.iter().map(|&v| vec![true; v]).collect();
        let refs: Vec<_> = (0..n)
            .map(|p| (&ks[p], &vs[p], &poss[p][..], valids[p], &tx[p][..]))
            .collect();
        let rows: usize = valids.iter().sum();
        let g_pad = engine.manifest.pick_g(rows)?;
        let gkv = GlobalKv::pack(&refs, g_pad)?;
        let (kv_pos, kv_owner, kv_tx) = gkv.meta_columns();
        for p in 0..n {
            // Local branch.
            let (x_loc, _, _) = engine.block_fused(m, &xs[p], &poss[p], &lmasks[p])?;
            // Global branch from the same input.
            let mask = global_mask(
                &poss[p], valids[p], g_pad, &kv_pos, &kv_owner, &kv_tx, gkv.rows(), p,
            );
            let x_glob = engine.attn_ffn(m, &xs[p], &qs[p], &gkv.k, &gkv.v, &mask)?;
            sigma[m] += x_loc.frob_dist_rows(&x_glob, valids[p]);
            new_xs.push(x_loc); // continue on the LocAttn trajectory
        }
        xs = new_xs;
    }
    Ok(sigma)
}
