//! Fig. 8 — response quality under varying synchronization intervals for
//! the task publisher (others fixed at H = M), plus a per-node attendance
//! dropout sweep (the participant-protocol dropout knob): how quality and
//! comm degrade as scheduled attendances are randomly dropped.
//!
//! The adaptive-KV-aggregation result: increasing the *critical*
//! participant's sync frequency monotonically improves its response
//! quality.
//!
//!     cargo bench --bench fig8_publisher_sync

mod common;

use anyhow::Result;
use common::*;
use fedattn::data::Segmentation;
use fedattn::fedattn::SyncSchedule;
use fedattn::util::json::Json;
use fedattn::util::stats::fmt_bytes;

fn main() -> Result<()> {
    fedattn::util::log::init();
    let engine = load_engine()?;
    let m = engine.manifest.model.n_layers;
    let n = 4usize;
    let mut rows = Vec::new();

    println!("== Fig. 8: publisher sync interval sweep (others H = {m}, N = {n}) ==");
    for seg in [Segmentation::SemQEx, Segmentation::TokQEx] {
        println!("\n-- segmentation {} --", seg.as_str());
        println!(
            "{:>8} {:>10} {:>14} {:>10}",
            "H_pub", "EM (pub)", "tx/participant", "comm ms"
        );
        for &h_pub in &[1usize, 2, 4, 8] {
            let mut hs = vec![m; n];
            hs[n - 1] = h_pub; // the publisher is the last participant
            let cfg = PointCfg::new(n, seg, SyncSchedule::per_participant(m, &hs));
            let r = run_point(&engine, &cfg)?;
            println!(
                "{:>8} {:>10.3} {:>14} {:>10.2}",
                h_pub,
                r.em_publisher,
                fmt_bytes(r.avg_tx_bytes),
                r.comm_time_ms
            );
            rows.push(point_json(
                &format!("{}:Hpub{}", seg.as_str(), h_pub),
                h_pub as f64,
                &r,
            ));
        }
    }
    // Dropout sweep: uniform H = 2 for everyone, then drop each scheduled
    // attendance with probability p.  Comm bytes shrink with p (fewer
    // exchange rounds reach anyone) while publisher EM degrades — the
    // federated-inference dropout/straggler scenario as a schedule input.
    println!("\n== per-node attendance dropout sweep (uniform H = 2, N = {n}) ==");
    println!(
        "{:>8} {:>10} {:>14} {:>10}",
        "dropout", "EM (pub)", "tx/participant", "comm ms"
    );
    for &p_drop in &[0.0f64, 0.1, 0.25, 0.5] {
        let mut cfg =
            PointCfg::new(n, Segmentation::SemQEx, SyncSchedule::uniform(m, n, 2));
        cfg.dropout_prob = p_drop;
        let r = run_point(&engine, &cfg)?;
        println!(
            "{:>8.2} {:>10.3} {:>14} {:>10.2}",
            p_drop,
            r.em_publisher,
            fmt_bytes(r.avg_tx_bytes),
            r.comm_time_ms
        );
        rows.push(point_json(&format!("dropout:{p_drop}"), p_drop, &r));
    }

    write_json("fig8_publisher_sync", Json::Arr(rows));
    Ok(())
}
