//! `fedattn` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   info       — print model/artifact/weight information
//!   run        — run one collaborative task and print the answer + costs
//!   serve      — replay a workload trace through the coordinator
//!   chaos      — churn-recovery capacity sweep (writes BENCH_churn.json)
//!   gen-data   — print sample MicroFact episodes (workload inspection)
//!   validate   — H=1 FedAttn ≡ CenAttn sanity check on live artifacts

use anyhow::{Context, Result};

use fedattn::cli::Args;
use fedattn::config::SystemConfig;
use fedattn::coordinator::{Coordinator, CoordinatorConfig};
use fedattn::data::{gen_episode, partition, Segmentation, TraceConfig, WorkloadTrace};
use fedattn::fedattn::{
    FedSession, LocalSparsity, NodeHost, SessionConfig, SyncSchedule, TcpTransport,
    Transport, TransportDriver,
};
use fedattn::metrics::{em_score, CostModel};
use fedattn::net::{LinkSpec, NetSim, Topology};
use fedattn::runtime::Engine;
use fedattn::util::prng::SplitMix64;
use fedattn::util::stats::fmt_bytes;

fn main() {
    fedattn::util::log::init();
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional(0).unwrap_or("help") {
        "info" => cmd_info(args),
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "node" => cmd_node(args),
        "chaos" => cmd_chaos(args),
        "gen-data" => cmd_gen_data(args),
        "validate" => cmd_validate(args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "fedattn — federated attention coordinator\n\
         \n\
         USAGE: fedattn <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           info                       model + artifact summary\n\
           run                        one collaborative task (in-process, or\n\
                                      over TCP with --connect)\n\
           serve                      replay a workload trace\n\
           node                       host participant nodes over TCP (--listen)\n\
           chaos                      churn-recovery capacity sweep: fault rate x\n\
                                      rejoin on/off (writes BENCH_churn.json)\n\
           gen-data                   sample MicroFact episodes\n\
           validate                   H=1 == CenAttn end-to-end check\n\
         \n\
         COMMON OPTIONS\n\
           --config <file.toml>       load a system config\n\
           --participants <N>         number of participants (default 3)\n\
           --h <H>                    uniform sync interval (default 2)\n\
           --seg <setting>            tok-seg:q-ag|tok-seg:q-ex|sem-seg:q-ag|sem-seg:q-ex\n\
           --kv-policy <p>            full|random|publisher-priority|recent-budget|\n\
                                      top-k-relevance|byte-budget\n\
           --kv-ratio <r>             sparse KV-exchange keep ratio (random policies)\n\
           --kv-budget-rows <k>       row budget for recent-budget / top-k-relevance\n\
           --kv-bytes <b>             total bytes per sync round for byte-budget\n\
           --kv-precision <p>         f32|f16|int8 wire precision of K/V rows\n\
                                      (default f32 = exact; reduced precisions\n\
                                      quantize rows at encode time with per-row\n\
                                      scales and cut uplink+downlink bytes)\n\
           --local-ratio <r>          sparse local-attention keep ratio\n\
           --dropout <p>              per-node attendance dropout probability\n\
                                      in [0, 1] (0 = off; masks the sync\n\
                                      schedule, not the data)\n\
           --round-deadline <ms>      per-sync-round contribution deadline in\n\
                                      simulated ms (late contributions are\n\
                                      excluded; off|none|inf disables); also\n\
                                      bounds the TCP read timeout (plus the\n\
                                      --deadline-grace-ms margin)\n\
           --delta-frames <on|off>    delta-encode the downlink (default on):\n\
                                      attendees receive only rows they do not\n\
                                      already hold; off ships+bills full frames\n\
           --rejoin <on|off>          churn recovery (default off): a wire node\n\
                                      whose transport fails goes on probation\n\
                                      and is readmitted via Rejoin/Resync at\n\
                                      the next round boundary\n\
           --retry-max-attempts <n>   connect/rejoin attempt budget (default 3)\n\
           --retry-backoff-ms <ms>    first-retry backoff, doubled per attempt\n\
                                      with seeded jitter (default 50)\n\
           --deadline-grace-ms <ms>   grace added to the round deadline when\n\
                                      deriving socket read timeouts\n\
                                      (default 15000)\n\
           --listen <addr>            node: accept driver connections here\n\
                                      (default 127.0.0.1:7070)\n\
           --engine <dir>             node: load the host's own engine from\n\
                                      this artifact dir (node-resident compute;\n\
                                      default: the shared --artifacts path)\n\
           --connect <a1[,a2,...]>    run/serve: drive participants over TCP;\n\
                                      each participant connects round-robin to\n\
                                      the listed node hosts, which run all\n\
                                      block compute and decode locally\n\
           --time-scale <f>           compress trace inter-arrival gaps by f\n\
                                      (serve; default TOML serving.time_scale,\n\
                                      else 10)\n\
           --tasks <n>, --seed <s>    workload size / determinism\n\
           --engines <n>              serving worker threads\n\
           --workers <n>              per-session participant parallelism\n\
                                      (pool width; 1 = sequential, results\n\
                                      are byte-identical either way)\n\
           --fabric <on|off>          serve: session-fabric scheduler (default\n\
                                      off): resumable sessions over the engine\n\
                                      pool, with admission control and\n\
                                      cross-session batched decode\n\
           --admission <p>            serve: block|shed-oldest|reject-over-slo\n\
                                      (fabric; default block; turned-away\n\
                                      tasks are recorded in the report)\n\
           --slo-ms <ms>              serve: predicted-wait SLO for\n\
                                      reject-over-slo\n\
           --max-inflight <n>         serve: max sessions admitted at once\n\
                                      (fabric; default 4 x engines)\n\
           --session-deadline <ms>    serve: end-to-end per-session deadline\n\
                                      (fabric; clock starts at the admission\n\
                                      offer, queue wait included; over-budget\n\
                                      sessions are cancelled at the next\n\
                                      resume point; off|none disables)\n\
           --watchdog <ms>            serve: stuck-session watchdog (fabric;\n\
                                      a dispatched work item making no\n\
                                      progress for this long is cancelled and\n\
                                      its wedged worker replaced by a spare;\n\
                                      off|none disables)\n\
           --slo-prior <ms>           serve: optimistic service-time prior\n\
                                      seeding the reject-over-slo EMA, so\n\
                                      gating engages before the first\n\
                                      completion (off|none disables)\n\
           --drain-after <ms>         serve: graceful drain this long after\n\
                                      start (SIGTERM stand-in): stop\n\
                                      admitting, finish in-flight work,\n\
                                      report the rest as drained\n\
           --heartbeat <ms>           run/serve --connect: ping each node\n\
                                      host at layer boundaries once this\n\
                                      interval has elapsed; a silent node is\n\
                                      demoted (or put on probation with\n\
                                      --rejoin) without waiting for a round\n\
                                      deadline (off|none disables)\n\
           --heartbeat-max-missed <n> consecutive missed heartbeats tolerated\n\
                                      before demotion (default 2)"
    );
}

fn load_config(args: &Args) -> Result<SystemConfig> {
    let mut sc = match args.opt("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path))
            .with_context(|| format!("loading config {path}"))?,
        None => SystemConfig::default(),
    };
    sc.artifacts_dir = args
        .opt("artifacts")
        .map(Into::into)
        .unwrap_or_else(fedattn::default_artifacts_dir);
    sc.seed = args.u64_or("seed", sc.seed);
    let f = &mut sc.federation;
    f.participants = args.usize_or("participants", f.participants);
    f.sync_h = args.usize_or("h", f.sync_h);
    if let Some(seg) = args.opt("seg") {
        f.segmentation =
            Segmentation::parse(seg).with_context(|| format!("unknown --seg {seg:?}"))?;
    }
    f.local_sparsity = args.f64_or("local-ratio", f.local_sparsity);
    let kv_ratio = args.f64_or("kv-ratio", 1.0);
    if kv_ratio < 1.0 {
        f.kv_policy = fedattn::fedattn::KvExchangePolicy::Random { ratio: kv_ratio };
    }
    // Explicit --kv-policy takes precedence over the --kv-ratio shorthand.
    if let Some(policy) = fedattn::cli::parse_kv_policy(args)? {
        f.kv_policy = policy;
    }
    if let Some(p) = fedattn::cli::parse_kv_precision(args)? {
        f.kv_precision = p;
    }
    f.max_new_tokens = args.usize_or("max-new", f.max_new_tokens);
    if let Some(p) = fedattn::cli::parse_dropout(args)? {
        f.dropout_prob = p;
    }
    if let Some(d) = fedattn::cli::parse_round_deadline(args)? {
        f.round_deadline_ms = d;
    }
    if let Some(on) = fedattn::cli::parse_delta_frames(args)? {
        f.delta_frames = on;
    }
    if let Some(on) = fedattn::cli::parse_rejoin(args)? {
        f.rejoin = on;
    }
    if let Some(hb) = fedattn::cli::parse_heartbeat_ms(args)? {
        f.heartbeat_ms = hb;
    }
    if let Some(n) = fedattn::cli::parse_heartbeat_max_missed(args)? {
        f.heartbeat_max_missed = n;
    }
    if let Some(n) = fedattn::cli::parse_retry_max_attempts(args)? {
        sc.transport.retry_max_attempts = n;
    }
    if let Some(ms) = fedattn::cli::parse_retry_backoff_ms(args)? {
        sc.transport.retry_backoff_ms = ms;
    }
    if let Some(ms) = fedattn::cli::parse_deadline_grace_ms(args)? {
        sc.transport.deadline_grace_ms = ms;
    }
    if let Some(addr) = args.opt("listen") {
        sc.node.listen = addr.to_string();
    }
    if let Some(dir) = fedattn::cli::parse_node_engine(args) {
        sc.node.engine_dir = Some(dir);
    }
    if let Some(hosts) = fedattn::cli::parse_connect(args)? {
        sc.node.connect = Some(hosts);
    }
    sc.serving.engines = args.usize_or("engines", sc.serving.engines);
    sc.serving.workers = fedattn::cli::parse_workers(args, sc.serving.workers);
    if let Some(on) = fedattn::cli::parse_fabric(args)? {
        sc.serving.fabric = on;
    }
    if let Some(policy) = fedattn::cli::parse_admission(args)? {
        sc.serving.admission = policy;
    }
    if let Some(n) = fedattn::cli::parse_max_inflight(args)? {
        sc.serving.max_inflight = Some(n);
    }
    if let Some(d) = fedattn::cli::parse_session_deadline(args)? {
        sc.serving.session_deadline_ms = d;
    }
    if let Some(w) = fedattn::cli::parse_watchdog_ms(args)? {
        sc.serving.watchdog_ms = w;
    }
    if let Some(p) = fedattn::cli::parse_slo_prior(args)? {
        sc.serving.slo_prior_ms = p;
    }
    if let Some(d) = fedattn::cli::parse_drain_after(args)? {
        sc.serving.drain_after_ms = d;
    }
    Ok(sc)
}

fn build_engine(sc: &SystemConfig) -> Result<Engine> {
    Engine::load(&sc.artifacts_dir, &sc.weights_file)
}

fn cmd_info(args: &Args) -> Result<()> {
    let sc = load_config(args)?;
    let engine = build_engine(&sc)?;
    let md = &engine.manifest.model;
    let cm = CostModel::new(md.clone());
    println!("model       : {}", md.name);
    println!(
        "layers      : {}  d_model {}  heads {}/{}  head_dim {}  d_ff {}",
        md.n_layers, md.d_model, md.n_heads, md.n_kv_heads, md.head_dim, md.d_ff
    );
    println!("params      : {}", engine.weights().param_count());
    println!("weights     : {}", fmt_bytes(cm.weight_bytes()));
    println!(
        "kv row      : {} bytes (GQA {}x)",
        md.kv_row_bytes(),
        md.n_heads / md.n_kv_heads
    );
    println!(
        "artifacts   : {} entries in {:?}",
        engine.manifest.entries.len(),
        engine.manifest.dir
    );
    println!("l variants  : {:?}", engine.manifest.l_variants);
    println!("g variants  : {:?}", engine.manifest.g_variants);
    println!("decode cache: {}", engine.manifest.decode_cache);
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let n = args.usize_or("tasks", 5);
    let mut rng = SplitMix64::new(args.u64_or("seed", 7));
    for i in 0..n {
        let ep = gen_episode(&mut rng, 4);
        println!("--- episode {i} [{}]", ep.kind.as_str());
        println!("{}", ep.prompt());
        println!("gold: {}", ep.answer);
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let sc = load_config(args)?;
    if let Some(addrs) = sc.node.connect.clone() {
        return cmd_run_wire(args, &sc, &addrs);
    }
    let engine = build_engine(&sc)?;
    let coord = Coordinator::new(engine, CoordinatorConfig::from_system(&sc));
    let mut rng = SplitMix64::new(sc.seed);
    let ep = gen_episode(&mut rng, args.usize_or("facts", 4));
    println!(
        "prompt ({} participants, {}):",
        sc.federation.participants,
        sc.federation.segmentation.as_str()
    );
    println!("  {}", ep.prompt());
    let r = coord.run_one(0, &ep, sc.seed)?;
    println!("answer      : {:?} (gold {:?}) -> EM {}", r.answer, r.gold, r.em);
    println!("service     : {:.1} ms ({} tokens)", r.service_ms, r.generated_tokens);
    println!(
        "comm        : {} over simulated net ({:.2} ms)",
        fmt_bytes(r.comm_bytes as f64),
        r.comm_time_ms
    );
    if r.demotions + r.rejoins + r.retries > 0 {
        println!(
            "churn       : {} demotion(s), {} rejoin(s), {} retry(s)",
            r.demotions, r.rejoins, r.retries
        );
    }
    Ok(())
}

/// `run --connect a1[,a2,...]` (or TOML `node.connect`) — the same
/// one-shot collaborative task, node-resident: every participant's block
/// compute and decode run at the listed `fedattn node` hosts (round-robin
/// per participant) on the hosts' own engines, and only protocol messages
/// cross the wire.  The answer and comm bytes are byte-identical to the
/// in-process `run`.
fn cmd_run_wire(args: &Args, sc: &SystemConfig, addrs: &[String]) -> Result<()> {
    anyhow::ensure!(!addrs.is_empty(), "--connect needs at least one host:port");
    let engine = build_engine(sc)?;
    let md = engine.manifest.model.clone();
    let n = sc.federation.participants;
    let mut rng = SplitMix64::new(sc.seed);
    let ep = gen_episode(&mut rng, args.usize_or("facts", 4));
    let part = partition(&ep, n, sc.federation.segmentation);

    let mut scfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, sc.federation.sync_h));
    scfg.local_sparsity = LocalSparsity { ratio: sc.federation.local_sparsity };
    scfg.kv_policy = sc.federation.kv_policy;
    scfg.max_new_tokens = sc.federation.max_new_tokens;
    scfg.dropout_prob = sc.federation.dropout_prob;
    scfg.round_deadline_ms = sc.federation.round_deadline_ms;
    scfg.delta_frames = sc.federation.delta_frames;
    scfg.rejoin = sc.federation.rejoin;
    scfg.kv_precision = sc.federation.kv_precision;
    scfg.heartbeat_ms = sc.federation.heartbeat_ms;
    scfg.heartbeat_max_missed = sc.federation.heartbeat_max_missed;
    scfg.rejoin_max_attempts = sc.transport.retry_max_attempts;
    scfg.seed = sc.seed;
    scfg.workers = sc.serving.workers;

    let links = sc.network.links(n);
    let net = NetSim::new(sc.network.topology, links, sc.seed);
    // Under a round deadline, bound the socket wait to the deadline plus
    // the configured grace margin instead of the 60 s default: a peer
    // that blows far past the round surfaces fast.
    let io_timeout = fedattn::fedattn::transport::read_timeout_for_deadline_with_grace(
        scfg.round_deadline_ms,
        std::time::Duration::from_secs_f64(sc.transport.deadline_grace_ms / 1e3),
    );
    let retry = fedattn::fedattn::RetryPolicy {
        max_attempts: sc.transport.retry_max_attempts,
        backoff_ms: sc.transport.retry_backoff_ms,
        jitter_seed: sc.seed,
        ..Default::default()
    };
    let dial = |p: usize, what: &str| -> Result<Box<dyn Transport>> {
        let addr = addrs[p % addrs.len()].as_str();
        TcpTransport::connect_with_retry(addr, &retry)
            .and_then(|t| t.with_read_timeout(io_timeout))
            .map(|t| Box::new(t) as Box<dyn Transport>)
            .with_context(|| format!("{what} participant {p} to node host {addr}"))
    };
    let transports: Vec<Box<dyn Transport>> =
        (0..n).map(|p| dial(p, "connecting")).collect::<Result<_>>()?;

    println!(
        "prompt ({n} participants over {} node host(s), {}):",
        addrs.len(),
        sc.federation.segmentation.as_str()
    );
    println!("  {}", ep.prompt());
    let t0 = std::time::Instant::now();
    let rejoin = scfg.rejoin;
    let mut driver = TransportDriver::new(&engine, &part, scfg, net, transports)?;
    if rejoin {
        // Probation nodes are re-dialed through the same round-robin map
        // (and retry policy) the original connect used.
        driver = driver.with_reconnector(Box::new(move |p| dial(p, "reconnecting")));
    }
    let rep = driver.run()?;
    let service_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "answer      : {:?} (gold {:?}) -> EM {}",
        rep.answer,
        ep.answer,
        em_score(&rep.answer, &ep.answer)
    );
    println!("service     : {service_ms:.1} ms ({} tokens)", rep.generated_tokens);
    println!(
        "comm        : {} over simulated net ({:.2} ms, {} rounds)",
        fmt_bytes(rep.net.total_bytes() as f64),
        rep.net.comm_time_ms,
        rep.net.rounds
    );
    if rep.net.demotions + rep.net.rejoins + rep.net.retries > 0 {
        println!(
            "churn       : {} demotion(s), {} rejoin(s), {} retry(s), {} resynced",
            rep.net.demotions,
            rep.net.rejoins,
            rep.net.retries,
            fmt_bytes(rep.net.resync_bytes as f64)
        );
    }
    Ok(())
}

/// `node --listen addr [--engine dir]` — host participant nodes for
/// wire-mode drivers.  The host owns its participants outright: block
/// forward passes, decode caches and token generation all run here, on
/// this process's engine — loaded from `--engine` (or TOML
/// `node.engine_dir`) when the node keeps its own artifact set, falling
/// back to the shared `--artifacts` path for single-machine demos.  Each
/// accepted connection gets its own serving thread (and engine clone), so
/// one process can host every participant of a session.
fn cmd_node(args: &Args) -> Result<()> {
    let sc = load_config(args)?;
    let engine_dir =
        sc.node.engine_dir.clone().unwrap_or_else(|| sc.artifacts_dir.clone());
    let engine = Engine::load(&engine_dir, &sc.weights_file)
        .with_context(|| format!("loading node engine from {}", engine_dir.display()))?;
    let addr = sc.node.listen.as_str();
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding node host to {addr}"))?;
    println!(
        "node host listening on {addr} (engine: {}; Ctrl-C to stop)",
        engine_dir.display()
    );
    loop {
        // A transient accept failure (peer RST during the handshake, fd
        // pressure) must not take down sessions served by other threads.
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) => {
                log::error!("accept failed on {addr}: {e}");
                continue;
            }
        };
        println!("serving driver at {peer}");
        let engine = engine.clone();
        std::thread::spawn(move || {
            let transport = match TcpTransport::from_stream(stream) {
                Ok(t) => t,
                Err(e) => {
                    log::error!("node transport setup failed for {peer}: {e}");
                    return;
                }
            };
            match NodeHost::new(engine, Box::new(transport)).serve() {
                Ok(()) => println!("driver {peer} finished"),
                Err(e) => log::error!("node session for {peer} failed: {e:#}"),
            }
        });
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let sc = load_config(args)?;
    let engine = build_engine(&sc)?;
    let mut ccfg = CoordinatorConfig::from_system(&sc);
    // Precedence: --time-scale > TOML serving.time_scale > the serve
    // subcommand's historical 10x compression.
    ccfg.time_scale = fedattn::cli::parse_time_scale(args)?
        .or(sc.serving.time_scale)
        .unwrap_or(10.0);
    let coord = Coordinator::new(engine, ccfg);
    let trace = WorkloadTrace::generate(&TraceConfig {
        seed: sc.seed,
        n_tasks: args.usize_or("tasks", 16),
        mean_interarrival_ms: args.f64_or("interarrival-ms", 200.0),
        ..Default::default()
    });
    println!(
        "serving {} tasks ({}) ...",
        trace.len(),
        if sc.serving.fabric {
            format!("fabric, admission {}", sc.serving.admission.name())
        } else {
            "thread-per-task".to_string()
        }
    );
    let rep = coord.serve_trace(&trace)?;
    println!("tasks       : {}", rep.results.len());
    println!("EM          : {:.3}", rep.em_rate());
    println!("throughput  : {:.2} tasks/s", rep.throughput_tasks_per_s());
    println!("latency p50 : {:.1} ms", rep.latency_percentile(50.0));
    println!("latency p95 : {:.1} ms", rep.latency_percentile(95.0));
    println!(
        "queue p50   : {:.1} ms  p95 {:.1} ms",
        rep.queue_percentile(50.0),
        rep.queue_percentile(95.0)
    );
    if rep.failed_count() > 0 {
        println!("failed      : {}", rep.failed_count());
        for f in &rep.failed {
            println!("  task {}: {}", f.task_id, f.error);
        }
    }
    if !rep.dropped.is_empty() {
        let shed = rep
            .dropped
            .iter()
            .filter(|d| d.reason == fedattn::serve::DropReason::Shed)
            .count();
        println!(
            "dropped     : {} ({} shed, {} rejected)",
            rep.dropped.len(),
            shed,
            rep.dropped.len() - shed
        );
    }
    if !rep.deadline_killed.is_empty() {
        println!("slo-killed  : {} over the session deadline", rep.deadline_killed.len());
        for f in &rep.deadline_killed {
            println!("  task {}: {}", f.task_id, f.error);
        }
    }
    if !rep.watchdog_killed.is_empty() {
        println!("wdog-killed : {} stuck sessions cancelled", rep.watchdog_killed.len());
        for f in &rep.watchdog_killed {
            println!("  task {}: {}", f.task_id, f.error);
        }
    }
    if !rep.drained.is_empty() {
        println!(
            "drained     : {} never admitted (graceful drain)",
            rep.drained.len()
        );
    }
    if rep.replaced_workers > 0 {
        println!(
            "spares      : {} wedged engine worker(s) replaced",
            rep.replaced_workers
        );
    }
    let comm: u64 = rep.results.iter().map(|r| r.comm_bytes).sum();
    println!("comm total  : {}", fmt_bytes(comm as f64));
    let demotions: u64 = rep.results.iter().map(|r| r.demotions).sum();
    let rejoins: u64 = rep.results.iter().map(|r| r.rejoins).sum();
    let retries: u64 = rep.results.iter().map(|r| r.retries).sum();
    if demotions + rejoins + retries > 0 {
        println!("churn       : {demotions} demotion(s), {rejoins} rejoin(s), {retries} retry(s)");
    }
    Ok(())
}

/// One sweep point of the deterministic churn model: `fault_rate > 0`
/// kills a link every `ceil(1/fault_rate)` sync rounds, cycling through
/// the non-publisher participants (the publisher is never killed — a
/// dead publisher ends the session identically under every policy).
/// With rejoin off every death is a permanent demotion, exactly the
/// pre-recovery driver; with rejoin on the node is readmitted at the
/// next round boundary — the probation → `Rejoin`/`Resync` path with a
/// reconnector that always answers — so it misses only the rounds it
/// was dark for.
struct ChurnPoint {
    rounds_total: usize,
    rounds_attended: usize,
    demotions: usize,
    rejoins: usize,
}

fn churn_point(n: usize, rounds: usize, fault_rate: f64, rejoin: bool) -> ChurnPoint {
    let period = if fault_rate > 0.0 { (1.0 / fault_rate).ceil() as usize } else { 0 };
    let mut alive = vec![true; n];
    let mut deaths = 0usize;
    let mut out = ChurnPoint {
        rounds_total: rounds * n,
        rounds_attended: 0,
        demotions: 0,
        rejoins: 0,
    };
    for r in 0..rounds {
        if rejoin {
            for a in alive.iter_mut().skip(1) {
                if !*a {
                    *a = true;
                    out.rejoins += 1;
                }
            }
        }
        // A fault mid-round costs that round's attendance (the driver's
        // `attend_eff` goes false for an in-round failure), so the kill
        // lands before the count.
        if period > 0 && (r + 1) % period == 0 {
            let victim = 1 + deaths % (n - 1);
            if alive[victim] {
                alive[victim] = false;
                if !rejoin {
                    out.demotions += 1;
                }
            }
            deaths += 1;
        }
        out.rounds_attended += alive.iter().filter(|a| **a).count();
    }
    out
}

/// `chaos [--participants N] [--rounds R]` — churn-recovery capacity
/// sweep, engine-free and RNG-free (see [`churn_point`]), comparing
/// attendee capacity across fault rates with rejoin off vs on.  Writes
/// the trajectory report to `BENCH_churn.json` at the repo root; CI
/// asserts the committed copy's schema and the recovery property
/// (rounds attended strictly higher with rejoin on at any nonzero fault
/// rate).
fn cmd_chaos(args: &Args) -> Result<()> {
    use fedattn::util::json::{Json, JsonBuilder};
    let n = args.usize_or("participants", 4).max(2);
    let rounds = args.usize_or("rounds", 32).max(1);
    let fault_rates = [0.0f64, 0.1, 0.25, 0.5];
    println!("== Churn recovery: attendee capacity (N = {n}, {rounds} sync rounds) ==");
    println!(
        "{:>10} {:>7} {:>10} {:>9} {:>10} {:>8}",
        "fault_rate", "rejoin", "attended", "capacity", "demotions", "rejoins"
    );
    let mut points = Vec::new();
    for &f in &fault_rates {
        for rejoin in [false, true] {
            let p = churn_point(n, rounds, f, rejoin);
            let capacity = p.rounds_attended as f64 / p.rounds_total as f64;
            println!(
                "{:>10.2} {:>7} {:>10} {:>8.1}% {:>10} {:>8}",
                f,
                if rejoin { "on" } else { "off" },
                format!("{}/{}", p.rounds_attended, p.rounds_total),
                capacity * 100.0,
                p.demotions,
                p.rejoins
            );
            points.push(
                JsonBuilder::new()
                    .num("fault_rate", f)
                    .set("rejoin", Json::Bool(rejoin))
                    .num("rounds_total", p.rounds_total as f64)
                    .num("rounds_attended", p.rounds_attended as f64)
                    .num("attend_rate", capacity)
                    .num("demotions", p.demotions as f64)
                    .num("rejoins", p.rejoins as f64)
                    .build(),
            );
        }
    }
    let report = JsonBuilder::new()
        .str("bench", "churn")
        .num("participants", n as f64)
        .num("sync_rounds", rounds as f64)
        .set("points", Json::Arr(points))
        .build();
    // Walk to the outermost Cargo.toml (the workspace root) so the
    // report lands next to the other committed BENCH_*.json copies no
    // matter which directory the subcommand runs from.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    let mut root = None;
    for _ in 0..5 {
        if dir.join("Cargo.toml").exists() {
            root = Some(dir.clone());
        }
        if !dir.pop() {
            break;
        }
    }
    let path = root.unwrap_or_else(|| std::path::PathBuf::from(".")).join("BENCH_churn.json");
    std::fs::write(&path, report.to_string_compact())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("(trajectory report written to {})", path.display());
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let sc = load_config(args)?;
    let engine = build_engine(&sc)?;
    let md = engine.manifest.model.clone();
    let mut rng = SplitMix64::new(sc.seed);
    let ep = gen_episode(&mut rng, 4);
    let n = sc.federation.participants;

    // FedAttn with H=1 (every block global).
    let part = partition(&ep, n, sc.federation.segmentation);
    let mut cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, n, 1));
    cfg.record_hidden = true;
    let net = NetSim::uniform(Topology::Star, n, LinkSpec::default(), 1);
    let fed = FedSession::new(&engine, &part, cfg, net)?.run()?;

    // CenAttn: one participant holding everything.
    let cen_part = partition(&ep, 1, Segmentation::TokQAg);
    let mut cen_cfg = SessionConfig::new(SyncSchedule::uniform(md.n_layers, 1, md.n_layers));
    cen_cfg.record_hidden = true;
    let cen_net = NetSim::uniform(Topology::Star, 1, LinkSpec::default(), 1);
    let cen = FedSession::new(&engine, &cen_part, cen_cfg, cen_net)?.run()?;

    // Compare the answers + hidden states row-by-row by global position.
    println!("fed answer  : {:?}", fed.answer);
    println!("cen answer  : {:?}", cen.answer);
    let cen_h = cen.hidden[0].as_ref().unwrap();
    let mut max_diff = 0f32;
    for (p, h) in fed.hidden.iter().enumerate() {
        let h = h.as_ref().unwrap();
        for (i, &gpos) in fed.positions[p].iter().enumerate() {
            let a = h.row(i);
            let b = cen_h.row(gpos as usize);
            for (x, y) in a.iter().zip(b) {
                max_diff = max_diff.max((x - y).abs());
            }
        }
    }
    println!("max |h_fed - h_cen| = {max_diff:e}");
    anyhow::ensure!(max_diff < 2e-4, "H=1 must match CenAttn (got {max_diff})");
    anyhow::ensure!(fed.answer == cen.answer, "answers must match");
    println!("validate OK");
    Ok(())
}
