//! # FedAttn — Federated Attention for collaborative LLM inference
//!
//! A production-shaped reproduction of *"Federated Attention: A Distributed
//! Paradigm for Collaborative LLM Inference over Edge Networks"* (Deng et
//! al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (build time)** — Pallas attention kernel
//!   (`python/compile/kernels/`), lowered in interpret mode.
//! * **Layer 2 (build time)** — TinyQwen JAX model pieces AOT-lowered to
//!   HLO text (`python/compile/aot.py` → `artifacts/`).
//! * **Layer 3 (this crate)** — the Rust coordinator: participants, sync
//!   schedules, KV exchange/aggregation, sparsity policies, the edge
//!   network simulator and the serving layer, all executing the AOT
//!   artifacts via PJRT.  Python never runs on the request path.
//!
//! Start with [`runtime::Engine`] + [`fedattn::FedSession`], or the
//! serving-level [`coordinator::Coordinator`].  See `examples/` for
//! runnable entry points and `rust/benches/` for the paper-figure
//! reproductions.

pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fedattn;
pub mod metrics;
pub mod model;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod theory;
pub mod tokenizer;
pub mod util;

use std::path::PathBuf;

/// Locate the artifacts directory: `$FEDATTN_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (where `make artifacts` puts it).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FEDATTN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from CWD looking for artifacts/manifest.json (tests and
    // benches run from target subdirectories).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}
