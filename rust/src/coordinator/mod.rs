//! Serving coordinator: task queue, engine pool, router, metrics.

mod server;

pub use server::{CoordinatorConfig, Coordinator, TaskQueue, TaskResult, ServeReport};
