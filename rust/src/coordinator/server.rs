//! The serving layer: a bounded task queue feeding an engine-worker pool,
//! with per-task federated sessions, backpressure, and latency accounting.
//!
//! One `Coordinator` owns one compiled `Engine` (artifacts + weights are
//! shared; PJRT executions are thread-safe) and `engines` worker threads.
//! Collaborative tasks arrive on a workload trace (Poisson arrivals); each
//! is partitioned per the configured segmentation, prefilled under the
//! configured schedule and decoded by its publisher.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::SystemConfig;
use crate::data::{partition, Episode, Segmentation, WorkloadTrace};
use crate::fedattn::{
    DecodeHandle, DecodeStep, FedSession, KvExchangePolicy, KvPrecision, LocalSparsity,
    SessionConfig, SyncSchedule, TcpTransport, Transport, TransportDriver,
};
use crate::metrics::em_score;
use crate::net::NetSim;
use crate::runtime::Engine;
use crate::serve::{
    run_fabric, AdmissionPolicy, DroppedTask, FabricConfig, FabricTask, FailedTask,
};
use crate::util::stats::{percentile, Summary};

/// Coordinator knobs (subset of [`SystemConfig`] plus scheduling).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub engines: usize,
    pub queue_depth: usize,
    /// Per-session participant-parallelism width (1 = sequential); the
    /// session's per-participant loops run on a pool of this many threads.
    pub workers: usize,
    pub participants: usize,
    pub sync_h: usize,
    pub segmentation: Segmentation,
    pub local_sparsity: f64,
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    /// Per-node attendance dropout probability applied to every served
    /// session's schedule (0.0 = off).
    pub dropout_prob: f64,
    /// Per-sync-round contribution deadline (simulated ms) applied to
    /// every served session; late contributions are excluded from the
    /// round (`None` = no deadline).
    pub round_deadline_ms: Option<f64>,
    /// Delta-encode the downlink for every served session (default on);
    /// off bills full broadcast frames — the pre-delta baseline.
    pub delta_frames: bool,
    /// Wire precision of K/V row payloads for every served session
    /// (`federation.kv_precision` / `--kv-precision`, default `f32`).
    pub kv_precision: KvPrecision,
    pub topology: crate::net::Topology,
    pub link: crate::net::LinkSpec,
    /// Heterogeneous per-participant links; `None` = `participants` copies
    /// of `link`.  Drives byte-budget allocation for adaptive KV policies.
    pub hetero_links: Option<Vec<crate::net::LinkSpec>>,
    pub seed: u64,
    /// Compress trace inter-arrival gaps by this factor (benches use > 1 to
    /// avoid waiting out real think-time).
    pub time_scale: f64,
    /// Node-resident wire mode (`node.connect` / `--connect`): each served
    /// session drives its participants over TCP transports connected
    /// round-robin to these node hosts — every block forward pass runs at
    /// the nodes, and the coordinator keeps only planning, aggregation and
    /// billing.  `None` (the default) serves fully in-process sessions.
    pub node_addrs: Option<Vec<String>>,
    /// Churn recovery for wire sessions (`federation.rejoin` /
    /// `--rejoin`): a node whose transport fails goes on probation and is
    /// re-dialed + readmitted (`Rejoin`/`Resync`) at round boundaries
    /// instead of demoted outright.  Off is byte-identical to the knob
    /// not existing.
    pub rejoin: bool,
    /// Transport retry/backoff + read-timeout grace knobs (`[transport]`).
    pub transport: crate::config::TransportConfig,
    /// Serve through the session fabric (`serving.fabric` / `--fabric`):
    /// resumable sessions multiplexed over the engine workers, with
    /// admission control and cross-session batched decode.  Off keeps
    /// the thread-per-task loop.
    pub fabric: bool,
    /// Admission policy in front of the task queue (fabric mode).
    pub admission: AdmissionPolicy,
    /// Max sessions admitted past the queue at once (fabric mode);
    /// `None` = 4 × engines.
    pub max_inflight: Option<usize>,
    /// End-to-end per-session budget in ms, queue wait included
    /// (`serving.session_deadline_ms` / `--session-deadline`); fabric
    /// mode cancels over-budget sessions into the `deadline_killed`
    /// bucket.  `None` = no deadline.
    pub session_deadline_ms: Option<f64>,
    /// Stuck-session watchdog window in ms (`serving.watchdog_ms` /
    /// `--watchdog`); fabric mode only.  `None` = off.
    pub watchdog_ms: Option<f64>,
    /// Service-time prior seeding the reject-over-SLO wait predictor
    /// (`serving.slo_prior_ms` / `--slo-prior`).  `None` = admit
    /// blind until the first completion.
    pub slo_prior_ms: Option<f64>,
    /// Graceful drain: stop admitting this many ms into a fabric serve
    /// run (`serving.drain_after_ms` / `--drain-after`) — the CLI
    /// approximation of a SIGTERM-triggered drain.  `None` = never.
    pub drain_after_ms: Option<f64>,
    /// Wire-session heartbeat window in ms (`federation.heartbeat_ms` /
    /// `--heartbeat`): the driver pings every node at round
    /// boundaries and demotes after `heartbeat_max_missed` consecutive
    /// misses.  `None` = off; in-process sessions ignore it.
    pub heartbeat_ms: Option<f64>,
    /// Consecutive missed heartbeats before demotion (min 1).
    pub heartbeat_max_missed: u32,
}

impl CoordinatorConfig {
    pub fn from_system(sc: &SystemConfig) -> Self {
        Self {
            engines: sc.serving.engines,
            queue_depth: sc.serving.queue_depth,
            workers: sc.serving.workers,
            participants: sc.federation.participants,
            sync_h: sc.federation.sync_h,
            segmentation: sc.federation.segmentation,
            local_sparsity: sc.federation.local_sparsity,
            kv_policy: sc.federation.kv_policy,
            max_new_tokens: sc.federation.max_new_tokens,
            dropout_prob: sc.federation.dropout_prob,
            round_deadline_ms: sc.federation.round_deadline_ms,
            delta_frames: sc.federation.delta_frames,
            kv_precision: sc.federation.kv_precision,
            topology: sc.network.topology,
            link: sc.network.link,
            hetero_links: sc
                .network
                .bandwidths_mbps
                .is_some()
                .then(|| sc.network.links(sc.federation.participants)),
            seed: sc.seed,
            time_scale: sc.serving.time_scale.unwrap_or(1.0),
            node_addrs: sc.node.connect.clone(),
            rejoin: sc.federation.rejoin,
            transport: sc.transport.clone(),
            fabric: sc.serving.fabric,
            admission: sc.serving.admission,
            max_inflight: sc.serving.max_inflight,
            session_deadline_ms: sc.serving.session_deadline_ms,
            watchdog_ms: sc.serving.watchdog_ms,
            slo_prior_ms: sc.serving.slo_prior_ms,
            drain_after_ms: sc.serving.drain_after_ms,
            heartbeat_ms: sc.federation.heartbeat_ms,
            heartbeat_max_missed: sc.federation.heartbeat_max_missed,
        }
    }

    /// Per-participant link specs (heterogeneous when configured).
    pub fn links(&self) -> Vec<crate::net::LinkSpec> {
        match &self.hetero_links {
            Some(l) => l.clone(),
            None => vec![self.link; self.participants],
        }
    }
}

/// Outcome of one served task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task_id: usize,
    pub answer: String,
    pub gold: String,
    pub em: bool,
    pub queue_ms: f64,
    pub service_ms: f64,
    pub latency_ms: f64,
    pub comm_bytes: u64,
    pub comm_time_ms: f64,
    pub generated_tokens: usize,
    /// Wire-mode churn: nodes permanently demoted during this task.
    pub demotions: u64,
    /// Wire-mode churn: successful mid-session readmissions.
    pub rejoins: u64,
    /// Wire-mode churn: failed reconnect attempts (probation retries).
    pub retries: u64,
}

/// Aggregate serving report.
///
/// `results` holds only tasks that *completed*; `em_rate` and the
/// latency/queue percentiles are computed over completions.  Tasks that
/// started but errored land in `failed` (id + error, never just a log
/// line), and tasks the admission policy turned away land in `dropped`.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub results: Vec<TaskResult>,
    /// Tasks that started but did not produce a result.
    pub failed: Vec<FailedTask>,
    /// Tasks shed or rejected by admission control (fabric mode).
    pub dropped: Vec<DroppedTask>,
    /// Sessions cancelled over their end-to-end deadline (fabric mode).
    pub deadline_killed: Vec<FailedTask>,
    /// Sessions cancelled by the stuck-session watchdog (fabric mode).
    pub watchdog_killed: Vec<FailedTask>,
    /// Task ids that never started because the fabric was draining.
    pub drained: Vec<usize>,
    /// Wedged engine workers replaced from the spare budget.
    pub replaced_workers: u64,
    pub makespan_ms: f64,
}

impl ServeReport {
    pub fn em_rate(&self) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        self.results.iter().filter(|r| r.em).count() as f64 / self.results.len() as f64
    }

    /// Tasks that started but errored (excluded from every other stat).
    pub fn failed_count(&self) -> usize {
        self.failed.len()
    }

    /// Every offered task, summed across all outcome buckets (completed,
    /// failed, dropped, deadline-killed, watchdog-killed, drained) — the
    /// liveness invariant is `accounted() == tasks offered`.
    pub fn accounted(&self) -> usize {
        self.results.len()
            + self.failed.len()
            + self.dropped.len()
            + self.deadline_killed.len()
            + self.watchdog_killed.len()
            + self.drained.len()
    }

    pub fn throughput_tasks_per_s(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.results.len() as f64 / (self.makespan_ms / 1e3)
    }

    /// Nearest-rank latency percentile; 0.0 for a zero-task report (never
    /// NaN — these values land verbatim in BENCH JSON).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.results.iter().map(|r| r.latency_ms).collect();
        percentile(&xs, p)
    }

    /// Nearest-rank queue-wait percentile (admission → prefill start);
    /// 0.0 for a zero-task report.
    pub fn queue_percentile(&self, p: f64) -> f64 {
        let xs: Vec<f64> = self.results.iter().map(|r| r.queue_ms).collect();
        percentile(&xs, p)
    }

    pub fn service_summary(&self) -> Summary {
        Summary::from_slice(
            &self.results.iter().map(|r| r.service_ms).collect::<Vec<_>>(),
        )
    }
}

/// Bounded FIFO of pending tasks (the backpressure point).
///
/// Public so stress tests and alternative frontends can exercise the
/// serving layer's admission control without a compiled engine: `push`
/// blocks once `capacity` items are pending, `pop` blocks until an item or
/// `close`, and no item is ever dropped.
pub struct TaskQueue<T> {
    inner: Mutex<std::collections::VecDeque<T>>,
    cv: Condvar,
    capacity: usize,
    closed: Mutex<bool>,
}

impl<T> TaskQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
            capacity,
            closed: Mutex::new(false),
        }
    }

    /// Blocking push (backpressure when the queue is full).
    pub fn push(&self, item: T) {
        let mut q = self.inner.lock().unwrap();
        while q.len() >= self.capacity {
            q = self.cv.wait(q).unwrap();
        }
        q.push_back(item);
        self.cv.notify_all();
    }

    /// Non-blocking push: `Err(item)` back to the caller when the queue
    /// is full (admission policies decide what to do with it).
    pub fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(item);
        }
        q.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Push that sheds the *oldest* queued item instead of blocking when
    /// full; the displaced item is returned so the caller can record the
    /// drop (shed-oldest admission).  Never blocks.
    pub fn shed_push(&self, item: T) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        let shed = (q.len() >= self.capacity).then(|| q.pop_front()).flatten();
        q.push_back(item);
        self.cv.notify_all();
        shed
    }

    /// Non-blocking pop: `None` when nothing is queued right now (the
    /// fabric scheduler polls between events instead of parking here).
    pub fn try_pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        let item = q.pop_front();
        if item.is_some() {
            self.cv.notify_all();
        }
        item
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                self.cv.notify_all();
                return Some(item);
            }
            if *self.closed.lock().unwrap() {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    pub fn close(&self) {
        // Hold the queue lock while flipping the flag: a consumer in
        // `pop` is either before its closed-check (will see true) or
        // already parked in `cv.wait` (will get the notify).  Without
        // this, close() could set+notify inside a consumer's
        // check-then-wait window and strand it forever.
        let _guard = self.inner.lock().unwrap();
        *self.closed.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Currently queued items (bounded by `capacity` between operations).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

pub struct Coordinator {
    engine: Engine,
    cfg: CoordinatorConfig,
    /// One participant-parallelism pool shared by every served session
    /// (spawning/joining `workers` OS threads per task would dominate
    /// short tasks); `None` when `workers <= 1`.
    session_pool: Option<Arc<crate::exec::Pool>>,
}

impl Coordinator {
    pub fn new(engine: Engine, cfg: CoordinatorConfig) -> Self {
        let session_pool =
            (cfg.workers > 1).then(|| Arc::new(crate::exec::Pool::new(cfg.workers)));
        Self { engine, cfg, session_pool }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Session config + network sim for one served task (shared by the
    /// synchronous path and the fabric's per-session state machines).
    fn session_setup(&self, task_seed: u64) -> Result<(SessionConfig, NetSim)> {
        let cfg = &self.cfg;
        let md = &self.engine.manifest.model;
        let schedule = SyncSchedule::uniform(md.n_layers, cfg.participants, cfg.sync_h);
        let mut scfg = SessionConfig::new(schedule);
        scfg.local_sparsity = LocalSparsity { ratio: cfg.local_sparsity };
        scfg.kv_policy = cfg.kv_policy;
        scfg.max_new_tokens = cfg.max_new_tokens;
        scfg.dropout_prob = cfg.dropout_prob;
        scfg.round_deadline_ms = cfg.round_deadline_ms;
        scfg.delta_frames = cfg.delta_frames;
        scfg.kv_precision = cfg.kv_precision;
        scfg.heartbeat_ms = cfg.heartbeat_ms;
        scfg.heartbeat_max_missed = cfg.heartbeat_max_missed;
        scfg.seed = task_seed;
        // The session borrows the coordinator's shared pool; keep
        // workers = 1 so FedSession::new doesn't spawn a throwaway one.
        scfg.workers = 1;
        let links = self.cfg.links();
        anyhow::ensure!(
            links.len() == cfg.participants,
            "hetero_links length {} != participants {}",
            links.len(),
            cfg.participants
        );
        // Byte-budget adaptive aggregation: the coordinator splits the
        // round's byte budget into per-participant row budgets weighted by
        // uplink bandwidth (§V Obs. 4 meets heterogeneous edge links).
        // Must stay in lockstep with FedSession::prefill's fallback, which
        // derives the identical allocation from the NetSim links when no
        // explicit budget is set — both defer to allocate_row_budgets.
        if let KvExchangePolicy::ByteBudget { bytes_per_round } = cfg.kv_policy {
            // Wire bytes per K+V row pair at the session precision — the
            // same divisor the drivers use, so reduced precisions buy
            // proportionally more rows under one byte budget.
            let row_bytes =
                cfg.kv_precision.wire_row_bytes(md.n_kv_heads, md.head_dim).max(1);
            scfg.kv_row_budgets = Some(crate::net::allocate_row_budgets(
                &links,
                bytes_per_round / row_bytes,
            ));
        }
        let net = NetSim::new(cfg.topology, links, task_seed);
        Ok((scfg, net))
    }

    /// Serve one episode synchronously (the `run` CLI subcommand and the
    /// thread-per-task serving loop).
    pub fn run_one(
        &self,
        task_id: usize,
        episode: &Episode,
        task_seed: u64,
    ) -> Result<TaskResult> {
        let cfg = &self.cfg;
        let part = partition(episode, cfg.participants, cfg.segmentation);
        let (mut scfg, net) = self.session_setup(task_seed)?;
        let t0 = Instant::now();
        let rep = match cfg.node_addrs.as_deref() {
            // Node-resident wire mode: the participants' block compute
            // runs at the configured node hosts; the coordinator session
            // is the message-turn driver.  The socket wait is bounded by
            // the round deadline (plus grace) rather than the 60 s
            // default, matching what the handshake announces node-side.
            Some(addrs) if !addrs.is_empty() => {
                let io_timeout =
                    crate::fedattn::transport::read_timeout_for_deadline_with_grace(
                        scfg.round_deadline_ms,
                        std::time::Duration::from_secs_f64(
                            cfg.transport.deadline_grace_ms / 1e3,
                        ),
                    );
                let retry = crate::fedattn::RetryPolicy {
                    max_attempts: cfg.transport.retry_max_attempts,
                    backoff_ms: cfg.transport.retry_backoff_ms,
                    jitter_seed: task_seed,
                    ..Default::default()
                };
                let dial = |p: usize, what: &str| -> Result<Box<dyn Transport>> {
                    let addr = &addrs[p % addrs.len()];
                    TcpTransport::connect_with_retry(addr, &retry)
                        .and_then(|t| t.with_read_timeout(io_timeout))
                        .map(|t| Box::new(t) as Box<dyn Transport>)
                        .with_context(|| {
                            format!("{what} participant {p} to node host {addr}")
                        })
                };
                let transports: Vec<Box<dyn Transport>> = (0..cfg.participants)
                    .map(|p| dial(p, "connecting"))
                    .collect::<Result<_>>()?;
                scfg.rejoin = cfg.rejoin;
                scfg.rejoin_max_attempts = cfg.transport.retry_max_attempts;
                let mut driver =
                    TransportDriver::new(&self.engine, &part, scfg, net, transports)?;
                if cfg.rejoin {
                    // Probation nodes re-dial the same round-robin host
                    // map (and retry policy) the original connect used.
                    driver =
                        driver.with_reconnector(Box::new(move |p| dial(p, "reconnecting")));
                }
                driver.run()?
            }
            _ => {
                let mut session = FedSession::new(&self.engine, &part, scfg, net)?;
                if let Some(pool) = &self.session_pool {
                    session = session.with_shared_pool(Arc::clone(pool));
                }
                session.run()?
            }
        };
        let service_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(TaskResult {
            task_id,
            em: em_score(&rep.answer, &episode.answer),
            answer: rep.answer,
            gold: episode.answer.clone(),
            queue_ms: 0.0,
            service_ms,
            latency_ms: service_ms,
            comm_bytes: rep.net.total_bytes(),
            comm_time_ms: rep.net.comm_time_ms,
            generated_tokens: rep.generated_tokens,
            demotions: rep.net.demotions,
            rejoins: rep.net.rejoins,
            retries: rep.net.retries,
        })
    }

    /// Serve a whole trace through `engines` workers with Poisson
    /// arrivals.  `serving.fabric` routes through the session fabric
    /// (resumable sessions, admission control, cross-session batched
    /// decode); off keeps the thread-per-task loop.  Both paths seed
    /// task `i` with `cfg.seed + i`, so at equal configuration they
    /// produce byte-identical per-task transcripts.
    pub fn serve_trace(&self, trace: &WorkloadTrace) -> Result<ServeReport> {
        if self.cfg.fabric {
            return self.serve_trace_fabric(trace);
        }
        let queue: Arc<TaskQueue<(usize, Instant)>> =
            Arc::new(TaskQueue::new(self.cfg.queue_depth));
        let results: Arc<Mutex<Vec<TaskResult>>> = Arc::new(Mutex::new(Vec::new()));
        let failed: Arc<Mutex<Vec<FailedTask>>> = Arc::new(Mutex::new(Vec::new()));
        let start = Instant::now();

        std::thread::scope(|s| -> Result<()> {
            // Workers.
            for _ in 0..self.cfg.engines.max(1) {
                let queue = Arc::clone(&queue);
                let results = Arc::clone(&results);
                let failed = Arc::clone(&failed);
                s.spawn(move || {
                    while let Some((task_id, enqueued_at)) = queue.pop() {
                        let queue_ms = enqueued_at.elapsed().as_secs_f64() * 1e3;
                        // Deterministic per-task seed: worker interleaving
                        // must not change any session's transcript.
                        let seed = self.cfg.seed + task_id as u64;
                        let task = &trace.tasks[task_id];
                        match self.run_one(task_id, &task.episode, seed) {
                            Ok(mut r) => {
                                r.queue_ms = queue_ms;
                                r.latency_ms = queue_ms + r.service_ms;
                                results.lock().unwrap().push(r);
                            }
                            Err(e) => {
                                log::error!("task {task_id} failed: {e:#}");
                                failed.lock().unwrap().push(FailedTask {
                                    task_id,
                                    error: format!("{e:#}"),
                                });
                            }
                        }
                    }
                });
            }

            // Arrival loop (trace replay with optional time compression).
            for task in &trace.tasks {
                let due_ms = task.arrival_ms / self.cfg.time_scale.max(1e-9);
                let elapsed = start.elapsed().as_secs_f64() * 1e3;
                if due_ms > elapsed {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        (due_ms - elapsed) / 1e3,
                    ));
                }
                queue.push((task.id, Instant::now()));
            }
            queue.close();
            Ok(())
        })?;

        let mut results = Arc::try_unwrap(results)
            .map_err(|_| anyhow::anyhow!("results still shared"))?
            .into_inner()
            .unwrap();
        results.sort_by_key(|r| r.task_id);
        let mut failed = Arc::try_unwrap(failed)
            .map_err(|_| anyhow::anyhow!("failed list still shared"))?
            .into_inner()
            .unwrap();
        failed.sort_by_key(|f| f.task_id);
        Ok(ServeReport {
            results,
            failed,
            dropped: Vec::new(),
            deadline_killed: Vec::new(),
            watchdog_killed: Vec::new(),
            drained: Vec::new(),
            replaced_workers: 0,
            makespan_ms: start.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Fabric-mode trace serving: every task becomes a [`SessionTask`]
    /// state machine scheduled by [`run_fabric`].
    fn serve_trace_fabric(&self, trace: &WorkloadTrace) -> Result<ServeReport> {
        let engines = self.cfg.engines.max(1);
        // `drain_after_ms` is the CLI stand-in for an operator SIGTERM: a
        // timer thread flips the drain signal mid-run, the fabric stops
        // admitting, and in-flight sessions finish (or deadline-kill).
        let drain = self.cfg.drain_after_ms.map(|after_ms| {
            let signal = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let armed = Arc::clone(&signal);
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_secs_f64(after_ms / 1e3));
                armed.store(true, std::sync::atomic::Ordering::SeqCst);
            });
            signal
        });
        let fcfg = FabricConfig {
            engines,
            queue_depth: self.cfg.queue_depth,
            max_inflight: self.cfg.max_inflight.unwrap_or(4 * engines),
            admission: self.cfg.admission,
            service_prior_ms: self.cfg.slo_prior_ms,
            batching: true,
            time_scale: self.cfg.time_scale,
            session_deadline_ms: self.cfg.session_deadline_ms,
            watchdog_ms: self.cfg.watchdog_ms,
            drain,
            faults: None,
        };
        let tasks: Vec<(f64, Box<dyn FabricTask + '_>)> = trace
            .tasks
            .iter()
            .map(|t| {
                let st = SessionTask {
                    coord: self,
                    task_id: t.id,
                    episode: &t.episode,
                    seed: self.cfg.seed + t.id as u64,
                    t_start: None,
                    handle: None,
                    net: None,
                    full: None,
                };
                (t.arrival_ms, Box::new(st) as Box<dyn FabricTask + '_>)
            })
            .collect();
        let out = run_fabric(Some(&self.engine), &fcfg, tasks)?;
        let mut results = out.results;
        results.sort_by_key(|r| r.task_id);
        let mut failed = out.failed;
        failed.sort_by_key(|f| f.task_id);
        let mut deadline_killed = out.deadline_killed;
        deadline_killed.sort_by_key(|f| f.task_id);
        let mut watchdog_killed = out.watchdog_killed;
        watchdog_killed.sort_by_key(|f| f.task_id);
        let mut drained = out.drained;
        drained.sort_unstable();
        Ok(ServeReport {
            results,
            failed,
            dropped: out.dropped,
            deadline_killed,
            watchdog_killed,
            drained,
            replaced_workers: out.replaced_workers,
            makespan_ms: out.makespan_ms,
        })
    }
}

/// One served session as a fabric state machine.
///
/// In-process sessions split into prefill (worker thread, once) + a
/// resumable publisher decode ([`DecodeHandle`]) the fabric steps —
/// individually or batched across sessions.  Wire-mode sessions decode
/// node-resident, so they run to completion inside `prefill` and report
/// `Done` immediately.
struct SessionTask<'c> {
    coord: &'c Coordinator,
    task_id: usize,
    episode: &'c Episode,
    seed: u64,
    t_start: Option<Instant>,
    handle: Option<DecodeHandle>,
    net: Option<crate::net::NetReport>,
    /// Wire-mode short-circuit: the completed result.
    full: Option<TaskResult>,
}

impl FabricTask for SessionTask<'_> {
    fn task_id(&self) -> usize {
        self.task_id
    }

    fn prefill(&mut self) -> Result<()> {
        self.t_start = Some(Instant::now());
        let cfg = &self.coord.cfg;
        if cfg.node_addrs.as_deref().is_some_and(|a| !a.is_empty()) {
            // Wire mode decodes at the nodes — no steppable decode to
            // schedule; run the whole session here.
            self.full = Some(self.coord.run_one(self.task_id, self.episode, self.seed)?);
            return Ok(());
        }
        let part = partition(self.episode, cfg.participants, cfg.segmentation);
        let (scfg, net) = self.coord.session_setup(self.seed)?;
        let mut session = FedSession::new(&self.coord.engine, &part, scfg, net)?;
        if let Some(pool) = &self.coord.session_pool {
            session = session.with_shared_pool(Arc::clone(pool));
        }
        let (handle, pre) = session.into_publisher_decode()?;
        self.handle = Some(handle);
        self.net = Some(pre.net);
        Ok(())
    }

    fn poll(&mut self) -> DecodeStep {
        if self.full.is_some() {
            return DecodeStep::Done;
        }
        match self.handle.as_mut() {
            Some(h) => h.poll(),
            None => DecodeStep::Done,
        }
    }

    fn dispatch(&mut self) -> Result<()> {
        let handle = self
            .handle
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("dispatch on a session without a decode handle"))?;
        handle.dispatch(&self.coord.engine)
    }

    fn decode_handle(&mut self) -> Option<&mut DecodeHandle> {
        self.handle.as_mut()
    }

    fn into_result(self: Box<Self>) -> Result<TaskResult> {
        if let Some(full) = self.full {
            return Ok(full);
        }
        let handle = self
            .handle
            .ok_or_else(|| anyhow::anyhow!("session finished without prefilling"))?;
        let net = require_net_report(self.net)?;
        let service_ms =
            self.t_start.map(|t| t.elapsed().as_secs_f64() * 1e3).unwrap_or(0.0);
        let answer = handle.text();
        Ok(TaskResult {
            task_id: self.task_id,
            em: em_score(&answer, &self.episode.answer),
            answer,
            gold: self.episode.answer.clone(),
            queue_ms: 0.0,
            service_ms,
            latency_ms: service_ms,
            comm_bytes: net.total_bytes(),
            comm_time_ms: net.comm_time_ms,
            generated_tokens: handle.ids().len(),
            demotions: net.demotions,
            rejoins: net.rejoins,
            retries: net.retries,
        })
    }
}

/// A completed session with no net report means comm accounting was lost
/// somewhere; surface it as a task failure instead of silently reporting
/// zero traffic (and zero demotions) in the [`TaskResult`].
fn require_net_report(net: Option<crate::net::NetReport>) -> Result<crate::net::NetReport> {
    net.ok_or_else(|| {
        anyhow::anyhow!("session finished without a net report (comm bytes unknown)")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_net_report_is_an_error_not_zero_traffic() {
        let err = require_net_report(None).unwrap_err();
        assert!(err.to_string().contains("without a net report"), "{err}");
        let rep = crate::net::NetReport { demotions: 2, ..Default::default() };
        assert_eq!(require_net_report(Some(rep)).unwrap().demotions, 2);
    }

    #[test]
    fn queue_fifo_and_close() {
        let q: TaskQueue<u32> = TaskQueue::new(8);
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_backpressure_blocks_until_pop() {
        let q: Arc<TaskQueue<u32>> = Arc::new(TaskQueue::new(1));
        q.push(1);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            q2.push(2); // blocks until main pops
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!h.is_finished(), "push should be blocked by backpressure");
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn serve_report_stats() {
        let mk = |id: usize, lat: f64, em: bool| TaskResult {
            task_id: id,
            answer: String::new(),
            gold: String::new(),
            em,
            queue_ms: 0.0,
            service_ms: lat,
            latency_ms: lat,
            comm_bytes: 0,
            comm_time_ms: 0.0,
            generated_tokens: 1,
            demotions: 0,
            rejoins: 0,
            retries: 0,
        };
        let rep = ServeReport {
            results: vec![mk(0, 10.0, true), mk(1, 20.0, false), mk(2, 30.0, true)],
            failed: vec![FailedTask { task_id: 3, error: "transport lost".into() }],
            dropped: Vec::new(),
            deadline_killed: vec![FailedTask {
                task_id: 4,
                error: "session deadline exceeded".into(),
            }],
            watchdog_killed: Vec::new(),
            drained: vec![5, 6],
            replaced_workers: 0,
            makespan_ms: 1000.0,
        };
        // Stats run over completions only; the failure is counted apart.
        assert!((rep.em_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((rep.throughput_tasks_per_s() - 3.0).abs() < 1e-12);
        assert_eq!(rep.latency_percentile(100.0), 30.0);
        assert_eq!(rep.failed_count(), 1);
        assert_eq!(rep.failed[0].task_id, 3);
        // Liveness buckets count toward the offered-task accounting.
        assert_eq!(rep.accounted(), 3 + 1 + 1 + 2);
    }

    #[test]
    fn serve_report_queue_percentiles() {
        let mk = |id: usize, q: f64| TaskResult {
            task_id: id,
            answer: String::new(),
            gold: String::new(),
            em: true,
            queue_ms: q,
            service_ms: 5.0,
            latency_ms: q + 5.0,
            comm_bytes: 0,
            comm_time_ms: 0.0,
            generated_tokens: 1,
            demotions: 0,
            rejoins: 0,
            retries: 0,
        };
        let rep = ServeReport {
            results: (0..10).map(|i| mk(i, (i + 1) as f64)).collect(),
            failed: Vec::new(),
            dropped: Vec::new(),
            deadline_killed: Vec::new(),
            watchdog_killed: Vec::new(),
            drained: Vec::new(),
            replaced_workers: 0,
            makespan_ms: 100.0,
        };
        // `percentile` indexes round(p · (n−1)): p50 of 1..=10 → v[5].
        assert_eq!(rep.queue_percentile(50.0), 6.0);
        assert_eq!(rep.queue_percentile(95.0), 10.0);
        assert_eq!(rep.queue_percentile(100.0), 10.0);
    }

    #[test]
    fn empty_serve_report_emits_finite_stats() {
        // A trace where every task failed (or an empty trace) must not
        // push NaN/inf into BENCH JSON or panic in the percentile sort.
        let rep = ServeReport {
            results: Vec::new(),
            failed: Vec::new(),
            dropped: Vec::new(),
            deadline_killed: Vec::new(),
            watchdog_killed: Vec::new(),
            drained: Vec::new(),
            replaced_workers: 0,
            makespan_ms: 0.0,
        };
        assert_eq!(rep.em_rate(), 0.0);
        assert_eq!(rep.throughput_tasks_per_s(), 0.0);
        for p in [0.0, 50.0, 95.0, 100.0] {
            let v = rep.latency_percentile(p);
            assert!(v.is_finite(), "p{p} = {v}");
            assert_eq!(v, 0.0);
            assert_eq!(rep.queue_percentile(p), 0.0);
        }
        assert_eq!(rep.failed_count(), 0);
    }
}
