//! Configuration substrate: a TOML-subset parser plus the typed
//! `SystemConfig` consumed by the CLI, coordinator and benches.

mod toml;
mod system;

pub use system::{
    FederationConfig, NetworkConfig, NodeConfig, ServingConfig, SystemConfig, TransportConfig,
};
pub use toml::{TomlDoc, TomlError, TomlValue};
