//! Typed system configuration assembled from a TOML file + CLI overrides.

use std::path::{Path, PathBuf};

use anyhow::Result;

use super::toml::TomlDoc;
use crate::data::Segmentation;
use crate::fedattn::{KvExchangePolicy, KvPrecision};
use crate::net::{LinkSpec, Topology};
use crate::serve::AdmissionPolicy;

/// Federation-level knobs (maps to Alg. 1 parameters).
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// Number of participants N.
    pub participants: usize,
    /// Uniform sync interval H (Alg. 1).
    pub sync_h: usize,
    /// Input segmentation setting (paper Fig. 4).
    pub segmentation: Segmentation,
    /// Local-attention token sparsity ratio (Fig. 9; 1.0 = dense).
    pub local_sparsity: f64,
    /// KV-exchange policy (Fig. 10 / §V Obs. 4).
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    /// Per-node, per-round attendance dropout probability (`--dropout` /
    /// `federation.dropout_prob`): each scheduled attendance is dropped
    /// independently with this probability.  0.0 (the default) disables
    /// dropout and is byte-identical to the knob not existing.
    pub dropout_prob: f64,
    /// Per-sync-round contribution deadline in simulated milliseconds
    /// (`--round-deadline` / `federation.round_deadline_ms`): link
    /// latency + jitter schedule each uplink's arrival, and
    /// contributions landing past the deadline are excluded from the
    /// round (partial aggregation).  `None` (the default) disables the
    /// deadline entirely and is byte-identical to the knob not existing.
    pub round_deadline_ms: Option<f64>,
    /// Delta-encode the downlink (`--delta-frames` /
    /// `federation.delta_frames`, default on): attendees receive only
    /// the transmitted rows they do not already hold, with an automatic
    /// full-frame fallback on any cache miss.  Off bills (and ships)
    /// full broadcast frames — the pre-delta baseline the comm benches
    /// compare against.
    pub delta_frames: bool,
    /// Churn recovery (`--rejoin` / `federation.rejoin`, default off):
    /// in wire mode, a node whose transport fails goes on probation and
    /// the driver retries a `Rejoin`/`Resync` readmission at each round
    /// boundary instead of demoting it outright.  Off is byte-identical
    /// to the knob not existing.
    pub rejoin: bool,
    /// Wire precision of K/V row payloads (`--kv-precision` /
    /// `federation.kv_precision` = `f32` | `f16` | `int8`, default
    /// `f32`).  Reduced precisions quantize rows at encode time with
    /// per-row scales; `f32` is byte-identical to the knob not existing.
    pub kv_precision: KvPrecision,
    /// Heartbeat window in milliseconds (`--heartbeat` /
    /// `federation.heartbeat_ms`): in wire mode the driver pings each
    /// node host at every layer boundary and waits up to this long for
    /// the echoed pong, and a node that misses
    /// [`heartbeat_max_missed`](Self::heartbeat_max_missed) consecutive
    /// beats is demoted (or put on probation when rejoin is on) without
    /// waiting for a round deadline.  `None` (the default) disables
    /// heartbeats entirely and is byte-identical to the knob not
    /// existing.
    pub heartbeat_ms: Option<f64>,
    /// Consecutive missed heartbeats tolerated before a node is declared
    /// non-responsive (`federation.heartbeat_max_missed`, default 2).
    /// Only consulted when [`heartbeat_ms`](Self::heartbeat_ms) is set.
    pub heartbeat_max_missed: u32,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            participants: 3,
            sync_h: 2,
            segmentation: Segmentation::SemQEx,
            local_sparsity: 1.0,
            kv_policy: KvExchangePolicy::Full,
            max_new_tokens: 12,
            dropout_prob: 0.0,
            round_deadline_ms: None,
            delta_frames: true,
            rejoin: false,
            kv_precision: KvPrecision::F32,
            heartbeat_ms: None,
            heartbeat_max_missed: 2,
        }
    }
}

/// Transport-layer knobs (`[transport]`): connect retry/backoff and the
/// read-timeout grace window, shared by `run --connect`, the
/// coordinator's wire sessions, and churn-recovery reconnects.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportConfig {
    /// Connect attempts before giving up (`transport.retry_max_attempts`
    /// / `--retry-max-attempts`); also the probation budget for rejoin.
    pub retry_max_attempts: u32,
    /// First-retry backoff in milliseconds, doubled per attempt with
    /// deterministic seeded jitter (`transport.retry_backoff_ms` /
    /// `--retry-backoff-ms`).
    pub retry_backoff_ms: f64,
    /// Grace added on top of the round deadline when deriving socket
    /// read timeouts (`transport.deadline_grace_ms` /
    /// `--deadline-grace-ms`): covers compute + control turns that
    /// follow the deadline cut.  Matches the historical hard-coded 15 s.
    pub deadline_grace_ms: f64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            retry_max_attempts: 3,
            retry_backoff_ms: 50.0,
            deadline_grace_ms: 15_000.0,
        }
    }
}

/// Edge-network model parameters.
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    pub topology: Topology,
    pub link: LinkSpec,
    /// Per-participant uplink bandwidths (Mbit/s) for heterogeneous-link
    /// scenarios (`network.bandwidths_mbps = [...]`); participants beyond
    /// the list reuse the uniform `link` spec.
    pub bandwidths_mbps: Option<Vec<f64>>,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self {
            topology: Topology::Star,
            link: LinkSpec::default(),
            bandwidths_mbps: None,
        }
    }
}

impl NetworkConfig {
    /// Materialise per-participant link specs (heterogeneous bandwidths
    /// when configured, otherwise `n` copies of the uniform link).
    pub fn links(&self, n: usize) -> Vec<LinkSpec> {
        (0..n)
            .map(|p| {
                let mut l = self.link;
                if let Some(bw) = self.bandwidths_mbps.as_ref().and_then(|b| b.get(p)) {
                    l.bandwidth_mbps = *bw;
                }
                l
            })
            .collect()
    }
}

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Engine worker threads.
    pub engines: usize,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Per-session participant-parallelism width (`--workers`): the
    /// per-participant prefill/decode loops run on a pool of this many
    /// threads.  1 = sequential; parallel sessions are byte-identical to
    /// sequential ones.
    pub workers: usize,
    /// Trace time-compression factor (`serving.time_scale` / CLI
    /// `--time-scale`): inter-arrival gaps are divided by this before
    /// replay.  `None` = not configured; consumers fall back to their own
    /// default (1.0 for real-time replay, 10.0 for the `serve`
    /// subcommand's historical behaviour).
    pub time_scale: Option<f64>,
    /// Serve through the session fabric (`serving.fabric` / `--fabric`):
    /// resumable sessions multiplexed over the engine workers, with
    /// admission control and cross-session batched decode.  Off (the
    /// default) keeps the thread-per-task loop.
    pub fabric: bool,
    /// Admission policy in front of the serving queue
    /// (`serving.admission` = `block` | `shed-oldest` | `reject-over-slo`;
    /// the SLO itself comes from `serving.slo_ms`).
    pub admission: AdmissionPolicy,
    /// Max sessions admitted past the queue at once in fabric mode
    /// (`serving.max_inflight`); `None` = 4 × engines.
    pub max_inflight: Option<usize>,
    /// End-to-end per-session deadline in milliseconds
    /// (`serving.session_deadline_ms` / `--session-deadline`): the clock
    /// starts when a task is offered to admission (queue wait included)
    /// and the fabric cancels over-deadline sessions at the next resume
    /// point, reporting them as `deadline_killed`.  `None` (the default)
    /// disables enforcement and is byte-identical to the knob not
    /// existing.
    pub session_deadline_ms: Option<f64>,
    /// Stuck-session watchdog window in milliseconds
    /// (`serving.watchdog_ms` / `--watchdog`): a dispatched work item
    /// making no progress for this long is cancelled, its sessions are
    /// reported as `watchdog_killed`, and a spare worker replaces the
    /// wedged one.  `None` (the default) disables the watchdog.
    pub watchdog_ms: Option<f64>,
    /// Optimistic service-time prior in milliseconds
    /// (`serving.slo_prior_ms` / `--slo-prior`): seeds the admission
    /// controller's service-time EMA so reject-over-SLO gating engages
    /// before the first completion instead of admitting a startup burst
    /// blind.  `None` (the default) keeps the learn-from-zero behaviour.
    pub slo_prior_ms: Option<f64>,
    /// Graceful-drain trigger in milliseconds after serve start
    /// (`serving.drain_after_ms` / `--drain-after`): a SIGTERM stand-in
    /// — once it fires the fabric stops admitting, finishes (or
    /// deadline-kills) in-flight sessions, and reports never-admitted
    /// tasks as `drained`.  `None` (the default) never drains.
    pub drain_after_ms: Option<f64>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            engines: 1,
            queue_depth: 64,
            workers: 1,
            time_scale: None,
            fabric: false,
            admission: AdmissionPolicy::Block,
            max_inflight: None,
            session_deadline_ms: None,
            watchdog_ms: None,
            slo_prior_ms: None,
            drain_after_ms: None,
        }
    }
}

/// Node-resident deployment knobs — both sides of the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeConfig {
    /// Node side: where `fedattn node` accepts driver connections
    /// (`node.listen` / `--listen`).
    pub listen: String,
    /// Node side: artifact directory for the node's *own* engine
    /// (`node.engine_dir` / `node --engine`).  `None` falls back to the
    /// shared `artifacts_dir` — the single-machine demo; a real edge
    /// deployment points each node host at its local artifact set, since
    /// node-resident compute means the node never borrows the driver's
    /// engine.
    pub engine_dir: Option<PathBuf>,
    /// Driver side: node-host addresses for wire sessions (`node.connect`
    /// / `run --connect`).  Participants connect round-robin to the list;
    /// `None` keeps sessions fully in-process.
    pub connect: Option<Vec<String>>,
}

impl Default for NodeConfig {
    fn default() -> Self {
        Self { listen: "127.0.0.1:7070".to_string(), engine_dir: None, connect: None }
    }
}

/// Root configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub artifacts_dir: PathBuf,
    pub weights_file: String,
    pub seed: u64,
    pub federation: FederationConfig,
    pub network: NetworkConfig,
    pub serving: ServingConfig,
    pub node: NodeConfig,
    pub transport: TransportConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            weights_file: "weights.npz".to_string(),
            seed: 7,
            federation: FederationConfig::default(),
            network: NetworkConfig::default(),
            serving: ServingConfig::default(),
            node: NodeConfig::default(),
            transport: TransportConfig::default(),
        }
    }
}

impl SystemConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut c = Self::default();
        c.artifacts_dir = PathBuf::from(doc.str_or("artifacts_dir", "artifacts"));
        c.weights_file = doc.str_or("weights_file", "weights.npz").to_string();
        c.seed = doc.usize_or("seed", 7) as u64;

        let f = &mut c.federation;
        f.participants = doc.usize_or("federation.participants", f.participants);
        f.sync_h = doc.usize_or("federation.sync_h", f.sync_h);
        if let Some(seg) = doc.get("federation.segmentation").and_then(|v| v.as_str()) {
            f.segmentation = Segmentation::parse(seg)
                .ok_or_else(|| anyhow::anyhow!("unknown segmentation {seg:?}"))?;
        }
        f.local_sparsity = doc.f64_or("federation.local_sparsity", 1.0);
        let kv_ratio = doc.f64_or("federation.kv_exchange_ratio", 1.0);
        f.kv_policy = match doc.str_or("federation.kv_policy", "full") {
            "full" if kv_ratio >= 1.0 => KvExchangePolicy::Full,
            "full" | "random" => KvExchangePolicy::Random { ratio: kv_ratio },
            "publisher-priority" => {
                KvExchangePolicy::PublisherPriority { remote_ratio: kv_ratio }
            }
            "recent-budget" => KvExchangePolicy::RecentBudget {
                budget_rows: doc.usize_or("federation.kv_budget_rows", 64),
            },
            "top-k-relevance" => KvExchangePolicy::TopKRelevance {
                budget_rows: doc.usize_or("federation.kv_budget_rows", 64),
            },
            "byte-budget" => KvExchangePolicy::ByteBudget {
                bytes_per_round: doc.usize_or("federation.kv_bytes_per_round", 64 * 1024),
            },
            other => anyhow::bail!("unknown kv_policy {other:?}"),
        };
        f.max_new_tokens = doc.usize_or("federation.max_new_tokens", f.max_new_tokens);
        f.dropout_prob = doc.f64_or("federation.dropout_prob", 0.0);
        anyhow::ensure!(
            (0.0..=1.0).contains(&f.dropout_prob),
            "federation.dropout_prob must be in [0, 1], got {}",
            f.dropout_prob
        );
        if let Some(v) = doc.get("federation.round_deadline_ms") {
            // Present but malformed must fail loudly — a silently
            // ignored deadline would corrupt straggler experiments.
            let d = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("federation.round_deadline_ms must be a number")
            })?;
            anyhow::ensure!(
                d.is_finite() && d >= 0.0,
                "federation.round_deadline_ms must be finite and >= 0, got {d}"
            );
            f.round_deadline_ms = Some(d);
        }
        if let Some(v) = doc.get("federation.delta_frames") {
            // Present but malformed must fail loudly — a silently ignored
            // toggle would corrupt full-vs-delta comm comparisons.
            f.delta_frames = v.as_bool().ok_or_else(|| {
                anyhow::anyhow!("federation.delta_frames must be a boolean")
            })?;
        }
        if let Some(v) = doc.get("federation.rejoin") {
            // Present but malformed must fail loudly — a silently ignored
            // toggle would corrupt churn-recovery experiments.
            f.rejoin = v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("federation.rejoin must be a boolean"))?;
        }
        if let Some(v) = doc.get("federation.kv_precision") {
            // Present but malformed must fail loudly — a silently ignored
            // precision would corrupt quality-vs-bytes comparisons.
            let name = v.as_str().ok_or_else(|| {
                anyhow::anyhow!("federation.kv_precision must be a string")
            })?;
            f.kv_precision = KvPrecision::from_str_opt(name)
                .ok_or_else(|| anyhow::anyhow!("unknown kv_precision {name:?}"))?;
        }
        if let Some(v) = doc.get("federation.heartbeat_ms") {
            // Present but malformed must fail loudly — a silently ignored
            // heartbeat would leave dead nodes undetected until a round
            // deadline fires (or never).
            let hb = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("federation.heartbeat_ms must be a number"))?;
            anyhow::ensure!(
                hb.is_finite() && hb > 0.0,
                "federation.heartbeat_ms must be finite and > 0, got {hb}"
            );
            f.heartbeat_ms = Some(hb);
        }
        if let Some(v) = doc.get("federation.heartbeat_max_missed") {
            let n = v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("federation.heartbeat_max_missed must be a positive integer")
            })?;
            anyhow::ensure!(n >= 1, "federation.heartbeat_max_missed must be >= 1, got {n}");
            f.heartbeat_max_missed = n as u32;
        }

        c.network.topology = if doc.str_or("network.topology", "star") == "mesh" {
            Topology::Mesh
        } else {
            Topology::Star
        };
        c.network.link = LinkSpec {
            bandwidth_mbps: doc.f64_or("network.bandwidth_mbps", 100.0),
            latency_ms: doc.f64_or("network.latency_ms", 5.0),
            jitter: doc.f64_or("network.jitter", 0.0),
        };
        if doc.get("network.bandwidths_mbps").is_some() {
            // Present but malformed must fail loudly — silently falling
            // back to uniform links would corrupt hetero-link experiments.
            c.network.bandwidths_mbps =
                Some(doc.f64_array("network.bandwidths_mbps").ok_or_else(|| {
                    anyhow::anyhow!("network.bandwidths_mbps must be a numeric array")
                })?);
        }

        c.node.listen = doc.str_or("node.listen", &c.node.listen).to_string();
        if let Some(v) = doc.get("node.engine_dir") {
            let dir = v.as_str().ok_or_else(|| {
                anyhow::anyhow!("node.engine_dir must be a string path")
            })?;
            c.node.engine_dir = Some(PathBuf::from(dir));
        }
        if doc.get("node.connect").is_some() {
            // Present but malformed must fail loudly — a silently dropped
            // host list would quietly run the session in-process.
            let hosts = doc.str_array("node.connect").ok_or_else(|| {
                anyhow::anyhow!("node.connect must be an array of host:port strings")
            })?;
            anyhow::ensure!(
                !hosts.is_empty(),
                "node.connect must list at least one host:port"
            );
            c.node.connect = Some(hosts);
        }

        let t = &mut c.transport;
        t.retry_max_attempts =
            doc.usize_or("transport.retry_max_attempts", t.retry_max_attempts as usize) as u32;
        anyhow::ensure!(
            t.retry_max_attempts >= 1,
            "transport.retry_max_attempts must be >= 1"
        );
        t.retry_backoff_ms = doc.f64_or("transport.retry_backoff_ms", t.retry_backoff_ms);
        anyhow::ensure!(
            t.retry_backoff_ms.is_finite() && t.retry_backoff_ms >= 0.0,
            "transport.retry_backoff_ms must be finite and >= 0, got {}",
            t.retry_backoff_ms
        );
        t.deadline_grace_ms = doc.f64_or("transport.deadline_grace_ms", t.deadline_grace_ms);
        anyhow::ensure!(
            t.deadline_grace_ms.is_finite() && t.deadline_grace_ms >= 0.0,
            "transport.deadline_grace_ms must be finite and >= 0, got {}",
            t.deadline_grace_ms
        );

        c.serving.engines = doc.usize_or("serving.engines", 1);
        c.serving.queue_depth = doc.usize_or("serving.queue_depth", 64);
        c.serving.workers = doc.usize_or("serving.workers", 1).max(1);
        if let Some(v) = doc.get("serving.time_scale") {
            // Present but malformed/non-positive must fail loudly.
            let ts = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("serving.time_scale must be a number")
            })?;
            anyhow::ensure!(ts > 0.0, "serving.time_scale must be > 0, got {ts}");
            c.serving.time_scale = Some(ts);
        }
        if let Some(v) = doc.get("serving.fabric") {
            // Present but malformed must fail loudly — a silently ignored
            // toggle would serve through the wrong scheduler.
            c.serving.fabric = v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("serving.fabric must be a boolean"))?;
        }
        let slo_ms = match doc.get("serving.slo_ms") {
            Some(v) => {
                let slo = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("serving.slo_ms must be a number"))?;
                anyhow::ensure!(
                    slo.is_finite() && slo > 0.0,
                    "serving.slo_ms must be finite and > 0, got {slo}"
                );
                Some(slo)
            }
            None => None,
        };
        if let Some(v) = doc.get("serving.admission") {
            let name = v.as_str().ok_or_else(|| {
                anyhow::anyhow!("serving.admission must be a string policy name")
            })?;
            // Unknown names and a missing/invalid SLO fail loudly here.
            c.serving.admission = AdmissionPolicy::parse(name, slo_ms)?;
        }
        anyhow::ensure!(
            slo_ms.is_none()
                || matches!(c.serving.admission, AdmissionPolicy::RejectOverSlo { .. }),
            "serving.slo_ms is set but serving.admission is not \"reject-over-slo\""
        );
        if let Some(v) = doc.get("serving.max_inflight") {
            let n = v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("serving.max_inflight must be a positive integer")
            })?;
            anyhow::ensure!(n >= 1, "serving.max_inflight must be >= 1, got {n}");
            c.serving.max_inflight = Some(n);
        }
        // Liveness-plane knobs share one shape: optional, strictly
        // positive, and loud on malformed input — a silently ignored
        // deadline or watchdog would corrupt SLO experiments.
        for (key, slot) in [
            ("serving.session_deadline_ms", &mut c.serving.session_deadline_ms),
            ("serving.watchdog_ms", &mut c.serving.watchdog_ms),
            ("serving.slo_prior_ms", &mut c.serving.slo_prior_ms),
            ("serving.drain_after_ms", &mut c.serving.drain_after_ms),
        ] {
            if let Some(v) = doc.get(key) {
                let ms = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be a number"))?;
                anyhow::ensure!(
                    ms.is_finite() && ms > 0.0,
                    "{key} must be finite and > 0, got {ms}"
                );
                *slot = Some(ms);
            }
        }
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let doc = TomlDoc::parse(&text).map_err(anyhow::Error::from)?;
        Self::from_toml(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.federation.participants, 3);
        assert_eq!(c.federation.kv_policy, KvExchangePolicy::Full);
    }

    #[test]
    fn full_config() {
        let doc = TomlDoc::parse(
            r#"
            seed = 42
            [federation]
            participants = 4
            sync_h = 4
            segmentation = "tok-seg:q-ex"
            kv_policy = "random"
            kv_exchange_ratio = 0.5
            [network]
            topology = "mesh"
            bandwidth_mbps = 20.0
            latency_ms = 10.0
            [serving]
            engines = 2
            workers = 3
        "#,
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.seed, 42);
        assert_eq!(c.federation.participants, 4);
        assert_eq!(c.federation.segmentation, Segmentation::TokQEx);
        assert_eq!(c.federation.kv_policy, KvExchangePolicy::Random { ratio: 0.5 });
        assert_eq!(c.network.topology, Topology::Mesh);
        assert_eq!(c.serving.engines, 2);
        assert_eq!(c.serving.workers, 3);
    }

    #[test]
    fn workers_default_and_floor() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(SystemConfig::from_toml(&doc).unwrap().serving.workers, 1);
        // 0 is clamped to sequential rather than an empty pool.
        let doc = TomlDoc::parse("[serving]\nworkers = 0").unwrap();
        assert_eq!(SystemConfig::from_toml(&doc).unwrap().serving.workers, 1);
    }

    #[test]
    fn hetero_links_from_array() {
        let doc = TomlDoc::parse(
            "[network]\nbandwidth_mbps = 80.0\nbandwidths_mbps = [100.0, 20.0]",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        let links = c.network.links(3);
        assert_eq!(links[0].bandwidth_mbps, 100.0);
        assert_eq!(links[1].bandwidth_mbps, 20.0);
        // Beyond the list: uniform fallback.
        assert_eq!(links[2].bandwidth_mbps, 80.0);
        // Present-but-malformed must error, not silently degrade.
        let doc =
            TomlDoc::parse("[network]\nbandwidths_mbps = \"fast\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn adaptive_policies_parse() {
        let doc = TomlDoc::parse(
            "[federation]\nkv_policy = \"top-k-relevance\"\nkv_budget_rows = 12",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(
            c.federation.kv_policy,
            KvExchangePolicy::TopKRelevance { budget_rows: 12 }
        );

        let doc = TomlDoc::parse(
            "[federation]\nkv_policy = \"byte-budget\"\nkv_bytes_per_round = 4096",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(
            c.federation.kv_policy,
            KvExchangePolicy::ByteBudget { bytes_per_round: 4096 }
        );
    }

    #[test]
    fn dropout_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(SystemConfig::from_toml(&doc).unwrap().federation.dropout_prob, 0.0);
        let doc = TomlDoc::parse("[federation]\ndropout_prob = 0.25").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.dropout_prob,
            0.25
        );
        let doc = TomlDoc::parse("[federation]\ndropout_prob = 1.5").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[federation]\ndropout_prob = -0.1").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn round_deadline_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.round_deadline_ms,
            None
        );
        let doc = TomlDoc::parse("[federation]\nround_deadline_ms = 25.0").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.round_deadline_ms,
            Some(25.0)
        );
        // 0 is a legal (everything-late) deadline.
        let doc = TomlDoc::parse("[federation]\nround_deadline_ms = 0").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.round_deadline_ms,
            Some(0.0)
        );
        let doc = TomlDoc::parse("[federation]\nround_deadline_ms = -5").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[federation]\nround_deadline_ms = \"fast\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn delta_frames_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(SystemConfig::from_toml(&doc).unwrap().federation.delta_frames);
        let doc = TomlDoc::parse("[federation]\ndelta_frames = false").unwrap();
        assert!(!SystemConfig::from_toml(&doc).unwrap().federation.delta_frames);
        let doc = TomlDoc::parse("[federation]\ndelta_frames = true").unwrap();
        assert!(SystemConfig::from_toml(&doc).unwrap().federation.delta_frames);
        // Present but malformed: loud failure, not a silent default.
        let doc = TomlDoc::parse("[federation]\ndelta_frames = \"yes\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejoin_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(!SystemConfig::from_toml(&doc).unwrap().federation.rejoin);
        let doc = TomlDoc::parse("[federation]\nrejoin = true").unwrap();
        assert!(SystemConfig::from_toml(&doc).unwrap().federation.rejoin);
        let doc = TomlDoc::parse("[federation]\nrejoin = false").unwrap();
        assert!(!SystemConfig::from_toml(&doc).unwrap().federation.rejoin);
        // Present but malformed: loud failure, not a silent default.
        let doc = TomlDoc::parse("[federation]\nrejoin = \"yes\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn kv_precision_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.kv_precision,
            KvPrecision::F32
        );
        let doc = TomlDoc::parse("[federation]\nkv_precision = \"f16\"").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.kv_precision,
            KvPrecision::F16
        );
        let doc = TomlDoc::parse("[federation]\nkv_precision = \"int8\"").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().federation.kv_precision,
            KvPrecision::Int8
        );
        // Present but malformed: loud failure, not a silent f32 default.
        let doc = TomlDoc::parse("[federation]\nkv_precision = \"int4\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[federation]\nkv_precision = 8").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn transport_section_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport, TransportConfig::default());
        assert_eq!(c.transport.retry_max_attempts, 3);
        assert_eq!(c.transport.deadline_grace_ms, 15_000.0);

        let doc = TomlDoc::parse(
            "[transport]\nretry_max_attempts = 5\nretry_backoff_ms = 10.0\n\
             deadline_grace_ms = 2000.0",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport.retry_max_attempts, 5);
        assert_eq!(c.transport.retry_backoff_ms, 10.0);
        assert_eq!(c.transport.deadline_grace_ms, 2000.0);

        let doc = TomlDoc::parse("[transport]\nretry_max_attempts = 0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[transport]\nretry_backoff_ms = -1.0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[transport]\ndeadline_grace_ms = -5").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn time_scale_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(SystemConfig::from_toml(&doc).unwrap().serving.time_scale, None);
        let doc = TomlDoc::parse("[serving]\ntime_scale = 25.0").unwrap();
        assert_eq!(
            SystemConfig::from_toml(&doc).unwrap().serving.time_scale,
            Some(25.0)
        );
        let doc = TomlDoc::parse("[serving]\ntime_scale = 0.0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[serving]\ntime_scale = \"fast\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn node_section_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.node, NodeConfig::default());
        assert_eq!(c.node.listen, "127.0.0.1:7070");

        let doc = TomlDoc::parse(
            "[node]\nlisten = \"0.0.0.0:9000\"\nengine_dir = \"/mnt/edge/artifacts\"\n\
             connect = [\"10.0.0.1:7070\", \"10.0.0.2:7070\"]",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.node.listen, "0.0.0.0:9000");
        assert_eq!(c.node.engine_dir, Some(PathBuf::from("/mnt/edge/artifacts")));
        assert_eq!(
            c.node.connect,
            Some(vec!["10.0.0.1:7070".to_string(), "10.0.0.2:7070".to_string()])
        );

        // Present-but-malformed must error, not silently fall back to an
        // in-process session.
        let doc = TomlDoc::parse("[node]\nconnect = \"10.0.0.1:7070\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[node]\nconnect = []").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[node]\nengine_dir = 7").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn heartbeat_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.federation.heartbeat_ms, None);
        assert_eq!(c.federation.heartbeat_max_missed, 2);

        let doc = TomlDoc::parse(
            "[federation]\nheartbeat_ms = 500.0\nheartbeat_max_missed = 3",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.federation.heartbeat_ms, Some(500.0));
        assert_eq!(c.federation.heartbeat_max_missed, 3);

        // Present but malformed: loud failure, not a silent default.
        let doc = TomlDoc::parse("[federation]\nheartbeat_ms = 0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[federation]\nheartbeat_ms = \"fast\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[federation]\nheartbeat_max_missed = 0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn liveness_knobs_parse_and_validate() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.serving.session_deadline_ms, None);
        assert_eq!(c.serving.watchdog_ms, None);
        assert_eq!(c.serving.slo_prior_ms, None);
        assert_eq!(c.serving.drain_after_ms, None);

        let doc = TomlDoc::parse(
            "[serving]\nsession_deadline_ms = 1500.0\nwatchdog_ms = 400.0\n\
             slo_prior_ms = 120.0\ndrain_after_ms = 60000",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.serving.session_deadline_ms, Some(1500.0));
        assert_eq!(c.serving.watchdog_ms, Some(400.0));
        assert_eq!(c.serving.slo_prior_ms, Some(120.0));
        assert_eq!(c.serving.drain_after_ms, Some(60000.0));

        // Zero, negative, and non-numeric values all fail loudly for
        // every knob in the family.
        for key in
            ["session_deadline_ms", "watchdog_ms", "slo_prior_ms", "drain_after_ms"]
        {
            let doc = TomlDoc::parse(&format!("[serving]\n{key} = 0")).unwrap();
            assert!(SystemConfig::from_toml(&doc).is_err(), "{key} = 0 must fail");
            let doc = TomlDoc::parse(&format!("[serving]\n{key} = -10.0")).unwrap();
            assert!(SystemConfig::from_toml(&doc).is_err(), "{key} < 0 must fail");
            let doc = TomlDoc::parse(&format!("[serving]\n{key} = \"soon\"")).unwrap();
            assert!(SystemConfig::from_toml(&doc).is_err(), "{key} non-numeric must fail");
        }
    }

    #[test]
    fn rejects_unknown_segmentation() {
        let doc = TomlDoc::parse("[federation]\nsegmentation = \"nope\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn serving_fabric_parses_and_validates() {
        let doc = TomlDoc::parse("").unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert!(!c.serving.fabric);
        assert_eq!(c.serving.admission, AdmissionPolicy::Block);
        assert_eq!(c.serving.max_inflight, None);

        let doc = TomlDoc::parse(
            "[serving]\nfabric = true\nadmission = \"shed-oldest\"\nmax_inflight = 8",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert!(c.serving.fabric);
        assert_eq!(c.serving.admission, AdmissionPolicy::ShedOldest);
        assert_eq!(c.serving.max_inflight, Some(8));

        let doc = TomlDoc::parse(
            "[serving]\nadmission = \"reject-over-slo\"\nslo_ms = 250.0",
        )
        .unwrap();
        let c = SystemConfig::from_toml(&doc).unwrap();
        assert_eq!(c.serving.admission, AdmissionPolicy::RejectOverSlo { slo_ms: 250.0 });

        // Present but malformed must fail loudly, not silently default.
        let doc = TomlDoc::parse("[serving]\nfabric = \"yes\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[serving]\nadmission = \"drop-newest\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        // reject-over-slo without an SLO, and an SLO without the policy.
        let doc = TomlDoc::parse("[serving]\nadmission = \"reject-over-slo\"").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[serving]\nslo_ms = 100.0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse(
            "[serving]\nadmission = \"reject-over-slo\"\nslo_ms = -1.0",
        )
        .unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[serving]\nmax_inflight = 0").unwrap();
        assert!(SystemConfig::from_toml(&doc).is_err());
    }
}
