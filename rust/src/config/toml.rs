//! TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and flat arrays; `#` comments.
//! This covers every config file shipped in this repo.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
}

#[derive(Debug, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// A parsed document: `section.key -> value` (top-level keys use `""`
/// section, nested tables are flattened with dots).
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(TomlValue::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    /// Numeric array (ints coerce to floats); `None` when the key is
    /// absent, not an array, or contains non-numeric items.
    pub fn f64_array(&self, key: &str) -> Option<Vec<f64>> {
        match self.get(key)? {
            TomlValue::Array(items) => {
                items.iter().map(TomlValue::as_f64).collect::<Option<Vec<f64>>>()
            }
            _ => None,
        }
    }

    /// String array; `None` when the key is absent, not an array, or
    /// contains non-string items.
    pub fn str_array(&self, key: &str) -> Option<Vec<String>> {
        match self.get(key)? {
            TomlValue::Array(items) => items
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Option<Vec<String>>>(),
            _ => None,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for item in inner.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue;
                }
                items.push(parse_value(item)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # top comment
        name = "fedattn"   # trailing comment
        [federation]
        participants = 4
        sync_h = 2
        kv_ratio = 0.75
        schemes = ["uniform", "deep-half"]
        [network]
        star = true
        bandwidth_mbps = 100.5
    "#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("name", ""), "fedattn");
        assert_eq!(d.usize_or("federation.participants", 0), 4);
        assert_eq!(d.f64_or("federation.kv_ratio", 0.0), 0.75);
        assert!(d.bool_or("network.star", false));
        assert_eq!(d.f64_or("network.bandwidth_mbps", 0.0), 100.5);
        match d.get("federation.schemes").unwrap() {
            TomlValue::Array(a) => {
                assert_eq!(a[1].as_str(), Some("deep-half"));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("missing", 7), 7);
    }

    #[test]
    fn hash_inside_string_kept() {
        let d = TomlDoc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(d.str_or("tag", ""), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = \"open").is_err());
    }

    #[test]
    fn f64_array_accessor() {
        let d = TomlDoc::parse("bw = [100.0, 20, 50.5]\ns = \"x\"").unwrap();
        assert_eq!(d.f64_array("bw"), Some(vec![100.0, 20.0, 50.5]));
        assert_eq!(d.f64_array("s"), None);
        assert_eq!(d.f64_array("missing"), None);
        let d = TomlDoc::parse("mixed = [1, \"a\"]").unwrap();
        assert_eq!(d.f64_array("mixed"), None);
    }

    #[test]
    fn str_array_accessor() {
        let d = TomlDoc::parse(
            "hosts = [\"127.0.0.1:7070\", \"127.0.0.1:7071\"]\nn = 3",
        )
        .unwrap();
        assert_eq!(
            d.str_array("hosts"),
            Some(vec!["127.0.0.1:7070".to_string(), "127.0.0.1:7071".to_string()])
        );
        assert_eq!(d.str_array("n"), None);
        assert_eq!(d.str_array("missing"), None);
        let d = TomlDoc::parse("mixed = [\"a\", 1]").unwrap();
        assert_eq!(d.str_array("mixed"), None);
    }

    #[test]
    fn int_vs_float() {
        let d = TomlDoc::parse("a = 3\nb = 3.5\nc = 1e3").unwrap();
        assert_eq!(d.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(d.get("b").unwrap().as_f64(), Some(3.5));
        assert_eq!(d.get("c").unwrap().as_f64(), Some(1000.0));
    }
}
