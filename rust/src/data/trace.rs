//! Workload traces for the serving benches: Poisson task arrivals with
//! MicroFact episodes, mirroring the request traces used by serving-paper
//! evaluations (the paper's testbed traces are not public — substitution
//! per DESIGN.md).

use super::microfact::{gen_episode, Episode};
use crate::util::prng::{SplitMix64, Xoshiro256ss};

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    pub n_tasks: usize,
    /// Mean task inter-arrival time in milliseconds (exponential).
    pub mean_interarrival_ms: f64,
    pub min_facts: usize,
    pub max_facts: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { seed: 17, n_tasks: 32, mean_interarrival_ms: 50.0, min_facts: 3, max_facts: 6 }
    }
}

/// One queued collaborative-inference task.
#[derive(Debug, Clone)]
pub struct TraceTask {
    pub id: usize,
    /// Arrival offset from trace start, milliseconds.
    pub arrival_ms: f64,
    pub episode: Episode,
}

#[derive(Debug, Clone)]
pub struct WorkloadTrace {
    pub tasks: Vec<TraceTask>,
}

impl WorkloadTrace {
    pub fn generate(cfg: &TraceConfig) -> Self {
        let mut ep_rng = SplitMix64::new(cfg.seed);
        let mut arr_rng = Xoshiro256ss::new(cfg.seed ^ 0xA77);
        let mut t = 0.0f64;
        let tasks = (0..cfg.n_tasks)
            .map(|id| {
                let span = cfg.max_facts - cfg.min_facts + 1;
                let nf = cfg.min_facts + ep_rng.below(span as u64) as usize;
                let episode = gen_episode(&mut ep_rng, nf);
                // Exponential inter-arrival.
                let u = arr_rng.next_f64().max(1e-12);
                t += -cfg.mean_interarrival_ms * u.ln();
                TraceTask { id, arrival_ms: t, episode }
            })
            .collect();
        Self { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_tasks() {
        let tr = WorkloadTrace::generate(&TraceConfig { n_tasks: 10, ..Default::default() });
        assert_eq!(tr.len(), 10);
        // Arrivals are strictly increasing.
        for w in tr.tasks.windows(2) {
            assert!(w[0].arrival_ms < w[1].arrival_ms);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig { seed: 5, n_tasks: 6, ..Default::default() };
        let a = WorkloadTrace::generate(&cfg);
        let b = WorkloadTrace::generate(&cfg);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.episode.prompt(), y.episode.prompt());
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
    }

    #[test]
    fn mean_interarrival_approximate() {
        let cfg = TraceConfig {
            seed: 9,
            n_tasks: 2000,
            mean_interarrival_ms: 20.0,
            ..Default::default()
        };
        let tr = WorkloadTrace::generate(&cfg);
        let total = tr.tasks.last().unwrap().arrival_ms;
        let mean = total / tr.len() as f64;
        assert!((mean - 20.0).abs() < 2.0, "mean inter-arrival {mean}");
    }
}
