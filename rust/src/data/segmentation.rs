//! The paper's 2×2 input-segmentation grid (§VII-A2, Fig. 4).
//!
//! The global token sequence (BOS + facts + question) is partitioned into
//! `N` contiguous spans, one per participant; the N-th participant is the
//! *task publisher*.
//!
//!  * **TokQAg**  — Tok-seg : Q-ag.  Uniform split by token count; the
//!    question is distributed like any other tokens.
//!  * **TokQEx**  — Tok-seg : Q-ex.  Publisher gets exactly the question
//!    tokens; the fact tokens are split uniformly among the others.
//!  * **SemQAg**  — Sem-seg : Q-ag.  Split at semantic boundaries (whole
//!    facts / the question), balancing token counts.
//!  * **SemQEx**  — Sem-seg : Q-ex.  Publisher gets the question; whole
//!    facts are distributed among the others.

use super::microfact::Episode;
use crate::tokenizer;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Segmentation {
    TokQAg,
    TokQEx,
    SemQAg,
    SemQEx,
}

impl Segmentation {
    pub const ALL: [Segmentation; 4] = [
        Segmentation::TokQAg,
        Segmentation::TokQEx,
        Segmentation::SemQAg,
        Segmentation::SemQEx,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Segmentation::TokQAg => "tok-seg:q-ag",
            Segmentation::TokQEx => "tok-seg:q-ex",
            Segmentation::SemQAg => "sem-seg:q-ag",
            Segmentation::SemQEx => "sem-seg:q-ex",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|x| x.as_str() == s)
    }
}

/// A disjoint contiguous partition of the global prompt tokens.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Global token ids (BOS + prompt).
    pub ids: Vec<i32>,
    /// `spans[n] = (start, end)` global index range of participant `n`.
    pub spans: Vec<(usize, usize)>,
}

impl Partition {
    pub fn n_participants(&self) -> usize {
        self.spans.len()
    }

    pub fn publisher(&self) -> usize {
        self.spans.len() - 1
    }

    /// owners[i] = participant holding global token i.
    pub fn owners(&self) -> Vec<usize> {
        let mut o = vec![0usize; self.ids.len()];
        for (n, &(s, e)) in self.spans.iter().enumerate() {
            for i in s..e {
                o[i] = n;
            }
        }
        o
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn span_len(&self, n: usize) -> usize {
        self.spans[n].1 - self.spans[n].0
    }

    /// Longest span — determines the padded per-participant L variant.
    pub fn max_span_len(&self) -> usize {
        (0..self.spans.len()).map(|n| self.span_len(n)).max().unwrap_or(0)
    }

    fn check(&self) {
        debug_assert!(!self.spans.is_empty());
        debug_assert_eq!(self.spans[0].0, 0);
        debug_assert_eq!(self.spans.last().unwrap().1, self.ids.len());
        for w in self.spans.windows(2) {
            debug_assert_eq!(w[0].1, w[1].0, "spans must be contiguous");
        }
    }
}

/// Split `[0, total)` into `n` near-equal contiguous chunks (first chunks
/// get the remainder), never producing an empty chunk when `total >= n`.
fn even_spans(offset: usize, total: usize, n: usize) -> Vec<(usize, usize)> {
    let base = total / n;
    let rem = total % n;
    let mut out = Vec::with_capacity(n);
    let mut cur = offset;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        out.push((cur, cur + len));
        cur += len;
    }
    out
}

/// Group `unit_lens` into `n` contiguous groups with near-balanced token
/// mass (greedy by target cumulative share).
fn balanced_groups(offset: usize, unit_lens: &[usize], n: usize) -> Vec<(usize, usize)> {
    let total: usize = unit_lens.iter().sum();
    let mut out = Vec::with_capacity(n);
    let mut cur = offset;
    let mut unit = 0usize;
    for g in 0..n {
        let target = total * (g + 1) / n;
        let mut end = cur;
        let mut acc: usize = unit_lens[..unit].iter().sum();
        // Advance units until reaching this group's cumulative target, but
        // always leave at least (n - g - 1) units for the remaining groups.
        while unit < unit_lens.len()
            && (acc < target || end == cur)
            && unit_lens.len() - unit > n - g - 1
        {
            acc += unit_lens[unit];
            end += unit_lens[unit];
            unit += 1;
        }
        if g == n - 1 {
            // Last group takes everything left.
            while unit < unit_lens.len() {
                end += unit_lens[unit];
                unit += 1;
            }
        }
        out.push((cur, end));
        cur = end;
    }
    out
}

/// Build the partition of an episode for `n` participants under `seg`.
///
/// Token layout: `[BOS] facts... question` — BOS is assigned to the first
/// participant's span.
pub fn partition(ep: &Episode, n: usize, seg: Segmentation) -> Partition {
    assert!(n >= 1);
    let prompt = ep.prompt();
    let ids = tokenizer::encode_with_bos(&prompt);
    let total = ids.len();
    // +1 for BOS on all char offsets.
    let bounds = ep.boundaries();
    let q_start = bounds[bounds.len() - 1] + 1;

    if n == 1 {
        return Partition { ids, spans: vec![(0, total)] };
    }

    let spans = match seg {
        Segmentation::TokQAg => even_spans(0, total, n),
        Segmentation::TokQEx => {
            // Publisher (last) takes the question; others split the rest.
            let mut spans = even_spans(0, q_start, n - 1);
            spans.push((q_start, total));
            spans
        }
        Segmentation::SemQAg => {
            // Units: [BOS+fact0, fact1, ..., factK-1, question].
            let mut unit_lens = Vec::with_capacity(ep.facts.len() + 1);
            for i in 0..ep.facts.len() {
                let start = bounds[i] + 1;
                let end = if i + 1 < ep.facts.len() { bounds[i + 1] + 1 } else { q_start };
                let mut len = end - start;
                if i == 0 {
                    len += 1; // BOS rides with the first fact
                }
                unit_lens.push(len);
            }
            unit_lens.push(total - q_start);
            balanced_groups(0, &unit_lens, n)
        }
        Segmentation::SemQEx => {
            let mut unit_lens = Vec::with_capacity(ep.facts.len());
            for i in 0..ep.facts.len() {
                let start = bounds[i] + 1;
                let end = if i + 1 < ep.facts.len() { bounds[i + 1] + 1 } else { q_start };
                let mut len = end - start;
                if i == 0 {
                    len += 1;
                }
                unit_lens.push(len);
            }
            let mut spans = balanced_groups(0, &unit_lens, n - 1);
            spans.push((q_start, total));
            spans
        }
    };
    let p = Partition { ids, spans };
    p.check();
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::microfact::gen_episode;
    use crate::util::prng::{SplitMix64, Xoshiro256ss};
    use crate::util::propcheck::propcheck;

    fn check_partition(p: &Partition, n: usize) -> Result<(), String> {
        if p.spans.len() != n {
            return Err(format!("expected {n} spans, got {}", p.spans.len()));
        }
        if p.spans[0].0 != 0 || p.spans.last().unwrap().1 != p.ids.len() {
            return Err("spans do not cover sequence".into());
        }
        for w in p.spans.windows(2) {
            if w[0].1 != w[1].0 {
                return Err(format!("gap/overlap between spans: {w:?}"));
            }
        }
        for (i, &(s, e)) in p.spans.iter().enumerate() {
            if e <= s {
                return Err(format!("empty span {i}: ({s},{e})"));
            }
        }
        Ok(())
    }

    #[test]
    fn all_settings_produce_disjoint_cover() {
        propcheck(120, |rng| {
            let seed = rng.next_u64();
            let mut sm = SplitMix64::new(seed);
            let nf = 3 + rng.below(4) as usize;
            let ep = gen_episode(&mut sm, nf);
            let n = 2 + rng.below(4) as usize;
            if n - 1 > nf {
                return Ok(()); // Sem Q-ex needs >= one unit per non-publisher
            }
            for seg in Segmentation::ALL {
                let p = partition(&ep, n, seg);
                check_partition(&p, n)?;
            }
            Ok(())
        });
    }

    #[test]
    fn q_ex_publisher_holds_question() {
        let mut sm = SplitMix64::new(5);
        let ep = gen_episode(&mut sm, 4);
        for seg in [Segmentation::TokQEx, Segmentation::SemQEx] {
            let p = partition(&ep, 3, seg);
            let (s, e) = p.spans[p.publisher()];
            let text = tokenizer::decode(&p.ids[s..e]);
            assert!(text.starts_with("Q:"), "{seg:?}: publisher text {text:?}");
            assert!(text.ends_with("A:"));
        }
    }

    #[test]
    fn sem_q_ag_respects_fact_boundaries() {
        let mut sm = SplitMix64::new(6);
        let ep = gen_episode(&mut sm, 5);
        let p = partition(&ep, 3, Segmentation::SemQAg);
        // Every span must start at a unit boundary (BOS, a fact, or Q).
        for &(s, _) in &p.spans[1..] {
            let text = tokenizer::decode(&p.ids[s..]);
            let ok = text.starts_with("Q:")
                || ep.facts.iter().any(|f| text.starts_with(f.as_str()));
            assert!(ok, "span start not on a semantic boundary: {text:?}");
        }
    }

    #[test]
    fn n1_is_single_span() {
        let mut sm = SplitMix64::new(7);
        let ep = gen_episode(&mut sm, 4);
        let p = partition(&ep, 1, Segmentation::TokQAg);
        assert_eq!(p.spans, vec![(0, p.ids.len())]);
    }

    #[test]
    fn owners_match_spans() {
        let mut sm = SplitMix64::new(8);
        let ep = gen_episode(&mut sm, 4);
        let p = partition(&ep, 4, Segmentation::TokQAg);
        let o = p.owners();
        for (n, &(s, e)) in p.spans.iter().enumerate() {
            for i in s..e {
                assert_eq!(o[i], n);
            }
        }
    }

    #[test]
    fn even_spans_balanced() {
        let mut rng = Xoshiro256ss::new(1);
        for _ in 0..50 {
            let total = 1 + rng.below(500) as usize;
            let n = 1 + rng.below(8.min(total as u64)) as usize;
            let spans = even_spans(0, total, n);
            let lens: Vec<usize> = spans.iter().map(|&(s, e)| e - s).collect();
            let min = *lens.iter().min().unwrap();
            let max = *lens.iter().max().unwrap();
            assert!(max - min <= 1, "unbalanced: {lens:?}");
            assert_eq!(lens.iter().sum::<usize>(), total);
        }
    }
}
