//! Workload data: the MicroFact collaborative-QA generator (bit-identical
//! mirror of `python/compile/data.py`), the 2×2 input-segmentation grid of
//! the paper's §VII-A2, and workload traces for the serving benches.

pub mod microfact;
pub mod segmentation;
pub mod trace;

pub use microfact::{gen_episode, Episode, QKind};
pub use segmentation::{partition, Partition, Segmentation};
pub use trace::{TraceConfig, WorkloadTrace};
