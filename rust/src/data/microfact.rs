//! MicroFact episode generator — **bit-identical** to
//! `python/compile/data.py::gen_episode` (same SplitMix64 stream, same pool
//! order, same draw order).  Cross-language agreement is covered by a test
//! against episode fixtures generated at AOT time.

use crate::util::prng::SplitMix64;

pub const NAMES: [&str; 16] = [
    "Lia", "Omar", "Tess", "Ravi", "Noa", "Kai", "Mia", "Jon",
    "Zoe", "Eli", "Ana", "Max", "Ida", "Sam", "Uma", "Leo",
];
pub const ITEMS: [&str; 12] = [
    "plums", "coins", "books", "pens", "cards", "nuts", "cups", "keys",
    "bags", "hats", "rocks", "seeds",
];
pub const MIN_COUNT: u64 = 2;
pub const MAX_COUNT: u64 = 9;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QKind {
    /// Single-fact retrieval: "how many X does NAME have?"
    Get,
    /// Comparison: "who has more X, A or B?"
    Most,
    /// Two-fact sum: "how many X do A and B have in total?"
    Sum,
}

impl QKind {
    pub fn as_str(self) -> &'static str {
        match self {
            QKind::Get => "get",
            QKind::Most => "most",
            QKind::Sum => "sum",
        }
    }
}

/// One collaborative-QA episode.
#[derive(Debug, Clone)]
pub struct Episode {
    pub facts: Vec<String>,
    pub question: String,
    pub answer: String,
    pub kind: QKind,
}

impl Episode {
    /// Full prompt text: facts joined by spaces + question (ends in "A:").
    pub fn prompt(&self) -> String {
        format!("{} {}", self.facts.join(" "), self.question)
    }

    /// Character offset of each fact start and of the question start within
    /// [`Episode::prompt`] — the *semantic boundaries* used by Sem-seg.
    pub fn boundaries(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.facts.len() + 1);
        let mut pos = 0usize;
        for f in &self.facts {
            offs.push(pos);
            pos += f.len() + 1; // trailing space
        }
        offs.push(pos); // question start
        offs
    }
}

/// Mirror of the Python generator; draw order must not change.
pub fn gen_episode(rng: &mut SplitMix64, n_facts: usize) -> Episode {
    let item = ITEMS[rng.below(ITEMS.len() as u64) as usize];
    let mut idxs: Vec<usize> = Vec::with_capacity(n_facts);
    while idxs.len() < n_facts {
        let c = rng.below(NAMES.len() as u64) as usize;
        if !idxs.contains(&c) {
            idxs.push(c);
        }
    }
    let names: Vec<&str> = idxs.iter().map(|&i| NAMES[i]).collect();
    let counts: Vec<u64> = (0..n_facts)
        .map(|_| MIN_COUNT + rng.below(MAX_COUNT - MIN_COUNT + 1))
        .collect();
    let facts: Vec<String> = names
        .iter()
        .zip(&counts)
        .map(|(n, c)| format!("{n} has {c} {item}."))
        .collect();

    let a = rng.below(n_facts as u64) as usize;
    let mut b = rng.below(n_facts as u64) as usize;
    while b == a {
        b = rng.below(n_facts as u64) as usize;
    }
    let r = rng.below(10);
    let (kind, question, answer) = if r < 4 {
        (
            QKind::Get,
            format!("Q: how many {item} does {} have? A:", names[a]),
            counts[a].to_string(),
        )
    } else if r < 7 {
        let hi = if counts[a] >= counts[b] { a } else { b };
        (
            QKind::Most,
            format!("Q: who has more {item}, {} or {}? A:", names[a], names[b]),
            names[hi].to_string(),
        )
    } else {
        (
            QKind::Sum,
            format!(
                "Q: how many {item} do {} and {} have in total? A:",
                names[a], names[b]
            ),
            (counts[a] + counts[b]).to_string(),
        )
    };
    Episode { facts, question, answer, kind }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut r1 = SplitMix64::new(99);
        let mut r2 = SplitMix64::new(99);
        let e1 = gen_episode(&mut r1, 4);
        let e2 = gen_episode(&mut r2, 4);
        assert_eq!(e1.prompt(), e2.prompt());
        assert_eq!(e1.answer, e2.answer);
    }

    #[test]
    fn facts_count_and_format() {
        let mut rng = SplitMix64::new(1);
        for nf in 2..=6 {
            let ep = gen_episode(&mut rng, nf);
            assert_eq!(ep.facts.len(), nf);
            for f in &ep.facts {
                assert!(f.ends_with('.'), "fact should end with period: {f}");
                assert!(f.contains(" has "), "fact format: {f}");
            }
            assert!(ep.question.starts_with("Q: "));
            assert!(ep.question.ends_with("A:"));
        }
    }

    #[test]
    fn answer_is_consistent_with_facts() {
        let mut rng = SplitMix64::new(2);
        for _ in 0..200 {
            let ep = gen_episode(&mut rng, 4);
            match ep.kind {
                QKind::Get | QKind::Sum => {
                    let v: u64 = ep.answer.parse().expect("numeric answer");
                    assert!(v >= MIN_COUNT && v <= 2 * MAX_COUNT);
                }
                QKind::Most => {
                    assert!(NAMES.contains(&ep.answer.as_str()));
                }
            }
        }
    }

    #[test]
    fn boundaries_cover_prompt() {
        let mut rng = SplitMix64::new(3);
        let ep = gen_episode(&mut rng, 5);
        let b = ep.boundaries();
        assert_eq!(b.len(), 6);
        assert_eq!(b[0], 0);
        let prompt = ep.prompt();
        // Question boundary points exactly at "Q:".
        assert!(prompt[b[5]..].starts_with("Q:"));
        // Each fact boundary points at the fact text.
        for (i, f) in ep.facts.iter().enumerate() {
            assert!(prompt[b[i]..].starts_with(f.as_str()));
        }
    }
}
