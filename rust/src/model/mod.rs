//! Model metadata: AOT manifest parsing, model configuration, weights.

mod manifest;
mod weights;

pub use manifest::{ArtifactEntry, ArtifactKind, Manifest, ModelDims};
pub use weights::{Weights, BLOCK_PARAM_NAMES};

/// Ordered weight names for layer `m` (full block: 12 tensors).
pub fn weights_block_names(m: usize) -> Vec<String> {
    BLOCK_PARAM_NAMES.iter().map(|n| format!("blk{m}.{n}")).collect()
}

/// QKV-projection weight names (ln1, wq, bq, wk, bk, wv, bv).
pub fn weights_proj_names(m: usize) -> Vec<String> {
    BLOCK_PARAM_NAMES[..7].iter().map(|n| format!("blk{m}.{n}")).collect()
}

/// Attention-output + FFN weight names (wo, ln2, wg, wu, wd).
pub fn weights_attn_names(m: usize) -> Vec<String> {
    BLOCK_PARAM_NAMES[7..].iter().map(|n| format!("blk{m}.{n}")).collect()
}
