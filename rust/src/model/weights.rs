//! Model weights: loaded once from `artifacts/weights.npz` (written by the
//! Python trainer) and uploaded to the PJRT device as persistent buffers so
//! the request path never re-copies parameters (`execute_b`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};
use xla::FromRawBytes;

/// Named weight literals, host-side.  The runtime turns these into device
/// buffers at engine construction.
pub struct Weights {
    tensors: HashMap<String, xla::Literal>,
}

// SAFETY: `xla::Literal` owns immutable host memory; after construction the
// map is only ever read.  The raw pointer inside the wrapper is non-Send
// only because the xla crate does not assert thread-safety.
unsafe impl Send for Weights {}
unsafe impl Sync for Weights {}

/// Per-block weight order — must match
/// `python/compile/model.py::BLOCK_PARAM_NAMES` and the AOT input order.
pub const BLOCK_PARAM_NAMES: [&str; 12] = [
    "ln1", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "ln2", "wg", "wu", "wd",
];

impl Weights {
    pub fn load(path: &Path) -> Result<Self> {
        let pairs = xla::Literal::read_npz(path, &())
            .with_context(|| format!("reading weights npz {path:?}"))?;
        let tensors: HashMap<String, xla::Literal> = pairs.into_iter().collect();
        anyhow::ensure!(!tensors.is_empty(), "weights file {path:?} is empty");
        Ok(Self { tensors })
    }

    pub fn from_literals(tensors: HashMap<String, xla::Literal>) -> Self {
        Self { tensors }
    }

    pub fn get(&self, name: &str) -> Result<&xla::Literal> {
        self.tensors.get(name).with_context(|| format!("missing weight {name:?}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Ordered per-block weights for layer `m`.
    pub fn block(&self, m: usize) -> Result<Vec<&xla::Literal>> {
        BLOCK_PARAM_NAMES
            .iter()
            .map(|n| self.get(&format!("blk{m}.{n}")))
            .collect()
    }

    /// The QKV-projection prefix (ln1, wq, bq, wk, bk, wv, bv) of layer `m`.
    pub fn block_proj(&self, m: usize) -> Result<Vec<&xla::Literal>> {
        BLOCK_PARAM_NAMES[..7]
            .iter()
            .map(|n| self.get(&format!("blk{m}.{n}")))
            .collect()
    }

    /// The attention-output + FFN suffix (wo, ln2, wg, wu, wd) of layer `m`.
    pub fn block_attn(&self, m: usize) -> Result<Vec<&xla::Literal>> {
        BLOCK_PARAM_NAMES[7..]
            .iter()
            .map(|n| self.get(&format!("blk{m}.{n}")))
            .collect()
    }

    /// Validate completeness against the model dims.
    pub fn validate(&self, n_layers: usize) -> Result<()> {
        for name in ["emb", "ln_f", "w_out"] {
            self.get(name)?;
        }
        for m in 0..n_layers {
            self.block(m)?;
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|l| l.element_count()).sum()
    }

    /// Embedding row lookup on the host (tokenizer+embedding run locally at
    /// each participant per the paper; a [V, d] table gather is not worth a
    /// device round-trip).
    pub fn embed_rows(&self, ids: &[i32], d_model: usize) -> Result<Vec<f32>> {
        let emb = self.get("emb")?;
        let table = emb.to_vec::<f32>()?;
        let vocab = table.len() / d_model;
        let mut out = vec![0f32; ids.len() * d_model];
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize;
            anyhow::ensure!(id < vocab, "token id {id} out of vocab {vocab}");
            out[i * d_model..(i + 1) * d_model]
                .copy_from_slice(&table[id * d_model..(id + 1) * d_model]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_weights(n_layers: usize, d: usize) -> Weights {
        let mut t = HashMap::new();
        let mk = |n: usize| xla::Literal::vec1(&vec![0.5f32; n][..]);
        t.insert("emb".into(), mk(4 * d));
        t.insert("ln_f".into(), mk(d));
        t.insert("w_out".into(), mk(d * 4));
        for m in 0..n_layers {
            for name in BLOCK_PARAM_NAMES {
                t.insert(format!("blk{m}.{name}"), mk(d));
            }
        }
        Weights::from_literals(t)
    }

    #[test]
    fn validate_complete() {
        let w = fake_weights(2, 8);
        w.validate(2).unwrap();
        assert!(w.validate(3).is_err());
    }

    #[test]
    fn block_ordering() {
        let w = fake_weights(1, 8);
        let b = w.block(0).unwrap();
        assert_eq!(b.len(), 12);
        assert_eq!(w.block_proj(0).unwrap().len(), 7);
        assert_eq!(w.block_attn(0).unwrap().len(), 5);
    }

    #[test]
    fn embed_rows_lookup() {
        let mut t = HashMap::new();
        // vocab 3, d 2: rows [0,1],[2,3],[4,5]
        t.insert(
            "emb".to_string(),
            xla::Literal::vec1(&[0f32, 1., 2., 3., 4., 5.][..]),
        );
        let w = Weights::from_literals(t);
        let rows = w.embed_rows(&[2, 0], 2).unwrap();
        assert_eq!(rows, vec![4., 5., 0., 1.]);
        assert!(w.embed_rows(&[3], 2).is_err());
    }
}
