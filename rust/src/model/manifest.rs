//! `artifacts/manifest.json` — the contract between the Python AOT exporter
//! and the Rust runtime.  Describes the model dimensions and every lowered
//! HLO entry point (name, file, variant sizes, parameter order).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    BlockFused,
    QkvProject,
    AttnFfn,
    DecodeBlock,
    /// Decode over a frozen device-resident cache `[C]` plus a small
    /// growing tail `[R]` (device-resident execution; uploads O(R) per
    /// step instead of O(C)).
    DecodeTail,
    /// Cross-session batched decode-tail: a leading batch dim `[B]` on
    /// every activation/cache operand advances `B` independent sessions
    /// by one token in a single dispatch (weights broadcast).
    DecodeTailBatched,
    Logits,
    Embed,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "block_fused" => Self::BlockFused,
            "qkv_project" => Self::QkvProject,
            "attn_ffn" => Self::AttnFfn,
            "decode_block" => Self::DecodeBlock,
            "decode_tail" => Self::DecodeTail,
            "decode_tail_batched" => Self::DecodeTailBatched,
            "logits" => Self::Logits,
            "embed" => Self::Embed,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// Model dimensions mirrored from `python/compile/config.py::ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

impl ModelDims {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Bytes of one token's K+V pair (f32) — the unit of FedAttn's
    /// communication accounting (paper §VII-A3a).
    pub fn kv_row_bytes(&self) -> usize {
        2 * self.kv_dim() * 4
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub l: Option<usize>,
    pub g: Option<usize>,
    pub c: Option<usize>,
    /// Decode-tail capacity (rows appended during decode) for
    /// [`ArtifactKind::DecodeTail`] entries.
    pub r: Option<usize>,
    /// Batch width (concurrent sessions per dispatch) for
    /// [`ArtifactKind::DecodeTailBatched`] entries.
    pub b: Option<usize>,
    /// Input names in call order (weights included).
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub l_variants: Vec<usize>,
    pub g_variants: Vec<usize>,
    pub decode_cache: usize,
    /// Decode-tail variants (empty for artifact sets exported before the
    /// device-resident decode path existed — the runtime falls back to
    /// full-cache uploads).
    pub decode_tail_variants: Vec<usize>,
    /// Batch widths of the cross-session batched decode variants (empty
    /// for artifact sets exported before the serving fabric existed —
    /// the fabric falls back to per-session decode dispatches).
    pub decode_batch_variants: Vec<usize>,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let m = j.get("model").context("manifest: missing model")?;
        let get_usize = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k).and_then(Json::as_usize).with_context(|| format!("manifest: {k}"))
        };
        let model = ModelDims {
            name: m.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            vocab_size: get_usize(m, "vocab_size")?,
            d_model: get_usize(m, "d_model")?,
            n_layers: get_usize(m, "n_layers")?,
            n_heads: get_usize(m, "n_heads")?,
            n_kv_heads: get_usize(m, "n_kv_heads")?,
            head_dim: get_usize(m, "head_dim")?,
            d_ff: get_usize(m, "d_ff")?,
            rope_theta: m.get("rope_theta").and_then(Json::as_f64).unwrap_or(10_000.0),
            rms_eps: m.get("rms_eps").and_then(Json::as_f64).unwrap_or(1e-6),
        };
        let aot = j.get("aot").context("manifest: missing aot")?;
        let arr_usize = |k: &str| -> Result<Vec<usize>> {
            Ok(aot
                .get(k)
                .and_then(Json::as_arr)
                .with_context(|| format!("manifest: aot.{k}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let entries_json =
            j.get("entries").and_then(Json::as_arr).context("manifest: entries")?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let name =
                e.get("name").and_then(Json::as_str).context("entry name")?.to_string();
            let file = dir.join(e.get("file").and_then(Json::as_str).context("entry file")?);
            let kind =
                ArtifactKind::parse(e.get("kind").and_then(Json::as_str).context("kind")?)?;
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .context("entry inputs")?
                .iter()
                .filter_map(|i| i.get("name").and_then(Json::as_str))
                .map(str::to_string)
                .collect();
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("entry outputs")?
                .iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect();
            entries.push(ArtifactEntry {
                name,
                file,
                kind,
                l: e.get("l").and_then(Json::as_usize),
                g: e.get("g").and_then(Json::as_usize),
                c: e.get("c").and_then(Json::as_usize),
                r: e.get("r").and_then(Json::as_usize),
                b: e.get("b").and_then(Json::as_usize),
                inputs,
                outputs,
            });
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            model,
            l_variants: arr_usize("l_variants")?,
            g_variants: arr_usize("g_variants")?,
            decode_cache: aot.get("decode_cache").and_then(Json::as_usize).unwrap_or(0),
            // Absent in pre-device-resident manifests: default to none.
            decode_tail_variants: aot
                .get("decode_tail")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            // Absent in pre-serving-fabric manifests: default to none.
            decode_batch_variants: aot
                .get("decode_batch")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            entries,
        })
    }

    /// Smallest L variant that fits `len` tokens.
    pub fn pick_l(&self, len: usize) -> Result<usize> {
        self.l_variants
            .iter()
            .copied()
            .filter(|&l| l >= len)
            .min()
            .with_context(|| format!("no L variant fits {len} tokens (max {:?})", self.l_variants.iter().max()))
    }

    /// Smallest G variant that fits `len` global KV rows.
    pub fn pick_g(&self, len: usize) -> Result<usize> {
        self.g_variants
            .iter()
            .copied()
            .filter(|&g| g >= len)
            .min()
            .with_context(|| format!("no G variant fits {len} KV rows (max {:?})", self.g_variants.iter().max()))
    }

    /// Smallest decode-tail variant with room for `len` appended rows;
    /// `None` when the artifact set predates the device-resident decode
    /// path (callers fall back to full-cache uploads).
    pub fn pick_decode_tail(&self, len: usize) -> Option<usize> {
        self.decode_tail_variants.iter().copied().filter(|&r| r >= len).min()
    }

    /// Smallest batched-decode width that fits `n` concurrent sessions;
    /// `None` when the artifact set has no batched variants (the serving
    /// fabric falls back to per-session decode dispatches).
    pub fn pick_decode_batch(&self, n: usize) -> Option<usize> {
        self.decode_batch_variants.iter().copied().filter(|&b| b >= n).min()
    }

    /// Largest batched-decode width available, if any — the fabric's
    /// cohort-size ceiling.
    pub fn max_decode_batch(&self) -> Option<usize> {
        self.decode_batch_variants.iter().copied().max()
    }

    pub fn find(&self, kind: ArtifactKind, l: Option<usize>, g: Option<usize>) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.l == l && (g.is_none() || e.g == g))
            .with_context(|| format!("no artifact kind={kind:?} l={l:?} g={g:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "model": {"name":"t","vocab_size":128,"d_model":96,"n_layers":8,
                "n_heads":4,"n_kv_heads":2,"head_dim":24,"d_ff":256,
                "rope_theta":10000.0,"rms_eps":1e-6,"qkv_bias":true},
      "aot": {"l_variants":[32,64],"g_variants":[128],"decode_cache":448,
              "block_q":32,"block_kv":64},
      "entries": [
        {"name":"block_fused_L32","file":"block_fused_L32.hlo.txt",
         "kind":"block_fused","l":32,"g":32,
         "inputs":[{"name":"x","dtype":"float32","shape":[32,96]}],
         "outputs":["x_out","k","v"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.model.d_model, 96);
        assert_eq!(m.model.kv_row_bytes(), 2 * 48 * 4);
        assert_eq!(m.l_variants, vec![32, 64]);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].kind, ArtifactKind::BlockFused);
        assert_eq!(m.entries[0].outputs, vec!["x_out", "k", "v"]);
    }

    #[test]
    fn pick_variants() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.pick_l(10).unwrap(), 32);
        assert_eq!(m.pick_l(33).unwrap(), 64);
        assert!(m.pick_l(65).is_err());
        assert_eq!(m.pick_g(100).unwrap(), 128);
    }

    #[test]
    fn decode_tail_variants_optional() {
        // SAMPLE predates decode_tail: no variants, pick falls back to None.
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert!(m.decode_tail_variants.is_empty());
        assert_eq!(m.pick_decode_tail(8), None);

        let with_tail = SAMPLE.replace(
            "\"decode_cache\":448,",
            "\"decode_cache\":448,\"decode_tail\":[16,32],",
        );
        let j = Json::parse(&with_tail).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.decode_tail_variants, vec![16, 32]);
        assert_eq!(m.pick_decode_tail(8), Some(16));
        assert_eq!(m.pick_decode_tail(17), Some(32));
        assert_eq!(m.pick_decode_tail(33), None);
    }

    #[test]
    fn decode_batch_variants_optional() {
        // SAMPLE predates the serving fabric: no batched variants, picks
        // fall back to None (per-session decode dispatch).
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert!(m.decode_batch_variants.is_empty());
        assert_eq!(m.pick_decode_batch(2), None);
        assert_eq!(m.max_decode_batch(), None);

        let with_batch = SAMPLE.replace(
            "\"decode_cache\":448,",
            "\"decode_cache\":448,\"decode_batch\":[2,4,8],",
        );
        let j = Json::parse(&with_batch).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert_eq!(m.decode_batch_variants, vec![2, 4, 8]);
        assert_eq!(m.pick_decode_batch(1), Some(2));
        assert_eq!(m.pick_decode_batch(3), Some(4));
        assert_eq!(m.pick_decode_batch(9), None);
        assert_eq!(m.max_decode_batch(), Some(8));
    }

    #[test]
    fn parses_batched_kind() {
        let with_entry = SAMPLE.replace(
            "\"outputs\":[\"x_out\",\"k\",\"v\"]}",
            "\"outputs\":[\"x_out\",\"k\",\"v\"]},
        {\"name\":\"decode_tail_B4_C448_R16\",\"file\":\"decode_tail_B4_C448_R16.hlo.txt\",
         \"kind\":\"decode_tail_batched\",\"b\":4,\"c\":448,\"r\":16,
         \"inputs\":[{\"name\":\"x\",\"dtype\":\"float32\",\"shape\":[4,1,96]}],
         \"outputs\":[\"x_out\",\"k_new\",\"v_new\"]}",
        );
        let j = Json::parse(&with_entry).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        let e = &m.entries[1];
        assert_eq!(e.kind, ArtifactKind::DecodeTailBatched);
        assert_eq!(e.b, Some(4));
        assert_eq!(e.r, Some(16));
    }

    #[test]
    fn find_artifact() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/a"), &j).unwrap();
        assert!(m.find(ArtifactKind::BlockFused, Some(32), None).is_ok());
        assert!(m.find(ArtifactKind::BlockFused, Some(64), None).is_err());
    }
}
