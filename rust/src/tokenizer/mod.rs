//! Byte-level ASCII tokenizer — mirrors `python/compile/data.py`.
//!
//! Printable ASCII chars (32..=126) map to their own codes; `PAD=0`,
//! `BOS=1`, `EOS=2`.  Vocab size 128 matches the model's embedding table.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const VOCAB_SIZE: usize = 128;

/// Encode text; non-ASCII and control characters are dropped (same as the
/// Python side's `errors="ignore"` + printable filter).
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes()
        .filter(|&b| (32..127).contains(&b))
        .map(|b| b as i32)
        .collect()
}

/// Encode with a leading BOS.
pub fn encode_with_bos(text: &str) -> Vec<i32> {
    let mut ids = Vec::with_capacity(text.len() + 1);
    ids.push(BOS);
    ids.extend(encode(text));
    ids
}

/// Decode ids back to text, skipping specials / padding.
pub fn decode(ids: &[i32]) -> String {
    ids.iter()
        .filter(|&&i| (32..127).contains(&i))
        .map(|&i| i as u8 as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn roundtrip_printable() {
        let s = "Lia has 7 plums. Q: who? A: Lia";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn drops_non_ascii_and_controls() {
        assert_eq!(decode(&encode("a\nb\tc\u{e9}d")), "abcd");
    }

    #[test]
    fn bos_prefix() {
        let ids = encode_with_bos("hi");
        assert_eq!(ids[0], BOS);
        assert_eq!(&ids[1..], &encode("hi")[..]);
    }

    #[test]
    fn decode_skips_specials() {
        assert_eq!(decode(&[BOS, 72, 105, EOS, PAD]), "Hi");
    }

    #[test]
    fn encode_ids_in_vocab_property() {
        propcheck(100, |rng| {
            let len = rng.below(64) as usize;
            let s: String = (0..len)
                .map(|_| char::from_u32(rng.below(0x250) as u32).unwrap_or('x'))
                .collect();
            let ids = encode(&s);
            for &i in &ids {
                if !(0..VOCAB_SIZE as i32).contains(&i) {
                    return Err(format!("id {i} out of vocab"));
                }
            }
            // Round-trip through decode must be a fixed point.
            let d = decode(&ids);
            if encode(&d) != ids {
                return Err("decode/encode not a fixed point".into());
            }
            Ok(())
        });
    }
}
