//! Small statistics helpers: running summaries, percentiles, timers.

use std::time::{Duration, Instant};

/// Online summary of a scalar series (mean/min/max/std via Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }
}

/// Percentile over a copy of the samples (nearest-rank on sorted data).
///
/// Total by construction: an empty slice yields 0.0 (serve reports with
/// zero completed tasks must not leak NaN into BENCH JSON), `p` is
/// clamped to `[0, 100]`, and NaN samples sort last instead of panicking
/// the comparator.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((49.0..=51.0).contains(&p50));
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }

    #[test]
    fn empty_percentile_is_zero_not_nan() {
        // Regression: used to return NaN, which flowed into BENCH JSON
        // whenever a serve run completed zero tasks.
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
    }

    #[test]
    fn percentile_total_on_hostile_inputs() {
        // NaN samples sort last instead of panicking the comparator, and
        // out-of-range p is clamped.
        let v = percentile(&[2.0, f64::NAN, 1.0], 0.0);
        assert_eq!(v, 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 250.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
    }
}
