//! Shared utilities: PRNGs, JSON, statistics, property testing, logging.

pub mod json;
pub mod log;
pub mod prng;
pub mod propcheck;
pub mod stats;
