//! Deterministic PRNGs shared with the Python build path.
//!
//! `SplitMix64` is kept **bit-identical** to `python/compile/data.py` so the
//! MicroFact episodes used for training (Python) and serving workloads
//! (Rust) are drawn from the same stream; cross-language agreement is pinned
//! by fixtures.  `Xoshiro256ss` (seeded from SplitMix64, per Vigna's
//! recommendation) is the general-purpose generator for sparsity sampling,
//! network jitter and property tests.

/// SplitMix64 — mirrors `compile.data.SplitMix64`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)` (modulo method, same as the Python side).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// xoshiro256** 1.0 — general-purpose generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`; unbiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// Bernoulli draw with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_python_reference() {
        // First outputs of SplitMix64(seed=0); the Python implementation in
        // compile/data.py produces the identical stream (verified fixture).
        let mut r = SplitMix64::new(0);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                0xE220A8397B1DCDAF,
                0x6E789E6AA1B965F4,
                0x06C45D188009454F,
                0xF88BB8A8724C81EC,
            ]
        );
    }

    #[test]
    fn splitmix_below_is_mod() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            let n = 1 + b.next_u64() % 1000;
            let mut a2 = a.clone();
            assert_eq!(a.below(n), a2.next_u64() % n);
            let _ = b; // keep streams independent
            break;
        }
    }

    #[test]
    fn xoshiro_uniform_range() {
        let mut r = Xoshiro256ss::new(7);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let v = r.below(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn xoshiro_normal_moments() {
        let mut r = Xoshiro256ss::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Xoshiro256ss::new(3);
        for _ in 0..50 {
            let s = r.sample_indices(40, 13);
            assert_eq!(s.len(), 13);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 40));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256ss::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
