//! Miniature property-based testing harness (proptest is unavailable
//! offline).  Runs a property against many PRNG-generated cases and, on
//! failure, reports the seed so the case can be replayed deterministically.
//!
//! ```ignore
//! propcheck(100, |rng| {
//!     let n = 1 + rng.below(50) as usize;
//!     let v = gen_partition(rng, n);
//!     check_partition_invariants(&v)   // -> Result<(), String>
//! });
//! ```

use super::prng::Xoshiro256ss;

/// Run `cases` random cases of `prop`.  Panics with the failing seed and
/// message on the first violation.
pub fn propcheck<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256ss) -> Result<(), String>,
{
    propcheck_seeded(0xFEDA_77_u64, cases, &mut prop);
}

/// Like [`propcheck`] with an explicit base seed (replay a failure by
/// passing the seed printed in the panic message).
pub fn propcheck_seeded<F>(base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Xoshiro256ss) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256ss::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper returning `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        propcheck(50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        propcheck(50, |rng| {
            let a = rng.below(100);
            if a < 90 {
                Ok(())
            } else {
                Err(format!("a = {a}"))
            }
        });
    }

    #[test]
    fn prop_assert_macro() {
        propcheck(10, |rng| {
            let x = rng.next_f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }
}
