//! Tiny leveled logger to stderr (the `log` crate facade is wired to this).
//!
//! Controlled by `FEDATTN_LOG` = `error|warn|info|debug|trace` (default
//! `info`).  The serving hot path logs nothing below `debug`.

use std::sync::OnceLock;

struct StderrLogger {
    max: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, md: &log::Metadata) -> bool {
        md.level() <= self.max
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:5}] {}: {}",
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger once; later calls are no-ops.
pub fn init() {
    let level = std::env::var("FEDATTN_LOG").unwrap_or_default();
    let max = match level.as_str() {
        "error" => log::LevelFilter::Error,
        "warn" => log::LevelFilter::Warn,
        "debug" => log::LevelFilter::Debug,
        "trace" => log::LevelFilter::Trace,
        "off" => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { max });
    let _ = log::set_logger(logger);
    log::set_max_level(max);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
