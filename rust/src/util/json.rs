//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! The parser covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null) — enough for `artifacts/manifest.json`
//! and config interchange.  The writer emits compact, valid JSON used by the
//! bench harness and metrics reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience builder for JSON objects.
#[derive(Default)]
pub struct JsonBuilder {
    map: BTreeMap<String, Json>,
}

impl JsonBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(mut self, k: &str, v: Json) -> Self {
        self.map.insert(k.to_string(), v);
        self
    }

    pub fn num(self, k: &str, v: f64) -> Self {
        self.set(k, Json::Num(v))
    }

    pub fn str(self, k: &str, v: &str) -> Self {
        self.set(k, Json::Str(v.to_string()))
    }

    pub fn arr_num(self, k: &str, vs: &[f64]) -> Self {
        self.set(k, Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect()))
    }

    pub fn build(self) -> Json {
        Json::Obj(self.map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"fig5","series":[1,2.5,3],"ok":true,"note":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn builder() {
        let j = JsonBuilder::new().num("h", 4.0).str("seg", "sem").build();
        assert_eq!(j.get("h").unwrap().as_usize(), Some(4));
    }
}
