//! Communication-cost models for the conventional model-parallel paradigms
//! (paper §II-B) vs FedAttn (§II-C.2).

use crate::model::ModelDims;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelismKind {
    /// Layer-wise partitioning: activations [L, d] cross nodes once per
    /// stage boundary.
    Pipeline,
    /// Hidden-dimension sharding: all-reduce of [L, d] after the attention
    /// and FFN linear transformations of *every* block.
    Tensor,
    /// This paper: K/V matrices [L, 2·kv_dim] exchanged every H blocks.
    FedAttn,
}

impl ParallelismKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ParallelismKind::Pipeline => "pipeline",
            ParallelismKind::Tensor => "tensor",
            ParallelismKind::FedAttn => "fedattn",
        }
    }
}

/// Analytic per-inference communication cost.
#[derive(Debug, Clone, Copy)]
pub struct CommCost {
    pub dims_bytes_per_elem: usize,
}

impl Default for CommCost {
    fn default() -> Self {
        Self { dims_bytes_per_elem: 4 }
    }
}

impl CommCost {
    /// Total bytes moved across node boundaries during one prefill of a
    /// length-`l` sequence on `n` nodes with sync interval `h` (FedAttn
    /// only; ignored otherwise).
    pub fn prefill_bytes(
        &self,
        kind: ParallelismKind,
        md: &ModelDims,
        l: usize,
        n: usize,
        h: usize,
    ) -> f64 {
        let b = self.dims_bytes_per_elem as f64;
        let d = md.d_model as f64;
        let lf = l as f64;
        match kind {
            ParallelismKind::Pipeline => {
                // n stages ⇒ (n-1) boundary crossings of the [L, d]
                // activations.
                (n as f64 - 1.0) * lf * d * b
            }
            ParallelismKind::Tensor => {
                // Ring all-reduce of [L, d] after each of the 2 linear
                // groups per block: 2(n-1)/n · L·d per all-reduce, on every
                // node ⇒ total 2·2(n-1)·L·d per block.
                let per_allreduce = 2.0 * (n as f64 - 1.0) * lf * d * b;
                2.0 * md.n_layers as f64 * per_allreduce
            }
            ParallelismKind::FedAttn => {
                // Every H blocks each node uplinks its local K/V
                // ([L/n, 2·kv_dim]) and downlinks the remote rows.
                let rounds = (md.n_layers as f64 / h as f64).floor();
                let kv_row = 2.0 * md.kv_dim() as f64 * b;
                let up = lf * kv_row; // all rows cross once (sum over nodes)
                let down = (n as f64 - 1.0) / n as f64 * lf * kv_row * n as f64;
                rounds * (up + down)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "t".into(),
            vocab_size: 128,
            d_model: 96,
            n_layers: 8,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 24,
            d_ff: 256,
            rope_theta: 1e4,
            rms_eps: 1e-6,
        }
    }

    #[test]
    fn tensor_parallelism_most_expensive() {
        let cc = CommCost::default();
        let md = dims();
        let tp = cc.prefill_bytes(ParallelismKind::Tensor, &md, 256, 4, 2);
        let pp = cc.prefill_bytes(ParallelismKind::Pipeline, &md, 256, 4, 2);
        let fa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 2);
        assert!(tp > pp, "tensor {tp} vs pipeline {pp}");
        assert!(tp > fa, "tensor {tp} vs fedattn {fa}");
    }

    #[test]
    fn fedattn_cost_decreases_with_h() {
        let cc = CommCost::default();
        let md = dims();
        let c2 = cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 2);
        let c4 = cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 4);
        let c8 = cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 8);
        assert!(c2 > c4 && c4 > c8);
        assert!((c2 / c4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gqa_reduces_fedattn_cost() {
        // §II-C: FedAttn directly benefits from grouped-query attention.
        let cc = CommCost::default();
        let mut md = dims();
        let full = {
            md.n_kv_heads = 4;
            cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 2)
        };
        md.n_kv_heads = 2;
        let gqa = cc.prefill_bytes(ParallelismKind::FedAttn, &md, 256, 4, 2);
        assert!((full / gqa - 2.0).abs() < 1e-9);
    }
}
