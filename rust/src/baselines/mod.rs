//! Baselines the paper compares against.
//!
//! * **CenAttn / LocAttn** are degenerate FedAttn configurations
//!   (`H = 1` with `N = 1` span, and no sync, respectively) — built from
//!   the same session machinery so comparisons are apples-to-apples.
//! * **Pipeline / Tensor parallelism** communication-cost models (§II-B):
//!   FedAttn's headline efficiency claim is against these; they are
//!   analytic functions of the architecture, reproduced here exactly as
//!   the paper describes them.

mod parallelism;

pub use parallelism::{CommCost, ParallelismKind};
