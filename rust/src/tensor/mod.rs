//! Minimal host-side tensor type + `xla::Literal` interop.
//!
//! The coordinator only needs dense f32/i32 arrays with shape bookkeeping:
//! hidden states, K/V buffers and additive masks that it scatters/gathers
//! between participants.  All heavy math lives in the AOT HLO artifacts.

mod device;
mod host;
pub use device::DeviceTensor;
pub use host::{i32_literal, HostTensor, TensorError, NEG_MASK};
