//! Dense row-major f32 host tensor with Literal conversion.

use anyhow::Result;

/// Additive-mask value for invisible positions.  Must match
/// `python/compile/kernels/ref.py::NEG`.
pub const NEG_MASK: f32 = -1e30;

#[derive(Debug, thiserror::Error)]
pub enum TensorError {
    #[error("shape mismatch: expected {expected:?}, got {got:?}")]
    Shape { expected: Vec<usize>, got: Vec<usize> },
    #[error("length {len} does not match shape {shape:?}")]
    Length { len: usize, shape: Vec<usize> },
}

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self, TensorError> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(TensorError::Length { len: data.len(), shape: shape.to_vec() });
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let numel: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let numel: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; numel] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Row stride for a 2-D-style view: elements per leading-index slice.
    pub fn row_len(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Borrow row `i` (leading dimension index).
    pub fn row(&self, i: usize) -> &[f32] {
        let rl = self.row_len();
        &self.data[i * rl..(i + 1) * rl]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let rl = self.row_len();
        &mut self.data[i * rl..(i + 1) * rl]
    }

    /// Copy `src`'s rows `[0, n)` into our rows starting at `dst_row`.
    pub fn copy_rows_from(&mut self, src: &HostTensor, src_rows: std::ops::Range<usize>, dst_row: usize) {
        let rl = self.row_len();
        assert_eq!(rl, src.row_len(), "row length mismatch");
        let n = src_rows.end - src_rows.start;
        let dst = &mut self.data[dst_row * rl..(dst_row + n) * rl];
        dst.copy_from_slice(&src.data[src_rows.start * rl..src_rows.end * rl]);
    }

    /// Frobenius norm of (self - other) over the first `rows` rows.
    pub fn frob_dist_rows(&self, other: &HostTensor, rows: usize) -> f64 {
        let rl = self.row_len();
        assert_eq!(rl, other.row_len());
        let n = rows * rl;
        self.data[..n]
            .iter()
            .zip(&other.data[..n])
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Max absolute difference over all elements.
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    // ---- Literal interop -------------------------------------------------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(self.data.as_slice());
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Self::new(&dims, data).map_err(anyhow::Error::from)?)
    }
}

/// i32 companion used for token ids / positions.
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(numel == data.len(), "i32 literal shape mismatch");
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_length() {
        assert!(HostTensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_copy() {
        let mut a = HostTensor::zeros(&[4, 3]);
        let b = HostTensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        a.copy_rows_from(&b, 0..2, 1);
        assert_eq!(a.row(0), &[0., 0., 0.]);
        assert_eq!(a.row(1), &[1., 2., 3.]);
        assert_eq!(a.row(2), &[4., 5., 6.]);
        assert_eq!(a.row(3), &[0., 0., 0.]);
    }

    #[test]
    fn frobenius_distance() {
        let a = HostTensor::new(&[2, 2], vec![1., 0., 0., 0.]).unwrap();
        let b = HostTensor::zeros(&[2, 2]);
        assert!((a.frob_dist_rows(&b, 2) - 1.0).abs() < 1e-12);
        assert!((a.frob_dist_rows(&b, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::new(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_literal_roundtrip() {
        let lit = i32_literal(&[4], &[1, 2, 3, 4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }
}
