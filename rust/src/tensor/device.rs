//! Device-resident tensor handles.
//!
//! A [`DeviceTensor`] wraps one `xla::PjRtBuffer` together with its host
//! shape, so hot paths can keep activations and KV buffers on the device
//! and pass *handles* between engine calls instead of re-uploading host
//! data per call.  The two wins this enables (paper §VI trade-off):
//!
//! * **Shared sync-round KV** — the packed global KV is uploaded once per
//!   sync round and every attendee's `attn_ffn` call borrows the same
//!   buffers (upload bytes drop ~N× per round).
//! * **Frozen decode caches** — after prefill, each block's KV cache and
//!   its visibility mask are uploaded once; every decode step then ships
//!   only the small growing tail, so per-token upload bytes are O(1) in
//!   the cache capacity `C`.
//!
//! PJRT device buffers are immutable once created, and the executable
//! output path materialises results on the host (the lowered entry points
//! return one tuple literal), so the handle API is *input-side*: callers
//! upload with [`DeviceTensor::upload`] / `Engine::upload` and the engine
//! threads the buffers straight into `execute_b`.  The sharing invariant
//! is therefore trivially safe: a shared device KV is read-only across
//! attendees by construction.

use std::sync::Arc;

use anyhow::Result;

use super::host::HostTensor;

/// A device-resident f32 tensor: an immutable PJRT buffer plus host-side
/// shape bookkeeping.  Cheaply cloneable (the buffer is shared via `Arc`).
#[derive(Clone)]
pub struct DeviceTensor {
    buf: Arc<xla::PjRtBuffer>,
    shape: Vec<usize>,
}

// SAFETY: PJRT's API guarantees thread-safe buffer use (the same guarantee
// `runtime::Engine` relies on for its client/executable/weight buffers);
// the raw pointer inside the xla crate wrapper is only non-Send because
// the crate does not assert this.  The buffer is never mutated after
// construction.
unsafe impl Send for DeviceTensor {}
unsafe impl Sync for DeviceTensor {}

impl std::fmt::Debug for DeviceTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceTensor").field("shape", &self.shape).finish()
    }
}

impl DeviceTensor {
    /// Upload a host tensor to the device.  Does *not* touch any engine
    /// counters — use `Engine::upload` on the hot path so the bytes are
    /// accounted.
    pub fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<Self> {
        let buf = client.buffer_from_host_buffer(t.data(), t.shape(), None)?;
        Ok(Self { buf: Arc::new(buf), shape: t.shape().to_vec() })
    }

    /// Wrap an already-created buffer (engine-internal).
    pub(crate) fn from_parts(buf: Arc<xla::PjRtBuffer>, shape: Vec<usize>) -> Self {
        Self { buf, shape }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes this tensor occupies (f32).
    pub fn byte_len(&self) -> u64 {
        4 * self.numel() as u64
    }

    /// The underlying shared buffer (for `execute_b` argument lists).
    pub(crate) fn buffer(&self) -> Arc<xla::PjRtBuffer> {
        Arc::clone(&self.buf)
    }

    /// Copy the tensor back to the host (device → host transfer).
    pub fn to_host(&self) -> Result<HostTensor> {
        let lit = self.buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Device round-trip needs a live PJRT client (the native xla_extension
    // library), which every test binary in this crate already links.
    #[test]
    fn upload_roundtrip_preserves_shape_and_data() {
        let client = xla::PjRtClient::cpu().expect("PJRT CPU client");
        let t = HostTensor::new(&[2, 3, 2], (0..12).map(|i| i as f32 * 0.5).collect())
            .unwrap();
        let d = DeviceTensor::upload(&client, &t).unwrap();
        assert_eq!(d.shape(), &[2, 3, 2]);
        assert_eq!(d.numel(), 12);
        assert_eq!(d.byte_len(), 48);
        let back = d.to_host().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn clones_share_the_buffer() {
        let client = xla::PjRtClient::cpu().expect("PJRT CPU client");
        let t = HostTensor::zeros(&[4, 4]);
        let a = DeviceTensor::upload(&client, &t).unwrap();
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.buf, &b.buf), "clone must not copy device memory");
        assert_eq!(b.to_host().unwrap(), t);
    }
}
