//! The execution engine.
//!
//! * HLO **text** artifacts (not serialized protos — xla_extension 0.5.1
//!   rejects jax≥0.5 64-bit instruction ids) are parsed with
//!   `HloModuleProto::from_text_file` and compiled lazily per variant.
//! * Weights are uploaded to the device **once** and every call passes
//!   device buffers (`execute_b`), so the hot path only uploads activations.
//! * Device-resident activations: hot-path entry points accept
//!   [`DeviceTensor`] handles for their large, reused inputs (the packed
//!   global KV at sync blocks, the frozen decode caches), so one upload
//!   serves many executions.  `EngineStats.upload_bytes_saved` measures
//!   exactly the bytes those handles avoided re-uploading.
//! * Thread safety: the PJRT CPU client is thread-safe (XLA guarantees
//!   thread-safe `Compile`/`Execute`); Rust-side maps are guarded by locks.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::model::{ArtifactKind, Manifest, Weights};
use crate::tensor::{DeviceTensor, HostTensor};

/// Cumulative engine counters (perf accounting).
///
/// Byte counters:
/// * `bytes_uploaded` — activation bytes shipped host→device on the
///   request path (inputs to `execute_b`, including explicit
///   [`Engine::upload`] calls).  Weight uploads are **not** included.
/// * `weight_bytes_uploaded` — one-time weight-literal uploads (first use
///   per weight; cached afterwards).
/// * `upload_bytes_saved` — bytes a call did *not* upload because the
///   caller passed an already-resident [`DeviceTensor`] handle instead of
///   host data; counted **per call** consuming the handle.  Net savings
///   vs an all-host-path engine are therefore `upload_bytes_saved` minus
///   the one explicit upload each handle cost (already in
///   `bytes_uploaded`) — with `a` consumers per handle, the host-only
///   engine would ship `a×`, this one ships `1×`.
///
/// Per-entry-point execution counters (`exec_*`) split `executions` by
/// lowered artifact family, so benches can report dispatch mixes.
#[derive(Debug, Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub compiles: AtomicU64,
    pub bytes_uploaded: AtomicU64,
    pub weight_bytes_uploaded: AtomicU64,
    pub upload_bytes_saved: AtomicU64,
    pub exec_nanos: AtomicU64,
    pub exec_block_fused: AtomicU64,
    pub exec_qkv_project: AtomicU64,
    pub exec_attn_ffn: AtomicU64,
    pub exec_decode_block: AtomicU64,
    pub exec_decode_tail: AtomicU64,
    /// Batched cross-session decode dispatches (one per cohort step).
    pub exec_decode_tail_batched: AtomicU64,
    /// Session-slots advanced by batched dispatches (Σ batch widths) —
    /// `batched_decode_rows / exec_decode_tail_batched` is the realized
    /// mean batch width.
    pub batched_decode_rows: AtomicU64,
    pub exec_logits: AtomicU64,
}

/// Plain-value copy of [`EngineStats`] at one instant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineStatsView {
    pub executions: u64,
    pub compiles: u64,
    pub bytes_uploaded: u64,
    pub weight_bytes_uploaded: u64,
    pub upload_bytes_saved: u64,
    pub exec_seconds: f64,
    pub exec_block_fused: u64,
    pub exec_qkv_project: u64,
    pub exec_attn_ffn: u64,
    pub exec_decode_block: u64,
    pub exec_decode_tail: u64,
    pub exec_decode_tail_batched: u64,
    pub batched_decode_rows: u64,
    pub exec_logits: u64,
}

impl EngineStats {
    /// Full counter snapshot (all fields, plain values).
    pub fn view(&self) -> EngineStatsView {
        EngineStatsView {
            executions: self.executions.load(Ordering::Relaxed),
            compiles: self.compiles.load(Ordering::Relaxed),
            bytes_uploaded: self.bytes_uploaded.load(Ordering::Relaxed),
            weight_bytes_uploaded: self.weight_bytes_uploaded.load(Ordering::Relaxed),
            upload_bytes_saved: self.upload_bytes_saved.load(Ordering::Relaxed),
            exec_seconds: self.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            exec_block_fused: self.exec_block_fused.load(Ordering::Relaxed),
            exec_qkv_project: self.exec_qkv_project.load(Ordering::Relaxed),
            exec_attn_ffn: self.exec_attn_ffn.load(Ordering::Relaxed),
            exec_decode_block: self.exec_decode_block.load(Ordering::Relaxed),
            exec_decode_tail: self.exec_decode_tail.load(Ordering::Relaxed),
            exec_decode_tail_batched: self.exec_decode_tail_batched.load(Ordering::Relaxed),
            batched_decode_rows: self.batched_decode_rows.load(Ordering::Relaxed),
            exec_logits: self.exec_logits.load(Ordering::Relaxed),
        }
    }
}

struct Inner {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    wbufs: Mutex<HashMap<String, Arc<xla::PjRtBuffer>>>,
}

// SAFETY: PJRT's C API guarantees thread-safe client/executable/buffer use
// (XLA PjRtClient is documented thread-safe); the raw pointers inside the
// xla crate wrappers are only non-Send because the crate does not assert
// this.  All Rust-side shared state is behind Mutexes.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// Compiled-model execution engine (cheaply cloneable via `Arc`).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<Inner>,
    pub manifest: Arc<Manifest>,
    weights: Arc<Weights>,
    pub stats: Arc<EngineStats>,
}

impl Engine {
    /// Build an engine from an artifacts directory (manifest + weights).
    pub fn load(artifacts_dir: &Path, weights_file: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(&artifacts_dir.join(weights_file))?;
        weights.validate(manifest.model.n_layers)?;
        Self::new(manifest, weights)
    }

    pub fn new(manifest: Manifest, weights: Weights) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            inner: Arc::new(Inner {
                client,
                exes: Mutex::new(HashMap::new()),
                wbufs: Mutex::new(HashMap::new()),
            }),
            manifest: Arc::new(manifest),
            weights: Arc::new(weights),
            stats: Arc::new(EngineStats::default()),
        })
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Compile (or fetch the cached) executable for an artifact.
    ///
    /// Double-checked: the (slow) XLA compile runs *outside* the cache
    /// lock so concurrent calls for other artifacts never stall behind
    /// it; if two threads race on the same cold artifact, the loser's
    /// compile is dropped and only the retained one is counted, so
    /// `stats.compiles` stays exact under `workers > 1`.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.inner.exes.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let entry = self
            .manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parsing HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let exe = Arc::new(exe);
        let mut exes = self.inner.exes.lock().unwrap();
        let kept = exes.entry(name.to_string()).or_insert_with(|| {
            self.stats.compiles.fetch_add(1, Ordering::Relaxed);
            Arc::clone(&exe)
        });
        Ok(Arc::clone(kept))
    }

    /// Eagerly compile every artifact needed for a session with the given
    /// L/G variants (avoids first-request latency spikes).
    pub fn warmup(&self, ls: &[usize], gs: &[usize]) -> Result<()> {
        for e in &self.manifest.entries {
            let want = match e.kind {
                ArtifactKind::BlockFused | ArtifactKind::QkvProject | ArtifactKind::Embed => {
                    e.l.map(|l| ls.contains(&l)).unwrap_or(false)
                }
                ArtifactKind::AttnFfn => {
                    e.l.map(|l| ls.contains(&l)).unwrap_or(false)
                        && e.g.map(|g| gs.contains(&g)).unwrap_or(false)
                }
                ArtifactKind::DecodeBlock
                | ArtifactKind::DecodeTail
                | ArtifactKind::Logits => true,
                // Batched variants compile lazily on first cohort dispatch:
                // only the fabric uses them, and only at the widths its
                // cohorts actually reach.
                ArtifactKind::DecodeTailBatched => false,
            };
            if want {
                self.executable(&e.name)?;
            }
        }
        Ok(())
    }

    /// Device buffer for a named weight (uploaded once, then cached).
    /// Same double-checked shape as [`Engine::executable`]: the upload
    /// runs outside the lock; a raced duplicate is dropped and only the
    /// retained buffer is counted, keeping `weight_bytes_uploaded` the
    /// true one-time weight footprint (all weights are f32).
    fn weight_buf(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.inner.wbufs.lock().unwrap().get(name) {
            return Ok(Arc::clone(b));
        }
        let lit = self.weights.get(name)?;
        let buf = Arc::new(
            self.inner
                .client
                .buffer_from_host_literal(None, lit)
                .with_context(|| format!("uploading weight {name}"))?,
        );
        let mut wbufs = self.inner.wbufs.lock().unwrap();
        let kept = wbufs.entry(name.to_string()).or_insert_with(|| {
            self.stats
                .weight_bytes_uploaded
                .fetch_add(4 * lit.element_count() as u64, Ordering::Relaxed);
            Arc::clone(&buf)
        });
        Ok(Arc::clone(kept))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Arc<xla::PjRtBuffer>> {
        self.stats
            .bytes_uploaded
            .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        Ok(Arc::new(self.inner.client.buffer_from_host_buffer(data, dims, None)?))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Arc<xla::PjRtBuffer>> {
        self.stats
            .bytes_uploaded
            .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        Ok(Arc::new(self.inner.client.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Upload a host tensor and return a shareable device handle.  The
    /// upload is counted in `stats.bytes_uploaded`; every subsequent call
    /// that passes the handle instead of host data counts the avoided
    /// re-upload in `stats.upload_bytes_saved`.
    pub fn upload(&self, t: &HostTensor) -> Result<DeviceTensor> {
        let buf = self.upload_f32(t.data(), t.shape())?;
        Ok(DeviceTensor::from_parts(buf, t.shape().to_vec()))
    }

    /// Run `name` with activation buffers + per-layer weight buffers; the
    /// lowered entry returns a tuple, decomposed into `HostTensor`s.
    fn run(
        &self,
        name: &str,
        activations: Vec<Arc<xla::PjRtBuffer>>,
        weight_names: &[String],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.executable(name)?;
        let mut args = activations;
        for w in weight_names {
            args.push(self.weight_buf(w)?);
        }
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let t0 = std::time::Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let lit = out[0][0].to_literal_sync()?;
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        let parts = lit.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    fn block_weight_names(&self, m: usize) -> Vec<String> {
        crate::model::weights_block_names(m)
    }

    // ------------------------------------------------------------------
    // Typed entry points
    // ------------------------------------------------------------------

    /// Host-side embedding lookup (tokenizer + embedding run locally).
    pub fn embed(&self, ids: &[i32]) -> Result<HostTensor> {
        let d = self.manifest.model.d_model;
        let data = self.weights.embed_rows(ids, d)?;
        Ok(HostTensor::new(&[ids.len(), d], data)?)
    }

    /// One local-attention Transformer block.  Shapes: x [L,d], pos [L],
    /// mask [L,L].  Returns (x_out [L,d], k [L,Hkv,hd], v [L,Hkv,hd]).
    pub fn block_fused(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
        mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let l = x.shape()[0];
        let name = format!("block_fused_L{l}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(pos, &[l])?,
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        self.stats.exec_block_fused.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 3, "block_fused returns 3 tensors");
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, k, v))
    }

    /// QKV projection + RoPE (sync-block phase 1, Eq. 17).
    pub fn qkv_project(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let l = x.shape()[0];
        let name = format!("qkv_project_L{l}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(pos, &[l])?,
        ];
        let wnames: Vec<String> = crate::model::weights_proj_names(layer);
        let mut out = self.run(&name, acts, &wnames)?;
        self.stats.exec_qkv_project.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 3, "qkv_project returns 3 tensors");
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let q = out.pop().unwrap();
        Ok((q, k, v))
    }

    /// Local Q over (global) KV + FFN (sync-block phase 2, Eq. 20–21).
    ///
    /// Uploads K/V for this one call; when several attendees share the
    /// same global KV, upload once and use [`Engine::attn_ffn_dev`].
    pub fn attn_ffn(
        &self,
        layer: usize,
        x: &HostTensor,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        mask: &HostTensor,
    ) -> Result<HostTensor> {
        let kd = self.upload(k)?;
        let vd = self.upload(v)?;
        self.attn_ffn_exec(layer, x, q, &kd, &vd, mask)
    }

    /// [`Engine::attn_ffn`] over an already-device-resident global KV.
    /// The shared buffers must be treated as read-only across attendees
    /// (PJRT buffers are immutable, so this holds by construction); the
    /// avoided K/V re-upload is counted in `stats.upload_bytes_saved`.
    pub fn attn_ffn_dev(
        &self,
        layer: usize,
        x: &HostTensor,
        q: &HostTensor,
        k: &DeviceTensor,
        v: &DeviceTensor,
        mask: &HostTensor,
    ) -> Result<HostTensor> {
        self.stats
            .upload_bytes_saved
            .fetch_add(k.byte_len() + v.byte_len(), Ordering::Relaxed);
        self.attn_ffn_exec(layer, x, q, k, v, mask)
    }

    fn attn_ffn_exec(
        &self,
        layer: usize,
        x: &HostTensor,
        q: &HostTensor,
        k: &DeviceTensor,
        v: &DeviceTensor,
        mask: &HostTensor,
    ) -> Result<HostTensor> {
        let l = x.shape()[0];
        let g = k.shape()[0];
        let name = format!("attn_ffn_L{l}_G{g}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_f32(q.data(), q.shape())?,
            k.buffer(),
            v.buffer(),
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let wnames: Vec<String> = crate::model::weights_attn_names(layer);
        let mut out = self.run(&name, acts, &wnames)?;
        self.stats.exec_attn_ffn.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 1, "attn_ffn returns 1 tensor");
        Ok(out.pop().unwrap())
    }

    /// One decode block over a padded KV cache (paper §IV-C).  Uploads the
    /// full `[C]` cache per call; prefer [`Engine::decode_block_tail`]
    /// when the artifact set provides decode-tail variants.
    pub fn decode_block(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: i32,
        k_cache: &HostTensor,
        v_cache: &HostTensor,
        mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.manifest.decode_cache;
        let name = format!("decode_block_C{c}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(&[pos], &[1])?,
            self.upload_f32(k_cache.data(), k_cache.shape())?,
            self.upload_f32(v_cache.data(), v_cache.shape())?,
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        self.stats.exec_decode_block.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 3, "decode_block returns 3 tensors");
        let vn = out.pop().unwrap();
        let kn = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, kn, vn))
    }

    /// Decode over a *frozen* device-resident cache plus a small growing
    /// tail: attends over `concat(cache, tail)` with visibility
    /// `concat(cache_mask, tail_mask)`.  The `[C]` cache and its `[1,C]`
    /// mask are device handles uploaded once after prefill; each step only
    /// uploads the `[R]` tail — O(1) bytes per step in `C`.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_block_tail(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: i32,
        k_cache: &DeviceTensor,
        v_cache: &DeviceTensor,
        cache_mask: &DeviceTensor,
        k_tail: &HostTensor,
        v_tail: &HostTensor,
        tail_mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.manifest.decode_cache;
        let r = k_tail.shape()[0];
        anyhow::ensure!(k_cache.shape()[0] == c, "decode cache capacity mismatch");
        let name = format!("decode_tail_C{c}_R{r}");
        self.stats.upload_bytes_saved.fetch_add(
            k_cache.byte_len() + v_cache.byte_len() + cache_mask.byte_len(),
            Ordering::Relaxed,
        );
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(&[pos], &[1])?,
            k_cache.buffer(),
            v_cache.buffer(),
            cache_mask.buffer(),
            self.upload_f32(k_tail.data(), k_tail.shape())?,
            self.upload_f32(v_tail.data(), v_tail.shape())?,
            self.upload_f32(tail_mask.data(), tail_mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        self.stats.exec_decode_tail.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 3, "decode_tail returns 3 tensors");
        let vn = out.pop().unwrap();
        let kn = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, kn, vn))
    }

    /// Cross-session batched decode: advance `B` independent sessions one
    /// token each in a single dispatch.  Every activation/cache operand
    /// carries a leading `[B]` batch dim (x `[B,1,d]`, pos `[B,1]`, caches
    /// `[B,C,…]`/`[B,1,C]`, tails `[B,R,…]`/`[B,1,R]`); slot `i` computes
    /// exactly [`Engine::decode_block_tail`] on its own operands, so the
    /// fabric's batched path stays byte-identical to per-session dispatch.
    /// Dead slots (finished sessions) ride along fully masked; callers
    /// discard their outputs.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_block_tail_batched(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
        k_cache: &DeviceTensor,
        v_cache: &DeviceTensor,
        cache_mask: &DeviceTensor,
        k_tail: &HostTensor,
        v_tail: &HostTensor,
        tail_mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.manifest.decode_cache;
        let b = x.shape()[0];
        let r = k_tail.shape()[1];
        anyhow::ensure!(pos.len() == b, "batched decode: pos len != batch");
        anyhow::ensure!(
            k_cache.shape()[..2] == [b, c],
            "batched decode cache shape mismatch (got {:?}, want [{b}, {c}, ..])",
            k_cache.shape()
        );
        let name = format!("decode_tail_B{b}_C{c}_R{r}");
        self.stats.upload_bytes_saved.fetch_add(
            k_cache.byte_len() + v_cache.byte_len() + cache_mask.byte_len(),
            Ordering::Relaxed,
        );
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(pos, &[b, 1])?,
            k_cache.buffer(),
            v_cache.buffer(),
            cache_mask.buffer(),
            self.upload_f32(k_tail.data(), k_tail.shape())?,
            self.upload_f32(v_tail.data(), v_tail.shape())?,
            self.upload_f32(tail_mask.data(), tail_mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        self.stats.exec_decode_tail_batched.fetch_add(1, Ordering::Relaxed);
        self.stats.batched_decode_rows.fetch_add(b as u64, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 3, "decode_tail_batched returns 3 tensors");
        let vn = out.pop().unwrap();
        let kn = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, kn, vn))
    }

    /// Final norm + LM head for a [1, d] hidden state.
    pub fn logits(&self, x: &HostTensor) -> Result<Vec<f32>> {
        let acts = vec![self.upload_f32(x.data(), x.shape())?];
        let wnames = vec!["ln_f".to_string(), "w_out".to_string()];
        let mut out = self.run("logits", acts, &wnames)?;
        self.stats.exec_logits.fetch_add(1, Ordering::Relaxed);
        anyhow::ensure!(out.len() == 1, "logits returns 1 tensor");
        // `into_data` hands back the tensor's own backing Vec — no second
        // full-vocab copy per decode token.
        Ok(out.pop().unwrap().into_data())
    }
}
