//! The execution engine.
//!
//! * HLO **text** artifacts (not serialized protos — xla_extension 0.5.1
//!   rejects jax≥0.5 64-bit instruction ids) are parsed with
//!   `HloModuleProto::from_text_file` and compiled lazily per variant.
//! * Weights are uploaded to the device **once** and every call passes
//!   device buffers (`execute_b`), so the hot path only uploads activations.
//! * Thread safety: the PJRT CPU client is thread-safe (XLA guarantees
//!   thread-safe `Compile`/`Execute`); Rust-side maps are guarded by locks.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::model::{ArtifactKind, Manifest, Weights};
use crate::tensor::HostTensor;

/// Cumulative engine counters (perf accounting).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub compiles: AtomicU64,
    pub bytes_uploaded: AtomicU64,
    pub exec_nanos: AtomicU64,
}

impl EngineStats {
    pub fn snapshot(&self) -> (u64, u64, u64, f64) {
        (
            self.executions.load(Ordering::Relaxed),
            self.compiles.load(Ordering::Relaxed),
            self.bytes_uploaded.load(Ordering::Relaxed),
            self.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

struct Inner {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    wbufs: Mutex<HashMap<String, Arc<xla::PjRtBuffer>>>,
}

// SAFETY: PJRT's C API guarantees thread-safe client/executable/buffer use
// (XLA PjRtClient is documented thread-safe); the raw pointers inside the
// xla crate wrappers are only non-Send because the crate does not assert
// this.  All Rust-side shared state is behind Mutexes.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// Compiled-model execution engine (cheaply cloneable via `Arc`).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<Inner>,
    pub manifest: Arc<Manifest>,
    weights: Arc<Weights>,
    pub stats: Arc<EngineStats>,
}

impl Engine {
    /// Build an engine from an artifacts directory (manifest + weights).
    pub fn load(artifacts_dir: &Path, weights_file: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(&artifacts_dir.join(weights_file))?;
        weights.validate(manifest.model.n_layers)?;
        Self::new(manifest, weights)
    }

    pub fn new(manifest: Manifest, weights: Weights) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            inner: Arc::new(Inner {
                client,
                exes: Mutex::new(HashMap::new()),
                wbufs: Mutex::new(HashMap::new()),
            }),
            manifest: Arc::new(manifest),
            weights: Arc::new(weights),
            stats: Arc::new(EngineStats::default()),
        })
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Compile (or fetch the cached) executable for an artifact.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.inner.exes.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let entry = self
            .manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parsing HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        let exe = Arc::new(exe);
        self.inner
            .exes
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile every artifact needed for a session with the given
    /// L/G variants (avoids first-request latency spikes).
    pub fn warmup(&self, ls: &[usize], gs: &[usize]) -> Result<()> {
        for e in &self.manifest.entries {
            let want = match e.kind {
                ArtifactKind::BlockFused | ArtifactKind::QkvProject | ArtifactKind::Embed => {
                    e.l.map(|l| ls.contains(&l)).unwrap_or(false)
                }
                ArtifactKind::AttnFfn => {
                    e.l.map(|l| ls.contains(&l)).unwrap_or(false)
                        && e.g.map(|g| gs.contains(&g)).unwrap_or(false)
                }
                ArtifactKind::DecodeBlock | ArtifactKind::Logits => true,
            };
            if want {
                self.executable(&e.name)?;
            }
        }
        Ok(())
    }

    /// Device buffer for a named weight (uploaded once, then cached).
    fn weight_buf(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.inner.wbufs.lock().unwrap().get(name) {
            return Ok(Arc::clone(b));
        }
        let lit = self.weights.get(name)?;
        let buf = self
            .inner
            .client
            .buffer_from_host_literal(None, lit)
            .with_context(|| format!("uploading weight {name}"))?;
        let buf = Arc::new(buf);
        self.inner
            .wbufs
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&buf));
        Ok(buf)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats
            .bytes_uploaded
            .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        Ok(self.inner.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats
            .bytes_uploaded
            .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        Ok(self.inner.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Run `name` with activation buffers + per-layer weight buffers; the
    /// lowered entry returns a tuple, decomposed into `HostTensor`s.
    fn run(
        &self,
        name: &str,
        activations: Vec<xla::PjRtBuffer>,
        weight_names: &[String],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.executable(name)?;
        let mut args: Vec<Arc<xla::PjRtBuffer>> =
            activations.into_iter().map(Arc::new).collect();
        for w in weight_names {
            args.push(self.weight_buf(w)?);
        }
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let t0 = std::time::Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let lit = out[0][0].to_literal_sync()?;
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        let parts = lit.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    fn block_weight_names(&self, m: usize) -> Vec<String> {
        crate::model::weights_block_names(m)
    }

    // ------------------------------------------------------------------
    // Typed entry points
    // ------------------------------------------------------------------

    /// Host-side embedding lookup (tokenizer + embedding run locally).
    pub fn embed(&self, ids: &[i32]) -> Result<HostTensor> {
        let d = self.manifest.model.d_model;
        let data = self.weights.embed_rows(ids, d)?;
        Ok(HostTensor::new(&[ids.len(), d], data)?)
    }

    /// One local-attention Transformer block.  Shapes: x [L,d], pos [L],
    /// mask [L,L].  Returns (x_out [L,d], k [L,Hkv,hd], v [L,Hkv,hd]).
    pub fn block_fused(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
        mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let l = x.shape()[0];
        let name = format!("block_fused_L{l}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(pos, &[l])?,
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        anyhow::ensure!(out.len() == 3, "block_fused returns 3 tensors");
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, k, v))
    }

    /// QKV projection + RoPE (sync-block phase 1, Eq. 17).
    pub fn qkv_project(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: &[i32],
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let l = x.shape()[0];
        let name = format!("qkv_project_L{l}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(pos, &[l])?,
        ];
        let wnames: Vec<String> = crate::model::weights_proj_names(layer);
        let mut out = self.run(&name, acts, &wnames)?;
        anyhow::ensure!(out.len() == 3, "qkv_project returns 3 tensors");
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let q = out.pop().unwrap();
        Ok((q, k, v))
    }

    /// Local Q over (global) KV + FFN (sync-block phase 2, Eq. 20–21).
    pub fn attn_ffn(
        &self,
        layer: usize,
        x: &HostTensor,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        mask: &HostTensor,
    ) -> Result<HostTensor> {
        let l = x.shape()[0];
        let g = k.shape()[0];
        let name = format!("attn_ffn_L{l}_G{g}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_f32(q.data(), q.shape())?,
            self.upload_f32(k.data(), k.shape())?,
            self.upload_f32(v.data(), v.shape())?,
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let wnames: Vec<String> = crate::model::weights_attn_names(layer);
        let mut out = self.run(&name, acts, &wnames)?;
        anyhow::ensure!(out.len() == 1, "attn_ffn returns 1 tensor");
        Ok(out.pop().unwrap())
    }

    /// One decode block over a padded KV cache (paper §IV-C).
    pub fn decode_block(
        &self,
        layer: usize,
        x: &HostTensor,
        pos: i32,
        k_cache: &HostTensor,
        v_cache: &HostTensor,
        mask: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let c = self.manifest.decode_cache;
        let name = format!("decode_block_C{c}");
        let acts = vec![
            self.upload_f32(x.data(), x.shape())?,
            self.upload_i32(&[pos], &[1])?,
            self.upload_f32(k_cache.data(), k_cache.shape())?,
            self.upload_f32(v_cache.data(), v_cache.shape())?,
            self.upload_f32(mask.data(), mask.shape())?,
        ];
        let mut out = self.run(&name, acts, &self.block_weight_names(layer))?;
        anyhow::ensure!(out.len() == 3, "decode_block returns 3 tensors");
        let vn = out.pop().unwrap();
        let kn = out.pop().unwrap();
        let xo = out.pop().unwrap();
        Ok((xo, kn, vn))
    }

    /// Final norm + LM head for a [1, d] hidden state.
    pub fn logits(&self, x: &HostTensor) -> Result<Vec<f32>> {
        let acts = vec![self.upload_f32(x.data(), x.shape())?];
        let wnames = vec!["ln_f".to_string(), "w_out".to_string()];
        let out = self.run("logits", acts, &wnames)?;
        Ok(out[0].data().to_vec())
    }
}
