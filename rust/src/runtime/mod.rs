//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once on the
//! CPU PJRT client, uploads weights once as device buffers, and exposes
//! typed execute wrappers for every entry point.
//!
//! Python never appears here — this is the request path.

mod engine;

pub use engine::{Engine, EngineStats, EngineStatsView};
