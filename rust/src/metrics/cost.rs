//! Analytic computational-cost models.
//!
//! The paper reports per-participant FLOPs and peak memory for Prefilling
//! and Decoding (§VII-A3b, Fig. 6).  These are *model* quantities — a
//! function of sequence/visibility sizes and the architecture — exactly as
//! the paper computes them; wall-clock on this CPU testbed is reported
//! separately by the benches.

use crate::model::ModelDims;

/// Cost of one phase for one participant.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseCost {
    pub flops: f64,
    pub peak_mem_bytes: f64,
}

impl PhaseCost {
    pub fn add(&mut self, other: PhaseCost) {
        self.flops += other.flops;
        self.peak_mem_bytes = self.peak_mem_bytes.max(other.peak_mem_bytes);
    }
}

/// Analytic cost model for the TinyQwen block structure.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub dims: ModelDims,
}

impl CostModel {
    pub fn new(dims: ModelDims) -> Self {
        Self { dims }
    }

    /// FLOPs of one Transformer block where `l` query rows attend to `g`
    /// KV rows: QKV projection (O(l·d²)), attention (O(l·g·d)), output
    /// projection and SwiGLU FFN.
    pub fn block_flops(&self, l: usize, g: usize) -> f64 {
        let d = self.dims.d_model as f64;
        let qd = self.dims.q_dim() as f64;
        let kd = self.dims.kv_dim() as f64;
        let dff = self.dims.d_ff as f64;
        let (l, g) = (l as f64, g as f64);
        let proj = 2.0 * l * d * (qd + 2.0 * kd);
        let scores = 2.0 * l * g * qd; // Q·Kᵀ over all query heads
        let av = 2.0 * l * g * qd; // P·V
        let out = 2.0 * l * qd * d;
        let ffn = 2.0 * l * d * dff * 3.0; // gate + up + down
        proj + scores + av + out + ffn
    }

    /// Peak live bytes while executing one block (activations + scores +
    /// KV + weights), f32.
    pub fn block_peak_mem(&self, l: usize, g: usize) -> f64 {
        let d = self.dims.d_model as f64;
        let qd = self.dims.q_dim() as f64;
        let kd = self.dims.kv_dim() as f64;
        let dff = self.dims.d_ff as f64;
        let (lf, gf) = (l as f64, g as f64);
        let acts = lf * d * 3.0; // x, residual, normed
        let qkv = lf * qd + 2.0 * gf * kd;
        // Flash-style tiles keep only an l×tile score panel live; the
        // additive mask is l×g.
        let tile = 64.0f64.min(gf);
        let scores = lf * tile + lf * gf;
        let ffn = lf * dff * 2.0;
        let weights = d * (qd + 2.0 * kd) + qd * d + 3.0 * d * dff + 2.0 * d;
        4.0 * (acts + qkv + scores + ffn + weights)
    }

    /// Prefill cost for one participant with `l` local tokens: `local`
    /// blocks at visibility `l` plus `global` blocks at visibility `g`.
    pub fn prefill_cost(&self, l: usize, g: usize, local_blocks: usize, global_blocks: usize) -> PhaseCost {
        let mut c = PhaseCost::default();
        for _ in 0..local_blocks {
            c.flops += self.block_flops(l, l);
            c.peak_mem_bytes = c.peak_mem_bytes.max(self.block_peak_mem(l, l));
        }
        for _ in 0..global_blocks {
            c.flops += self.block_flops(l, g);
            c.peak_mem_bytes = c.peak_mem_bytes.max(self.block_peak_mem(l, g));
        }
        c
    }

    /// Decode cost for `t` generated tokens against an average cache of
    /// `cache` rows across all layers (KV caching ⇒ O(cache) per step).
    pub fn decode_cost(&self, t: usize, cache: usize) -> PhaseCost {
        let mut c = PhaseCost::default();
        for _ in 0..t {
            for _ in 0..self.dims.n_layers {
                c.flops += self.block_flops(1, cache);
            }
        }
        // Peak memory: the persistent KV caches dominate.
        let kv_cache_bytes =
            (self.dims.n_layers * cache * self.dims.kv_dim() * 2 * 4) as f64;
        c.peak_mem_bytes = self.block_peak_mem(1, cache) + kv_cache_bytes;
        c
    }

    /// Weight bytes (f32) — the floor under any peak-memory number.
    pub fn weight_bytes(&self) -> f64 {
        let d = self.dims.d_model as f64;
        let v = self.dims.vocab_size as f64;
        let qd = self.dims.q_dim() as f64;
        let kd = self.dims.kv_dim() as f64;
        let dff = self.dims.d_ff as f64;
        let per_block = d * (qd + 2.0 * kd) + qd + 2.0 * kd + qd * d + 3.0 * d * dff + 2.0 * d;
        4.0 * (v * d + self.dims.n_layers as f64 * per_block + d + d * v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "t".into(),
            vocab_size: 128,
            d_model: 96,
            n_layers: 8,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 24,
            d_ff: 256,
            rope_theta: 1e4,
            rms_eps: 1e-6,
        }
    }

    #[test]
    fn flops_scale_quadratically_with_visibility() {
        let cm = CostModel::new(dims());
        let f1 = cm.block_flops(64, 64);
        let f2 = cm.block_flops(128, 128);
        // attention term is quadratic, projections linear: 2x seq ⇒ between
        // 2x and 4x FLOPs.
        assert!(f2 > 2.0 * f1 && f2 < 4.0 * f1, "{f1} {f2}");
    }

    #[test]
    fn fedattn_prefill_cheaper_than_centralized() {
        // N participants with L/N tokens each, H=M local blocks, vs one
        // participant with all L tokens — the paper's computational saving.
        let cm = CostModel::new(dims());
        let central = cm.prefill_cost(256, 256, 8, 0).flops;
        let fed_per_participant = cm.prefill_cost(64, 256, 7, 1).flops;
        assert!(
            fed_per_participant < central / 2.0,
            "fed {fed_per_participant} vs central {central}"
        );
    }

    #[test]
    fn decode_linear_in_tokens() {
        let cm = CostModel::new(dims());
        let c1 = cm.decode_cost(10, 300).flops;
        let c2 = cm.decode_cost(20, 300).flops;
        assert!((c2 / c1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn weight_bytes_close_to_param_count() {
        let cm = CostModel::new(dims());
        // 838752 params for the base preset (from python config).
        let params = cm.weight_bytes() / 4.0;
        assert!((params - 838_752.0).abs() < 1_000.0, "params {params}");
    }
}
