//! Exact-match scoring (the paper's Pass@1 EM on GSM8K; here on MicroFact).

/// Extract the model's answer from generated text: everything up to the
/// first sentence/terminator, trimmed.
pub fn extract_answer(generated: &str) -> String {
    let s = generated.trim_start();
    let end = s
        .find(|c: char| c == '.' || c == '\n' || c == 'Q')
        .unwrap_or(s.len());
    s[..end].trim().to_string()
}

/// Pass@1 exact match.
pub fn em_score(generated: &str, gold: &str) -> bool {
    extract_answer(generated) == gold.trim()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_plain_number() {
        assert_eq!(extract_answer(" 12"), "12");
        assert_eq!(extract_answer(" 12. Lia has"), "12");
        assert_eq!(extract_answer(" Lia Q: who"), "Lia");
    }

    #[test]
    fn exact_match() {
        assert!(em_score(" 7", "7"));
        assert!(em_score(" Lia. Omar has 3", "Lia"));
        assert!(!em_score(" 8", "7"));
        assert!(!em_score("", "7"));
    }
}
