//! Metrics: analytic FLOPs / peak-memory models (paper §III-C), exact-match
//! scoring, and aggregate reporting for the paper-figure benches.

mod cost;
mod em;

pub use cost::{CostModel, PhaseCost};
pub use em::{em_score, extract_answer};
