//! Fixed-size thread pool with task submission and a scoped parallel map.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Error from a scoped parallel region: some closure panicked.
#[derive(Debug, thiserror::Error)]
#[error("{panicked} of {total} parallel tasks panicked")]
pub struct ScopeError {
    pub panicked: usize,
    pub total: usize,
}

/// A fixed pool of worker threads consuming from one shared queue.
///
/// `Sync` regardless of toolchain (the submission side is behind a
/// `Mutex`), so one pool can be shared across serving workers via
/// `Arc<Pool>`; concurrent `scope_map` calls interleave safely — each
/// call collects its results on its own channel.
pub struct Pool {
    tx: Option<Mutex<Sender<Task>>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl Pool {
    /// Create a pool with `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("fedattn-worker-{i}"))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match task {
                            Ok(t) => {
                                let _ = catch_unwind(AssertUnwindSafe(t));
                                inf.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(Mutex::new(tx)), workers, in_flight }
    }

    /// Pool sized to the machine (min 1; this image exposes 1 core).
    pub fn default_size() -> usize {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }

    /// Fire-and-forget task.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .unwrap()
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of tasks submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Run `f(i)` for `i in 0..n` across the pool and collect results in
    /// order.  Blocks until all complete.  Panics inside closures are
    /// reported as a [`ScopeError`].
    pub fn scope_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>, ScopeError>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, Option<T>)>, Receiver<(usize, Option<T>)>) =
            channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.spawn(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(i))).ok();
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        let mut panicked = 0usize;
        while got < n {
            let Ok((i, v)) = rrx.recv() else {
                // Every result sender is gone with results still owed: a
                // task vanished without reporting.  That happens when the
                // panic payload itself panics on drop — the inner
                // `catch_unwind` returns the payload, `.ok()` drops it,
                // and the drop-panic unwinds past the reporting `send`
                // (caught by the worker loop, which survives).  Fold the
                // missing tasks into the ScopeError instead of panicking
                // the caller's thread.
                panicked += n - got;
                break;
            };
            if let Some(v) = v {
                slots[i] = Some(v);
            } else {
                panicked += 1;
            }
            got += 1;
        }
        if panicked > 0 {
            return Err(ScopeError { panicked, total: n });
        }
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn scope_map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.scope_map(100, |i| i * i).unwrap();
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_all_tasks() {
        let pool = Pool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn scope_map_reports_panics() {
        let pool = Pool::new(2);
        let err = pool
            .scope_map(10, |i| {
                if i == 7 {
                    panic!("boom");
                }
                i
            })
            .unwrap_err();
        assert_eq!(err.panicked, 1);
        assert_eq!(err.total, 10);
    }

    #[test]
    fn scope_map_survives_drop_panicking_payload() {
        // A panic payload whose Drop itself panics never reaches the
        // result channel: the inner catch_unwind hands the payload to
        // `.ok()`, dropping it re-panics, and the reporting send is
        // skipped.  This used to abort the caller via
        // `rrx.recv().expect(..)`; it must surface as ScopeError.
        struct DropBomb;
        impl Drop for DropBomb {
            fn drop(&mut self) {
                if !std::thread::panicking() {
                    panic!("payload drop panic");
                }
            }
        }
        let pool = Pool::new(2);
        let err = pool
            .scope_map(6, |i| {
                if i == 3 {
                    std::panic::panic_any(DropBomb);
                }
                i
            })
            .unwrap_err();
        assert_eq!(err.panicked, 1);
        assert_eq!(err.total, 6);
        // The pool stays usable for the next region.
        assert_eq!(pool.scope_map(3, |i| i + 1).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn empty_scope() {
        let pool = Pool::new(1);
        assert!(pool.scope_map(0, |i| i).unwrap().is_empty());
    }

    #[test]
    fn pool_is_send_and_sync() {
        // The coordinator shares one pool across serving workers via
        // Arc<Pool>; pin the auto-traits that relies on.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Pool>();
    }

    #[test]
    fn concurrent_scope_maps_do_not_cross_results() {
        let pool = Arc::new(Pool::new(3));
        let handles: Vec<_> = (0..4)
            .map(|base: usize| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || pool.scope_map(20, move |i| base * 100 + i))
            })
            .collect();
        for (base, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap().unwrap();
            assert_eq!(out, (0..20).map(|i| base * 100 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_survives_task_panic() {
        let pool = Pool::new(1);
        pool.spawn(|| panic!("ignored"));
        let out = pool.scope_map(3, |i| i + 1).unwrap();
        assert_eq!(out, vec![1, 2, 3]);
    }
}
