//! Execution substrate: a work-stealing-free, bounded thread pool with
//! scoped parallel-for — the offline-image substitute for tokio.
//!
//! FedAttn participants are CPU-bound (each drives PJRT executions), so a
//! plain pool with bounded channels gives the same concurrency structure an
//! async runtime would, with simpler reasoning about backpressure.

pub mod pool;

pub use pool::{Pool, ScopeError};
