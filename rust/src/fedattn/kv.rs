//! Global KV aggregation (Eq. 20): packing per-participant K/V rows into
//! one global buffer.
//!
//! The paper's Π_n indicator matrices scatter local rows to their global
//! positions.  Because attention is permutation-invariant in the KV axis
//! once positions ride along (RoPE is applied at projection time and the
//! mask is position-based), we *pack* valid rows contiguously and carry
//! `(pos, owner, transmitted)` metadata per row instead of materialising an
//! L-sized scatter — the packed form is what a real edge implementation
//! ships over the wire.

use anyhow::Result;

use crate::tensor::HostTensor;

/// Metadata of one packed KV row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvRowMeta {
    /// Global token position (drives the causal mask).
    pub pos: i32,
    /// Owning participant.
    pub owner: usize,
    /// The row's index within its owner's valid rows — a stable,
    /// round-scoped row id.  Packing is owner-major in local order, so
    /// `row` is exactly the index into the owner's padded K/V tensors;
    /// delta downlink frames use it as the retain-list id an attendee
    /// resolves against its own fresh KV (see
    /// [`protocol::GlobalKvDeltaFrame`]).
    ///
    /// [`protocol::GlobalKvDeltaFrame`]: crate::fedattn::protocol::GlobalKvDeltaFrame
    pub row: usize,
    /// Whether the row was transmitted this round (sparse KV exchange);
    /// untransmitted rows are visible only to their owner.
    pub transmitted: bool,
    /// Accumulated attention mass on this row at selection time (adaptive
    /// aggregation, §V Obs. 4); 0 when relevance is not tracked.
    pub relevance: f32,
}

/// A packed global KV buffer padded to a G variant.
#[derive(Debug, Clone)]
pub struct GlobalKv {
    /// `[g_pad, Hkv, hd]`.
    pub k: HostTensor,
    pub v: HostTensor,
    /// Valid packed rows (`meta.len() <= g_pad`).
    pub meta: Vec<KvRowMeta>,
}

impl GlobalKv {
    pub fn rows(&self) -> usize {
        self.meta.len()
    }

    pub fn g_pad(&self) -> usize {
        self.k.shape()[0]
    }

    /// Bytes a participant contributes when transmitting `rows` KV rows.
    pub fn row_bytes(kv_heads: usize, head_dim: usize) -> usize {
        2 * kv_heads * head_dim * 4
    }

    /// Pack per-participant KV into a global buffer.
    ///
    /// * `parts[n] = (k, v, pos, valid, transmitted)` where `k`/`v` are the
    ///   participant's padded `[l_pad, Hkv, hd]` tensors, `pos` its global
    ///   positions, `valid` its real row count and `transmitted[i]` the
    ///   sparse-exchange flag for local row `i`.
    /// * `g_pad` — the padded global size (a manifest G variant).
    ///
    /// Rows are packed participant-major, position-ascending — the same
    /// order the Python reference uses when concatenating Π_n blocks.
    pub fn pack(
        parts: &[(&HostTensor, &HostTensor, &[i32], usize, &[bool])],
        g_pad: usize,
    ) -> Result<Self> {
        let (hkv, hd) = {
            let s = parts[0].0.shape();
            (s[1], s[2])
        };
        let total: usize = parts.iter().map(|p| p.3).sum();
        anyhow::ensure!(
            total <= g_pad,
            "packed KV rows {total} exceed padded size {g_pad}"
        );
        let mut k = HostTensor::zeros(&[g_pad, hkv, hd]);
        let mut v = HostTensor::zeros(&[g_pad, hkv, hd]);
        let mut meta = Vec::with_capacity(total);
        let mut cursor = 0usize;
        for (owner, (pk, pv, pos, valid, tx)) in parts.iter().enumerate() {
            anyhow::ensure!(pk.shape() == pv.shape(), "k/v shape mismatch");
            anyhow::ensure!(*valid <= pos.len() && *valid <= tx.len());
            k.copy_rows_from(pk, 0..*valid, cursor);
            v.copy_rows_from(pv, 0..*valid, cursor);
            for i in 0..*valid {
                meta.push(KvRowMeta {
                    pos: pos[i],
                    owner,
                    row: i,
                    transmitted: tx[i],
                    relevance: 0.0,
                });
            }
            cursor += valid;
        }
        Ok(Self { k, v, meta })
    }

    /// Stamp each packed row's metadata with the owner's accumulated
    /// relevance score (`scores_by_owner[owner][local_row]`, same
    /// packing order as [`GlobalKv::pack`]).  Rows beyond a participant's
    /// score vector keep relevance 0.
    pub fn attach_relevance(&mut self, scores_by_owner: &[Vec<f64>]) {
        let mut cursor = vec![0usize; scores_by_owner.len()];
        for m in &mut self.meta {
            let Some(c) = cursor.get_mut(m.owner) else { continue };
            let i = *c;
            *c += 1;
            if let Some(&s) = scores_by_owner[m.owner].get(i) {
                m.relevance = s as f32;
            }
        }
    }

    /// Per-participant transmitted-row counts (for comm accounting).
    pub fn tx_rows_by_owner(&self, n_participants: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_participants];
        for m in &self.meta {
            if m.transmitted {
                counts[m.owner] += 1;
            }
        }
        counts
    }

    /// Decomposed metadata columns for the mask builder.
    pub fn meta_columns(&self) -> (Vec<i32>, Vec<usize>, Vec<bool>) {
        let pos = self.meta.iter().map(|m| m.pos).collect();
        let owner = self.meta.iter().map(|m| m.owner).collect();
        let tx = self.meta.iter().map(|m| m.transmitted).collect();
        (pos, owner, tx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    fn part(rows: usize, hkv: usize, hd: usize, base: f32) -> (HostTensor, HostTensor) {
        let mut k = HostTensor::zeros(&[rows, hkv, hd]);
        let mut v = HostTensor::zeros(&[rows, hkv, hd]);
        for i in 0..rows {
            k.row_mut(i).fill(base + i as f32);
            v.row_mut(i).fill(-(base + i as f32));
        }
        (k, v)
    }

    #[test]
    fn pack_two_participants() {
        let (k0, v0) = part(4, 2, 3, 10.0);
        let (k1, v1) = part(4, 2, 3, 100.0);
        let pos0 = [0, 1, 2, 3];
        let pos1 = [4, 5, 6, 7];
        let tx = [true, true, false, true];
        let g = GlobalKv::pack(
            &[
                (&k0, &v0, &pos0, 3, &tx),
                (&k1, &v1, &pos1, 2, &tx),
            ],
            8,
        )
        .unwrap();
        assert_eq!(g.rows(), 5);
        assert_eq!(g.k.row(0)[0], 10.0);
        assert_eq!(g.k.row(3)[0], 100.0);
        assert_eq!(
            g.meta[3],
            KvRowMeta { pos: 4, owner: 1, row: 0, transmitted: true, relevance: 0.0 }
        );
        assert_eq!(g.meta[2].transmitted, false);
        assert_eq!(g.tx_rows_by_owner(2), vec![2, 2]);
        // padding rows zero
        assert!(g.k.row(5).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn attach_relevance_scatters_by_owner() {
        let (k0, v0) = part(3, 1, 2, 0.0);
        let (k1, v1) = part(2, 1, 2, 10.0);
        let pos0 = [0, 1, 2];
        let pos1 = [3, 4];
        let tx = [true; 3];
        let mut g = GlobalKv::pack(
            &[(&k0, &v0, &pos0, 3, &tx), (&k1, &v1, &pos1, 2, &tx[..2])],
            5,
        )
        .unwrap();
        g.attach_relevance(&[vec![0.5, 1.5, 2.5], vec![9.0, 8.0]]);
        let rel: Vec<f32> = g.meta.iter().map(|m| m.relevance).collect();
        assert_eq!(rel, vec![0.5, 1.5, 2.5, 9.0, 8.0]);
    }

    #[test]
    fn pack_rejects_overflow() {
        let (k0, v0) = part(4, 1, 2, 0.0);
        let pos = [0, 1, 2, 3];
        let tx = [true; 4];
        assert!(GlobalKv::pack(&[(&k0, &v0, &pos, 4, &tx)], 3).is_err());
    }

    #[test]
    fn every_valid_row_packed_exactly_once() {
        propcheck(60, |rng| {
            let n = 1 + rng.below(4) as usize;
            let hkv = 1 + rng.below(2) as usize;
            let hd = 2usize;
            let mut parts_data = Vec::new();
            let mut next_pos = 0i32;
            for pi in 0..n {
                let rows = 1 + rng.below(6) as usize;
                let valid = 1 + rng.below(rows as u64) as usize;
                let (k, v) = part(rows, hkv, hd, (pi * 1000) as f32);
                let pos: Vec<i32> = (0..rows as i32).map(|i| next_pos + i).collect();
                next_pos += valid as i32;
                let tx: Vec<bool> = (0..rows).map(|_| rng.bernoulli(0.7)).collect();
                parts_data.push((k, v, pos, valid, tx));
            }
            let refs: Vec<_> = parts_data
                .iter()
                .map(|(k, v, p, val, tx)| (k, v, p.as_slice(), *val, tx.as_slice()))
                .collect();
            let total: usize = refs.iter().map(|r| r.3).sum();
            let g = GlobalKv::pack(&refs, total.max(1)).map_err(|e| e.to_string())?;
            if g.rows() != total {
                return Err(format!("rows {} != total {total}", g.rows()));
            }
            // owner-major, each owner's rows in local order
            let mut idx = 0usize;
            for (owner, r) in refs.iter().enumerate() {
                for i in 0..r.3 {
                    let m = g.meta[idx];
                    if m.owner != owner || m.pos != r.2[i] || m.row != i {
                        return Err(format!("meta mismatch at {idx}: {m:?}"));
                    }
                    if g.k.row(idx)[0] != r.0.row(i)[0] {
                        return Err("k row content mismatch".into());
                    }
                    idx += 1;
                }
            }
            Ok(())
        });
    }
}
