//! The session driver: one collaborative-inference task run as a typed
//! message-passing protocol over [`ParticipantNode`]s.
//!
//! The driver owns no participant state.  Each round (Transformer block)
//! it:
//!
//! 1. asks the [`Aggregator`] which rows every node transmits,
//! 2. collects each node's [`KvContribution`] (the uplink message whose
//!    encoded payload size **is** the round's byte accounting, fed
//!    straight into [`NetSim::exchange_round`]),
//! 3. merges contributions into the global KV (Eq. 20) and lets every
//!    attendee attend over the shared device upload,
//! 4. hands the frame (or, off-round, each node's own KV) back to the
//!    nodes for their decode caches.
//!
//! Attendance is a *schedule input*: per-node dropout
//! ([`SessionConfig::dropout_prob`]) masks attendance before the first
//! round, so a dropped node simply runs the local path — no special case
//! in the round loop.  Stragglers are a *round input*: with a per-round
//! deadline ([`SessionConfig::round_deadline_ms`]) the network simulator
//! schedules each uplink's arrival and late contributions are excluded
//! from aggregation and billing (partial aggregation); without one, no
//! arrival is ever drawn and the loop is byte-identical to the
//! pre-deadline driver.  A wire deployment attaches one
//! [`RemoteParticipant`] per node
//! ([`SessionDriver::new_with_remotes`], usually via
//! [`TransportDriver`]): the protocol plane then crosses real
//! transports while the compute plane stays engine-colocated.  Wire
//! rounds are **concurrent** — contribution requests fan out to every
//! node before any reply is read (pool tasks when `workers > 1`), so the
//! round costs the slowest link rather than the sum — and the downlink
//! ships **delta frames** by default ([`SessionConfig::delta_frames`]):
//! each attendee receives only the transmitted rows it does not already
//! hold.  Collection order is pinned to participant index, so both
//! optimizations are byte-invisible to the golden fixtures.
//!
//! Device-resident execution (shared per-round KV uploads, frozen decode
//! caches + `[R]` tails) and pool-parallel per-participant loops carry
//! over from the pre-protocol session; a parallel session is
//! byte-identical to a sequential one (ordered collection, sequential
//! host-side reductions).
//!
//! [`NetSim::exchange_round`]: crate::net::NetSim::exchange_round
//! [`Aggregator`]: crate::fedattn::aggregate::Aggregator
//! [`TransportDriver`]: crate::fedattn::transport::TransportDriver

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::data::Partition;
use crate::exec::Pool;
use crate::fedattn::aggregate::{self, Aggregator, PartRows};
use crate::fedattn::kv::GlobalKv;
use crate::fedattn::masks::global_mask;
use crate::fedattn::node::{BlockCache, Participant, ParticipantNode};
use crate::fedattn::protocol::KvContribution;
use crate::fedattn::relevance::{self, RelevanceTracker};
use crate::fedattn::schedule::SyncSchedule;
use crate::fedattn::sparse::{KvExchangePolicy, LocalSparsity, TxContext};
use crate::fedattn::transport::{RemoteParticipant, Transport};
use crate::net::{NetReport, NetSim};
use crate::runtime::Engine;
use crate::tensor::HostTensor;
use crate::tokenizer;
use crate::util::prng::Xoshiro256ss;

/// Session knobs (one FedAttn task).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub schedule: SyncSchedule,
    pub local_sparsity: LocalSparsity,
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Collect every participant's final hidden states (error analysis /
    /// divergence metrics; costs memory, off for serving).
    pub record_hidden: bool,
    /// Keep KV caches and decode a response for *every* participant (the
    /// paper's Fig. 5 reports mean/min/max EM across participants).  The
    /// default caches and decodes only the task publisher.
    pub decode_all: bool,
    /// Coordinator-allocated per-participant KV row budgets (heterogeneous
    /// links); overrides the budget embedded in budgeted policies.  For
    /// [`KvExchangePolicy::ByteBudget`] with no explicit allocation the
    /// session derives one from the network simulator's link specs.
    pub kv_row_budgets: Option<Vec<usize>>,
    /// Thread-pool width for the per-participant loops (1 = sequential).
    /// Parallel sessions are byte-identical to sequential ones (ordered
    /// result collection + sequential host-side reductions).
    pub workers: usize,
    /// Freeze decode caches on the device and ship only the decode tail
    /// per token step.  Ignored (with a host-path fallback) when the
    /// artifact set predates decode-tail variants.
    pub device_decode: bool,
    /// Per-node, per-round attendance dropout probability in `[0, 1]`:
    /// each scheduled attendance is independently dropped with this
    /// probability (its own seeded RNG stream, so `0.0` is byte-identical
    /// to no dropout).  A dropped node runs the local path for that block
    /// and its peers aggregate without it — the federated-inference
    /// straggler/dropout scenario as a schedule input.
    pub dropout_prob: f64,
    /// Per-sync-round contribution deadline in **simulated** milliseconds
    /// (`federation.round_deadline_ms` / `--round-deadline`).  With a
    /// deadline, [`NetSim`] link latency + jitter *schedule* each uplink's
    /// arrival ([`NetSim::uplink_arrivals`]); contributions that land
    /// after the deadline are excluded from the round — not billed, not
    /// aggregated — and the late participant runs the local path (partial
    /// aggregation, the FL straggler analogue).  A round where every
    /// attendee misses the cut degrades to local attention exactly like a
    /// fully-dropped round.  `None` (the default) disables the deadline
    /// entirely: no arrivals are scheduled, no extra RNG is consumed, and
    /// behaviour is byte-identical to the pre-deadline driver.
    ///
    /// [`NetSim`]: crate::net::NetSim
    /// [`NetSim::uplink_arrivals`]: crate::net::NetSim::uplink_arrivals
    pub round_deadline_ms: Option<f64>,
    /// Delta-encode the downlink (`federation.delta_frames` /
    /// `--delta-frames`, default on): each attendee receives a
    /// [`GlobalKvDeltaFrame`] carrying only the transmitted rows of
    /// *other* participants — its own rows ride as a retain-list of
    /// round-scoped row ids resolved against the fresh KV it contributed,
    /// and untransmitted remote rows (masked for it anyway) are elided.
    /// Downlink billing is the delta (`total - own_tx`, the accounting
    /// the protocol has always used), and any cache miss automatically
    /// falls back to a full frame.  With the knob **off**, full
    /// [`GlobalKvFrame`]s ship and every attendee is billed every packed
    /// row — the pre-delta wire cost, kept as the measurable baseline
    /// (`BENCH_comm_delta.json`).  Decoded transcripts are byte-identical
    /// either way: elided rows are invisible to the attendee by
    /// construction.
    ///
    /// [`GlobalKvDeltaFrame`]: crate::fedattn::protocol::GlobalKvDeltaFrame
    /// [`GlobalKvFrame`]: crate::fedattn::protocol::GlobalKvFrame
    pub delta_frames: bool,
}

impl SessionConfig {
    pub fn new(schedule: SyncSchedule) -> Self {
        Self {
            schedule,
            local_sparsity: LocalSparsity::full(),
            kv_policy: KvExchangePolicy::Full,
            max_new_tokens: 12,
            seed: 0,
            record_hidden: false,
            decode_all: false,
            kv_row_budgets: None,
            workers: 1,
            device_decode: true,
            dropout_prob: 0.0,
            round_deadline_ms: None,
            delta_frames: true,
        }
    }
}

/// Prefill result (before decoding).
pub struct PrefillOutput {
    /// Final hidden states per participant (only when `record_hidden`),
    /// trimmed to valid rows.
    pub hidden: Vec<Option<HostTensor>>,
    /// Positions of each participant's valid tokens.
    pub positions: Vec<Vec<i32>>,
    pub net: NetReport,
    pub wall_ms: f64,
}

/// Full session result.
pub struct SessionReport {
    /// The task publisher's decoded answer.
    pub answer: String,
    pub generated_tokens: usize,
    /// Per-participant answers (only participants that kept caches decode;
    /// others are `None`).  `answers[publisher]` equals `answer`.
    pub answers: Vec<Option<String>>,
    pub net: NetReport,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Final hidden per participant (when `record_hidden`).
    pub hidden: Vec<Option<HostTensor>>,
    pub positions: Vec<Vec<i32>>,
}

/// Run `f(0..n)` across the pool (ordered results) or inline when no pool
/// is configured.  Errors are stringly-typed so closure results satisfy
/// the pool's `Send + 'static` bound.
fn run_parallel<T, F>(pool: Option<&Arc<Pool>>, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(usize) -> Result<T, String> + Send + Sync + 'static,
{
    let outs: Vec<Result<T, String>> = match pool {
        Some(pool) => pool
            .scope_map(n, f)
            .map_err(|e| anyhow::anyhow!("parallel section failed: {e}"))?,
        None => (0..n).map(f).collect(),
    };
    outs.into_iter().map(|r| r.map_err(anyhow::Error::msg)).collect()
}

/// Collect one round's uplink contributions from transport-backed nodes
/// **concurrently**: every request is issued before any reply is read, so
/// the wall-clock cost of the wire round is the slowest node's round trip
/// rather than the sum over nodes.
///
/// With a pool, each node's full round trip (encode request → send →
/// await reply → decode) runs as its own task via [`Pool::scope_map`],
/// overlapping serialization work too; without one, the driver fans all
/// requests out first and then drains the replies.  Either way results
/// are collected **by participant index, never arrival order** — the
/// aggregation input (and thus the whole session) is deterministic, and
/// late nodes were already demoted by the simulated per-round deadline
/// before any request went out.
#[allow(clippy::too_many_arguments)]
fn collect_remote_contributions(
    pool: Option<&Arc<Pool>>,
    remotes: &mut Vec<RemoteParticipant>,
    block: usize,
    epoch: usize,
    ks: &Arc<Vec<HostTensor>>,
    vs: &Arc<Vec<HostTensor>>,
    tx_flags: &[Vec<bool>],
    on_time: &[bool],
    scores: &[Option<Vec<f64>>],
) -> Result<Vec<Option<KvContribution>>> {
    let n = remotes.len();
    for r in remotes.iter_mut() {
        r.begin_round(epoch);
    }
    match pool {
        Some(pool) if n > 1 => {
            // Move each proxy into a slot its pool task takes exactly
            // once and puts back when the round trip completes.
            let slots: Arc<Vec<Mutex<Option<RemoteParticipant>>>> =
                Arc::new(remotes.drain(..).map(|r| Mutex::new(Some(r))).collect());
            let ks_in = Arc::clone(ks);
            let vs_in = Arc::clone(vs);
            let tx_in: Arc<Vec<Vec<bool>>> = Arc::new(tx_flags.to_vec());
            let on_in: Arc<Vec<bool>> = Arc::new(on_time.to_vec());
            let scores_in: Arc<Vec<Option<Vec<f64>>>> = Arc::new(scores.to_vec());
            let slots_in = Arc::clone(&slots);
            let outs = run_parallel(Some(pool), n, move |p| {
                let mut r = slots_in[p]
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or("remote slot taken twice")?;
                let res = if on_in[p] {
                    r.contribute(block, &ks_in[p], &vs_in[p], &tx_in[p], scores_in[p].as_deref())
                        .map(Some)
                        .map_err(|e| format!("{e:#}"))
                } else {
                    Ok(None)
                };
                *slots_in[p].lock().unwrap() = Some(r);
                res
            });
            // Put the proxies back (index order) *before* surfacing any
            // error, so a failed round can still shut the hosts down.
            // Every task returns its proxy to its slot before its result
            // is sent, and scope_map has collected all results by now, so
            // the slots are settled — but a worker may still be dropping
            // its closure's Arc clone, so read through the Arc instead of
            // unwrapping it.  A panicked task may have dropped its proxy;
            // the survivors are enough for shutdown and the error aborts
            // the session anyway.
            let mut restored = Vec::with_capacity(n);
            for slot in slots.iter() {
                if let Some(r) = slot.lock().unwrap().take() {
                    restored.push(r);
                }
            }
            *remotes = restored;
            outs
        }
        _ => {
            // No pool: still overlap the network by issuing every request
            // up front; replies queue on their own per-node transports
            // while earlier ones are read.
            for p in 0..n {
                if on_time[p] {
                    remotes[p].contribute_send(
                        block,
                        &ks[p],
                        &vs[p],
                        &tx_flags[p],
                        scores[p].as_deref(),
                    )?;
                }
            }
            let mut out = Vec::with_capacity(n);
            for p in 0..n {
                out.push(if on_time[p] {
                    Some(remotes[p].contribute_recv(block)?)
                } else {
                    None
                });
            }
            Ok(out)
        }
    }
}

/// Drives one collaborative task through the engine by exchanging typed
/// round messages between [`ParticipantNode`]s.
pub struct SessionDriver<'a> {
    engine: &'a Engine,
    cfg: SessionConfig,
    /// One node per participant, each owning exactly its own state.
    nodes: Vec<ParticipantNode>,
    /// Effective attendance after dropout (== `cfg.schedule` when
    /// `dropout_prob` is 0).
    schedule: SyncSchedule,
    /// Aggregation policy object (selection + merge).
    aggregator: Box<dyn Aggregator>,
    net: NetSim,
    rng: Xoshiro256ss,
    publisher: usize,
    total_len: usize,
    /// Per-row attention-mass accumulator (only for relevance policies).
    relevance: Option<RelevanceTracker>,
    /// Worker pool for the per-participant loops (`workers > 1`).
    pool: Option<Arc<Pool>>,
    /// Wire deployment: one transport-backed proxy per participant.  When
    /// set, every protocol-plane step (contribution uplink, frame/local
    /// downlink, decode) crosses the proxy's transport instead of
    /// touching the local node's caches; the compute plane (hidden
    /// states, QKV, attention) stays engine-colocated.  `None` is the
    /// fully in-process session.
    remotes: Option<Vec<RemoteParticipant>>,
}

impl<'a> SessionDriver<'a> {
    pub fn new(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
    ) -> Result<Self> {
        let n = partition.n_participants();
        anyhow::ensure!(net.n_participants() == n, "net sim participant count");
        anyhow::ensure!(cfg.schedule.n_participants() == n, "schedule participant count");
        anyhow::ensure!(
            cfg.schedule.n_blocks() == engine.manifest.model.n_layers,
            "schedule block count"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.dropout_prob),
            "dropout_prob must be in [0, 1], got {}",
            cfg.dropout_prob
        );
        if let Some(d) = cfg.round_deadline_ms {
            // NaN fails the comparison; +inf is allowed (it still
            // schedules arrivals, unlike None which skips scheduling).
            anyhow::ensure!(
                d >= 0.0,
                "round_deadline_ms must be >= 0, got {d}"
            );
        }
        let mut rng = Xoshiro256ss::new(cfg.seed ^ 0x5E55_10);
        let publisher = partition.publisher();

        // Build one node per participant: apply local sparsity, pad, embed.
        let mut nodes = Vec::with_capacity(n);
        for p in 0..n {
            let (s, e) = partition.spans[p];
            let span_ids = &partition.ids[s..e];
            // Protect the tail of the publisher (the "A:" anchor) from
            // local-sparsity dropping.
            let protect = if p == publisher { 3 } else { 0 };
            let keep = cfg.local_sparsity.select(span_ids.len(), protect, &mut rng);
            let ids: Vec<i32> = keep.iter().map(|&i| span_ids[i]).collect();
            let pos: Vec<i32> = keep.iter().map(|&i| (s + i) as i32).collect();
            let keep_caches = p == publisher || cfg.decode_all;
            nodes.push(ParticipantNode::build(engine, p, &ids, pos, keep_caches)?);
        }

        if let Some(b) = &cfg.kv_row_budgets {
            anyhow::ensure!(b.len() == n, "kv_row_budgets length {} != {n}", b.len());
        }
        let relevance = cfg.kv_policy.needs_relevance().then(|| {
            RelevanceTracker::new(&nodes.iter().map(|s| s.valid).collect::<Vec<_>>())
        });
        let pool = (cfg.workers > 1).then(|| Arc::new(Pool::new(cfg.workers)));
        let aggregator = aggregate::for_policy(cfg.kv_policy);

        // Dropout draws come from their own seeded stream: with prob 0 no
        // stream is even created, so the default path stays byte-identical
        // to the pre-dropout driver.
        let schedule = if cfg.dropout_prob > 0.0 {
            let mut drng = Xoshiro256ss::new(cfg.seed ^ 0xD80F_F00D);
            cfg.schedule.with_dropout(cfg.dropout_prob, &mut drng)
        } else {
            cfg.schedule.clone()
        };

        Ok(Self {
            engine,
            cfg,
            nodes,
            schedule,
            aggregator,
            net,
            rng,
            publisher,
            total_len: partition.len(),
            relevance,
            pool,
            remotes: None,
        })
    }

    /// A wire deployment of the session: one [`Transport`] per
    /// participant, each leading to a node host (see
    /// [`transport::NodeHost`]) that owns that participant's decode
    /// caches and speaks the protocol messages.  The driver keeps the
    /// compute plane; local caches are dropped so the transported state
    /// is authoritative.  Sends each host its `Init` frame before
    /// returning.
    ///
    /// [`transport::NodeHost`]: crate::fedattn::transport::NodeHost
    pub fn new_with_remotes(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
        transports: Vec<Box<dyn Transport>>,
    ) -> Result<Self> {
        let mut driver = Self::new(engine, partition, cfg, net)?;
        let n = driver.nodes.len();
        anyhow::ensure!(
            transports.len() == n,
            "got {} transports for {n} participants",
            transports.len()
        );
        let md = &engine.manifest.model;
        let cache_capacity = engine.manifest.decode_cache;
        let mut remotes = Vec::with_capacity(n);
        for (p, t) in transports.into_iter().enumerate() {
            let keep = p == driver.publisher || driver.cfg.decode_all;
            let node = &mut driver.nodes[p];
            // The remote host owns the authoritative caches.
            node.caches = Vec::new();
            let mut rp =
                RemoteParticipant::new(p, node.pos.clone(), node.valid, keep, t);
            rp.set_delta_frames(driver.cfg.delta_frames);
            rp.init(md.n_layers, md.n_kv_heads, md.head_dim, cache_capacity)?;
            remotes.push(rp);
        }
        driver.remotes = Some(remotes);
        Ok(driver)
    }

    /// The effective attendance schedule (after dropout masking).
    pub fn effective_schedule(&self) -> &SyncSchedule {
        &self.schedule
    }

    /// Does participant `p` keep decode caches (locally or at its remote
    /// host)?
    fn keeps_caches_for(&self, p: usize) -> bool {
        match &self.remotes {
            Some(r) => r[p].keeps_caches(),
            None => self.nodes[p].keeps_caches(),
        }
    }

    /// Run the federated prefill (Alg. 1 lines 2–14).
    pub fn prefill(&mut self) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let md = self.engine.manifest.model.clone();
        let n = self.nodes.len();
        let n_layers = md.n_layers;
        let row_bytes_usize = GlobalKv::row_bytes(md.n_kv_heads, md.head_dim);

        // Budgeted policies: resolve per-participant row budgets once per
        // session.  ByteBudget's total is split across heterogeneous links
        // proportionally to bandwidth unless the coordinator already did.
        let budgets: Option<Vec<usize>> =
            match (&self.cfg.kv_row_budgets, self.cfg.kv_policy) {
                (Some(b), _) => Some(b.clone()),
                (None, KvExchangePolicy::ByteBudget { bytes_per_round }) => {
                    Some(crate::net::allocate_row_budgets(
                        self.net.links(),
                        bytes_per_round / row_bytes_usize.max(1),
                    ))
                }
                _ => None,
            };

        // Executed-sync-round ordinal: the round-scoped "epoch" stamped on
        // contribute requests and delta downlink frames so a node can tie
        // a delta's retain-list to the fresh-KV generation it references.
        let mut epoch = 0usize;
        for m in 0..n_layers {
            let attend = self.schedule.attend[m].clone();

            // Round planning.  Row selection runs first — it depends only
            // on relevance accumulated at *earlier* sync rounds, never on
            // this block's compute, and its RNG draws happen in
            // participant order exactly as before, so the session stream
            // is unchanged.  With a deadline, the planned payload sizes
            // (a pure function of the selected rows) are handed to the
            // network simulator to *schedule* each uplink's arrival; the
            // stragglers whose contribution lands past the deadline are
            // demoted to the local path before any compute is placed.
            let plan = if attend.iter().any(|&b| b) {
                let mut tx_flags: Vec<Vec<bool>> = Vec::with_capacity(n);
                for p in 0..n {
                    let ctx = TxContext {
                        who: p,
                        publisher: self.publisher,
                        len: self.nodes[p].valid,
                        row_bytes: row_bytes_usize,
                        relevance: self.relevance.as_ref().map(|t| t.scores(p)),
                        row_budget: budgets.as_ref().map(|b| b[p]),
                    };
                    tx_flags.push(self.aggregator.select(&ctx, &mut self.rng));
                }
                let payloads: Vec<u64> = tx_flags
                    .iter()
                    .map(|tx| {
                        tx.iter().filter(|&&b| b).count() as u64 * row_bytes_usize as u64
                    })
                    .collect();
                let (on_time, arrivals) = match self.cfg.round_deadline_ms {
                    Some(d) => {
                        let arr = self.net.uplink_arrivals(&payloads);
                        (arr.iter().map(|&a| a <= d).collect::<Vec<bool>>(), Some(arr))
                    }
                    // No deadline: nobody is late and no arrival is ever
                    // drawn (byte-identical to the pre-deadline driver).
                    None => (vec![true; n], None),
                };
                let attend_eff: Vec<bool> =
                    attend.iter().zip(&on_time).map(|(&a, &o)| a && o).collect();
                attend_eff
                    .iter()
                    .any(|&b| b)
                    .then_some((tx_flags, on_time, arrivals, attend_eff))
            } else {
                None
            };

            let Some((tx_flags, on_time, arrivals, attend)) = plan else {
                // Phase I only — either nobody is scheduled at this block
                // or every scheduled attendee missed the deadline.  Both
                // run a fused local block for everyone (pool-parallel;
                // ordered collection keeps determinism) with no exchange
                // and no round recorded: deadline starvation degrades
                // exactly like a fully-dropped round.
                let inputs: Vec<_> = self
                    .nodes
                    .iter()
                    .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                    .collect();
                let engine = self.engine.clone();
                let outs = run_parallel(self.pool.as_ref(), n, move |p| {
                    let (x, pos, lmask) = &inputs[p];
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map_err(|e| format!("{e:#}"))
                })?;
                for (p, (xo, k, v)) in outs.into_iter().enumerate() {
                    self.nodes[p].set_hidden(xo);
                    if self.keeps_caches_for(p) {
                        match self.remotes.as_mut() {
                            Some(r) => r[p].absorb_local(m, &k, &v)?,
                            None => self.nodes[p].absorb_local(m, &k, &v)?,
                        }
                    }
                }
                continue;
            };

            // This block executes a sync round: stamp it with the next
            // round-scoped epoch.
            let round_epoch = epoch;
            epoch += 1;

            // Sync block: everyone produces (q,)k,v; attendees do global
            // attention over the aggregated KV.  Phase 1 is pool-parallel.
            let inputs: Vec<_> = self
                .nodes
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let engine = self.engine.clone();
            let phase1 = run_parallel(self.pool.as_ref(), n, move |p| {
                let (x, pos, lmask) = &inputs[p];
                if attend_in[p] {
                    engine
                        .qkv_project(m, x.as_ref(), pos.as_slice())
                        .map(|(q, k, v)| (Some(q), k, v, None))
                } else {
                    // Non-attendee: plain local block; its fresh K/V are
                    // what it would transmit to attendees.
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map(|(xo, k, v)| (None, k, v, Some(xo)))
                }
                .map_err(|e| format!("{e:#}"))
            })?;
            let mut qs: Vec<Option<HostTensor>> = Vec::with_capacity(n);
            let mut ks: Vec<HostTensor> = Vec::with_capacity(n);
            let mut vs: Vec<HostTensor> = Vec::with_capacity(n);
            for (p, (q, k, v, xo)) in phase1.into_iter().enumerate() {
                qs.push(q);
                ks.push(k);
                vs.push(v);
                if let Some(xo) = xo {
                    self.nodes[p].set_hidden(xo);
                }
            }
            // Shared for the (possibly pool-parallel) contribution
            // round-trips below and the aggregation after them.
            let ks = Arc::new(ks);
            let vs = Arc::new(vs);

            // Round messages: each on-time node packages its uplink
            // KvContribution — over the wire when remotes are attached,
            // so the message has really crossed a transport before its
            // payload size is billed.  A late node contributes nothing
            // this round (its rows are excluded from aggregation, the
            // FL-straggler partial-aggregation analogue).  The message
            // carries the real row payload so accounting is measured,
            // not estimated.
            //
            // Remote collection is concurrent: every node receives its
            // contribution request before any reply is read, so the wire
            // round waits for the slowest node instead of summing all of
            // them.  Results are collected by participant index (never
            // arrival order), so aggregation — and therefore the whole
            // session — is deterministic.  The in-process path keeps its
            // sequential loop: node contributions are pure and the
            // `session_golden` fixtures pin that path byte-for-byte.
            let contributions: Vec<Option<KvContribution>> = match self.remotes.as_mut() {
                Some(remotes) => {
                    // Owned score copies so the pool tasks' closures can be
                    // 'static; the wire path copies the K/V payloads anyway.
                    let scores_by_p: Vec<Option<Vec<f64>>> = (0..n)
                        .map(|p| self.relevance.as_ref().map(|t| t.scores(p).to_vec()))
                        .collect();
                    collect_remote_contributions(
                        self.pool.as_ref(),
                        remotes,
                        m,
                        round_epoch,
                        &ks,
                        &vs,
                        &tx_flags,
                        &on_time,
                        &scores_by_p,
                    )?
                }
                None => {
                    let mut out = Vec::with_capacity(n);
                    for p in 0..n {
                        if !on_time[p] {
                            out.push(None);
                            continue;
                        }
                        let scores = self.relevance.as_ref().map(|t| t.scores(p));
                        out.push(Some(self.nodes[p].contribute(
                            m,
                            &ks[p],
                            &vs[p],
                            &tx_flags[p],
                            scores,
                        )?));
                    }
                    out
                }
            };

            // Aggregate the on-time contributions into the global KV
            // (Eq. 20); a late participant's rows are excluded entirely
            // (valid = 0 keeps the owner numbering stable).
            let rows_total: usize = (0..n)
                .map(|p| if on_time[p] { self.nodes[p].valid } else { 0 })
                .sum();
            let g_pad = self.engine.manifest.pick_g(rows_total)?;
            let parts_refs: Vec<PartRows<'_>> = (0..n)
                .map(|p| {
                    (
                        &ks[p],
                        &vs[p],
                        self.nodes[p].pos.as_slice(),
                        if on_time[p] { self.nodes[p].valid } else { 0 },
                        tx_flags[p].as_slice(),
                    )
                })
                .collect();
            let gkv = self.aggregator.aggregate(
                &parts_refs,
                g_pad,
                self.relevance.as_ref().map(|t| t.all_scores()),
            )?;
            let (kv_pos, kv_owner, kv_tx) = gkv.meta_columns();

            // Communication accounting + simulated transfer time: the
            // bytes on the wire are the encoded contribution payloads —
            // the protocol messages are the single source of truth.  Late
            // contributions never arrived, so they bill nothing: round
            // bytes are exactly the sum of on-time payloads.
            let tx_bytes: Vec<u64> = contributions
                .iter()
                .map(|c| c.as_ref().map_or(0, |c| c.payload_bytes()))
                .collect();
            #[cfg(debug_assertions)]
            {
                // The packed rows and the wire messages must tell the same
                // story, uplink and downlink (also pinned, with real
                // payloads, by tests/protocol_messages.rs).
                let row_bytes = row_bytes_usize as u64;
                let from_pack: Vec<u64> = gkv
                    .tx_rows_by_owner(n)
                    .iter()
                    .map(|&r| r as u64 * row_bytes)
                    .collect();
                debug_assert_eq!(tx_bytes, from_pack, "uplink bytes drifted from pack");
                let frame = crate::fedattn::protocol::GlobalKvFrame::from_global(m, &gkv);
                let total: u64 = tx_bytes.iter().sum();
                for p in 0..n {
                    debug_assert_eq!(
                        frame.payload_bytes_for(p),
                        total - tx_bytes[p],
                        "downlink bytes drifted from frame"
                    );
                }
                debug_assert_eq!(
                    frame.full_payload_bytes(),
                    gkv.rows() as u64 * row_bytes_usize as u64,
                    "full-frame bytes drifted from packed rows"
                );
            }
            // Downlink billing follows the frames actually shipped: with
            // delta frames (default) each attendee is billed the
            // transmitted rows of its peers (`total - own_tx` — the
            // accounting the protocol has always used, so the default is
            // byte-identical to the pre-delta driver); with full frames
            // every attendee is billed every packed row, the pre-delta
            // wire cost kept as the measurable baseline.
            let rx_full: Option<Vec<u64>> = (!self.cfg.delta_frames)
                .then(|| vec![gkv.rows() as u64 * row_bytes_usize as u64; n]);
            match (&arrivals, &rx_full) {
                // Deadline path: reuse the pre-drawn uplink times so the
                // round is billed against the very arrivals that decided
                // who made the cut.
                (Some(arr), None) => self.net.exchange_round_scheduled(&tx_bytes, &attend, arr),
                (None, None) => self.net.exchange_round(&tx_bytes, &attend),
                (Some(arr), Some(rx)) => {
                    self.net.exchange_round_scheduled_with_downlink(&tx_bytes, &attend, arr, rx)
                }
                (None, Some(rx)) => self.net.exchange_round_with_downlink(&tx_bytes, &attend, rx),
            };

            // Upload the packed global KV to the device ONCE per sync
            // round; every attendee's attention shares the handles (the
            // buffers are immutable, so read-only sharing holds by
            // construction).
            let gk_dev = self.engine.upload(&gkv.k)?;
            let gv_dev = self.engine.upload(&gkv.v)?;

            // Global attention + FFN for attendees (Eq. 21 + 19),
            // pool-parallel.  When a relevance policy is active, each
            // attendee also computes the column marginals of its attention
            // (row-sum of the attention weights) inside its task; the
            // accumulation below stays sequential in participant order so
            // the result is bit-identical to a sequential session.
            let gkv = Arc::new(gkv);
            let qs = Arc::new(qs);
            let kv_meta = Arc::new((kv_pos, kv_owner, kv_tx));
            let pinputs: Vec<_> = self
                .nodes
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), st.valid))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let track_mass = self.relevance.is_some();
            let engine = self.engine.clone();
            let rows = gkv.rows();
            let gkv_in = Arc::clone(&gkv);
            type AttnOut = Option<(HostTensor, Option<Vec<f64>>)>;
            let outs: Vec<AttnOut> = run_parallel(self.pool.as_ref(), n, move |p| {
                if !attend_in[p] {
                    return Ok(None);
                }
                let (x, pos_pad, valid) = &pinputs[p];
                let q = qs[p].as_ref().ok_or("missing q for attendee")?;
                let (kv_pos, kv_owner, kv_tx) = &*kv_meta;
                let mask = global_mask(
                    pos_pad.as_slice(),
                    *valid,
                    g_pad,
                    kv_pos,
                    kv_owner,
                    kv_tx,
                    rows,
                    p,
                );
                let mass = track_mass
                    .then(|| relevance::attention_mass(q, &gkv_in.k, &mask, *valid, rows));
                let xo = engine
                    .attn_ffn_dev(m, x.as_ref(), q, &gk_dev, &gv_dev, &mask)
                    .map_err(|e| format!("{e:#}"))?;
                Ok(Some((xo, mass)))
            })?;
            let mut round_mass: Option<Vec<f64>> =
                self.relevance.as_ref().map(|_| vec![0.0; gkv.rows()]);
            for (p, out) in outs.into_iter().enumerate() {
                let Some((xo, mass)) = out else { continue };
                if let (Some(acc), Some(mass)) = (round_mass.as_mut(), mass) {
                    for (a, x) in acc.iter_mut().zip(&mass) {
                        *a += x;
                    }
                }
                self.nodes[p].set_hidden(xo);
            }
            if let (Some(tr), Some(acc)) = (self.relevance.as_mut(), round_mass) {
                tr.observe(&gkv.meta, &acc);
            }

            // Decode caches for this block (paper §IV-C): nodes that
            // (effectively) attended absorb the aggregated frame
            // (restricted to what they could see); others — including
            // deadline stragglers — absorb their own local KV.  In wire
            // mode the frame/local rows cross the transport to the host
            // that owns the authoritative caches.
            for p in 0..n {
                if !self.keeps_caches_for(p) {
                    continue;
                }
                if attend[p] {
                    match self.remotes.as_mut() {
                        Some(r) => r[p].absorb_frame(m, &gkv)?,
                        None => self.nodes[p].absorb_frame(m, &gkv)?,
                    }
                } else {
                    match self.remotes.as_mut() {
                        Some(r) => r[p].absorb_local(m, &ks[p], &vs[p])?,
                        None => self.nodes[p].absorb_local(m, &ks[p], &vs[p])?,
                    }
                }
            }
        }

        let hidden = self.collect_hidden();
        Ok(PrefillOutput {
            hidden,
            positions: self.nodes.iter().map(|s| s.pos.clone()).collect(),
            net: self.net.report().clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    fn collect_hidden(&self) -> Vec<Option<HostTensor>> {
        self.nodes
            .iter()
            .map(|st| {
                if self.cfg.record_hidden {
                    let mut h = HostTensor::zeros(&[st.valid, st.x.shape()[1]]);
                    h.copy_rows_from(st.x.as_ref(), 0..st.valid, 0);
                    Some(h)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Greedy decode from participant `p`'s KV caches (requires that `p`
    /// kept caches).  Returns the decoded text and token count.  In wire
    /// mode the decode runs at `p`'s node host (which owns the caches and
    /// its own engine) and the tokens stream back as `TokenBroadcast`
    /// frames.
    pub fn decode_participant(&mut self, p: usize) -> Result<(String, usize)> {
        anyhow::ensure!(self.keeps_caches_for(p), "participant {p} has no caches");
        let h_last = self.nodes[p].last_hidden();
        if let Some(remotes) = self.remotes.as_mut() {
            let (total_len, max_new, dev) =
                (self.total_len, self.cfg.max_new_tokens, self.cfg.device_decode);
            return remotes[p].decode(&h_last, total_len, max_new, dev);
        }
        let mut caches = std::mem::take(&mut self.nodes[p].caches);
        let res = decode_from_caches(
            self.engine,
            &mut caches,
            &h_last,
            self.total_len,
            self.cfg.max_new_tokens,
            self.cfg.device_decode,
        );
        self.nodes[p].caches = caches;
        res
    }

    /// Decode the task publisher.
    pub fn decode(&mut self) -> Result<(String, usize)> {
        self.decode_participant(self.publisher)
    }

    /// Prefill + decode, returning the full report.  With `decode_all`
    /// and `workers > 1` the per-participant decodes run pool-parallel
    /// (each participant's caches are independent).
    pub fn run(mut self) -> Result<SessionReport> {
        let pre = self.prefill()?;
        let t0 = std::time::Instant::now();
        let n = self.nodes.len();
        let decoders: Vec<usize> =
            (0..n).filter(|&p| self.keeps_caches_for(p)).collect();

        let decoded: Vec<(String, usize)> = if self.remotes.is_some() {
            // Wire mode: decode sequentially through each host (the
            // tokens are independent of decode order, and parallel
            // decodes would only contend the transports), then release
            // the hosts — on the error path too, so a failed decode
            // still tells the surviving hosts to exit instead of leaving
            // them to discover the dropped transports.
            let mut out = Vec::with_capacity(decoders.len());
            let mut failed = None;
            for &p in &decoders {
                match self.decode_participant(p) {
                    Ok(r) => out.push(r),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            for r in self.remotes.as_mut().unwrap() {
                let _ = r.shutdown();
            }
            if let Some(e) = failed {
                return Err(e);
            }
            out
        } else {
            // Move each decoding participant's caches + kick-off hidden
            // state into a slot the (shared) pool closure can take
            // exactly once.
            let slots: Vec<Mutex<Option<(Vec<BlockCache>, HostTensor)>>> = decoders
                .iter()
                .map(|&p| {
                    let caches = std::mem::take(&mut self.nodes[p].caches);
                    let h_last = self.nodes[p].last_hidden();
                    Mutex::new(Some((caches, h_last)))
                })
                .collect();
            let slots = Arc::new(slots);
            let engine = self.engine.clone();
            let (total_len, max_new, device_decode) =
                (self.total_len, self.cfg.max_new_tokens, self.cfg.device_decode);
            let slots_in = Arc::clone(&slots);
            run_parallel(self.pool.as_ref(), decoders.len(), move |i| {
                let (mut caches, h_last) = slots_in[i]
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or("decode slot taken twice")?;
                decode_from_caches(&engine, &mut caches, &h_last, total_len, max_new, device_decode)
                    .map_err(|e| format!("{e:#}"))
            })?
        };

        let mut answers: Vec<Option<String>> = vec![None; n];
        let mut generated = 0usize;
        let mut answer = String::new();
        for (&p, (text, tokens)) in decoders.iter().zip(decoded) {
            if p == self.publisher {
                answer = text.clone();
                generated = tokens;
            }
            answers[p] = Some(text);
        }
        Ok(SessionReport {
            answer,
            generated_tokens: generated,
            answers,
            net: self.net.into_report(),
            prefill_ms: pre.wall_ms,
            decode_ms: t0.elapsed().as_secs_f64() * 1e3,
            hidden: pre.hidden,
            positions: pre.positions,
        })
    }

    /// Prefill only (error-analysis paths that do not decode).
    pub fn run_prefill_only(mut self) -> Result<PrefillOutput> {
        self.prefill()
    }

    /// Attach a shared worker pool (e.g. the coordinator's, reused across
    /// tasks) instead of the session-owned one `workers > 1` would spawn.
    /// Pass `workers = 1` in the config when using this to avoid creating
    /// a throwaway pool in [`SessionDriver::new`].
    pub fn with_shared_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

/// Greedy decode over one participant's per-layer caches.
///
/// When `device_decode` is set and the artifact set has a decode-tail
/// variant wide enough for the horizon, each cache is frozen on the
/// device first and every step uploads only the `[R]` tail (O(1) bytes
/// per step in the cache capacity); otherwise the host path uploads the
/// full cache per layer per step, as before.
fn decode_from_caches(
    engine: &Engine,
    caches: &mut [BlockCache],
    h_last: &HostTensor,
    total_len: usize,
    max_new_tokens: usize,
    device_decode: bool,
) -> Result<(String, usize)> {
    let ids =
        decode_ids_from_caches(engine, caches, h_last, total_len, max_new_tokens, device_decode)?;
    Ok((tokenizer::decode(&ids), ids.len()))
}

/// [`decode_from_caches`] at the token level: the raw greedy token ids,
/// before detokenization.  The wire transport's node host uses this to
/// stream each generated token back as a `TokenBroadcast` frame.
pub(crate) fn decode_ids_from_caches(
    engine: &Engine,
    caches: &mut [BlockCache],
    h_last: &HostTensor,
    total_len: usize,
    max_new_tokens: usize,
    device_decode: bool,
) -> Result<Vec<i32>> {
    // A step appends at most one row per layer, and the final step never
    // appends: at most max_new_tokens - 1 tail rows per decode.
    let steps = max_new_tokens.saturating_sub(1);
    let tail_r = (device_decode && steps > 0)
        .then(|| engine.manifest.pick_decode_tail(steps))
        .flatten();
    // Freeze lazily, right before the first real decode pass — a decode
    // that terminates on its kick-off logits (immediate EOS) uploads
    // nothing at all, same as the host path.
    let mut frozen = false;

    // Kick-off logits from the participant's final prompt token.
    let mut logits = engine.logits(h_last)?;
    let mut out_ids: Vec<i32> = Vec::new();
    for step in 0..max_new_tokens {
        let next = argmax(&logits);
        if next == tokenizer::EOS {
            break;
        }
        out_ids.push(next);
        if step + 1 == max_new_tokens {
            break;
        }
        if let (Some(r), false) = (tail_r, frozen) {
            for cache in caches.iter_mut() {
                // A previous decode may have part-filled this cache's
                // tail; when the remaining capacity can't fit this
                // horizon, drop the stale prefix so freeze_device
                // re-uploads a fresh one (current cache state, empty
                // tail).
                let len = cache.len;
                let stale = cache
                    .dev
                    .as_ref()
                    .is_some_and(|dev| len - dev.base_len + steps > dev.k_tail.shape()[0]);
                if stale {
                    cache.dev = None;
                }
                cache.freeze_device(engine, r)?;
            }
            frozen = true;
        }
        // One decode pass to produce logits for the following token.
        let pos = (total_len + step) as i32;
        let mut x = engine.embed(&[next])?;
        for (m, cache) in caches.iter_mut().enumerate() {
            let (xo, kn, vn) = match cache.dev.as_ref() {
                Some(dev) => engine.decode_block_tail(
                    m,
                    &x,
                    pos,
                    &dev.k,
                    &dev.v,
                    &dev.mask,
                    &dev.k_tail,
                    &dev.v_tail,
                    &dev.tail_mask,
                )?,
                None => engine.decode_block(m, &x, pos, &cache.k, &cache.v, &cache.dmask)?,
            };
            x = xo;
            cache.push_rows(&kn, &vn, 1, &[true]);
        }
        logits = engine.logits(&x)?;
    }
    Ok(out_ids)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn run_parallel_matches_sequential_and_reports_errors() {
        let pool = Arc::new(Pool::new(3));
        let seq = run_parallel(None, 8, |i| Ok::<usize, String>(i * i)).unwrap();
        let par = run_parallel(Some(&pool), 8, |i| Ok::<usize, String>(i * i)).unwrap();
        assert_eq!(seq, par);
        let err = run_parallel(Some(&pool), 4, |i| {
            if i == 2 {
                Err("boom".to_string())
            } else {
                Ok(i)
            }
        });
        assert!(err.is_err());
    }

    #[test]
    fn session_config_rejects_bad_dropout() {
        // Validated in SessionDriver::new; the config itself is plain data.
        let cfg = SessionConfig::new(SyncSchedule::uniform(4, 2, 2));
        assert_eq!(cfg.dropout_prob, 0.0);
    }
}
