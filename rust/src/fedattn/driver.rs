//! The session driver: one collaborative-inference task run as a typed
//! message-passing protocol over [`ParticipantNode`]s.
//!
//! The driver owns no participant state.  Each round (Transformer block)
//! it:
//!
//! 1. asks the [`Aggregator`] which rows every node transmits,
//! 2. collects each node's [`KvContribution`] (the uplink message whose
//!    encoded payload size **is** the round's byte accounting, fed
//!    straight into [`NetSim::exchange_round`]),
//! 3. merges contributions into the global KV (Eq. 20) and lets every
//!    attendee attend over the shared device upload,
//! 4. hands the frame (or, off-round, each node's own KV) back to the
//!    nodes for their decode caches.
//!
//! Attendance is a *schedule input*: per-node dropout
//! ([`SessionConfig::dropout_prob`]) masks attendance before the first
//! round, so a dropped node simply runs the local path — no special case
//! in the round loop.  Stragglers are a *round input*: with a per-round
//! deadline ([`SessionConfig::round_deadline_ms`]) the network simulator
//! schedules each uplink's arrival and late contributions are excluded
//! from aggregation and billing (partial aggregation); without one, no
//! arrival is ever drawn and the loop is byte-identical to the
//! pre-deadline driver.
//!
//! A wire deployment attaches one [`RemoteParticipant`] per node
//! ([`SessionDriver::new_with_remotes`], usually via
//! [`TransportDriver`]): the session then runs **node-resident** — every
//! block forward pass (hidden states, QKV projection, attendee
//! attention, the local path, decode) executes at the node host on its
//! own engine, and only protocol messages cross the wire:
//! `KvContribution` up, `GlobalKvDeltaFrame`/`GlobalKvFrame` down,
//! `TokenBroadcast` out, plus the hidden-state-free control plane
//! (`Join`/`Advance*`/`RoundMass`).  The driver keeps planning (row
//! selection, deadlines, aggregation, billing) and sees only the
//! transmitted KV rows — untransmitted rows stay zero on its side, which
//! is invisible by construction (they are masked for every other
//! attendee, and an attendee restores its *own* rows from the fresh KV
//! it kept).  Wire rounds are **concurrent** — block turns fan out to
//! every node before any reply is read, so the round costs the slowest
//! node rather than the sum — and the downlink ships **delta frames** by
//! default ([`SessionConfig::delta_frames`]): each attendee receives
//! only the transmitted rows it does not already hold.  Collection order
//! is pinned to participant index, so both optimizations are
//! byte-invisible to the golden fixtures.  A node whose transport fails
//! mid-session is *demoted* — excluded from the remaining rounds exactly
//! like a deadline miss, its decode answer reported absent — without
//! killing the session.
//!
//! With churn recovery on ([`SessionConfig::rejoin`] plus a reconnector,
//! wired by [`TransportDriver::with_reconnector`]), demotion becomes a
//! two-stage state machine: a failed node first enters **probation**,
//! and at each following round boundary the driver asks the reconnector
//! for a fresh transport and runs the `Rejoin` handshake — shipping one
//! `Resync` frame (the retained aggregated [`GlobalKvFrame`]) per round
//! the node attended pre-demotion, so the node replays itself to the
//! live block.  A readmitted node is bit-identical to one that merely
//! missed those rounds via deadline misses (resync bytes are tallied on
//! the side in [`NetReport::resync_bytes`], never through round billing,
//! precisely so that equivalence holds).  A node that exhausts
//! [`SessionConfig::rejoin_max_attempts`] probation retries — or is
//! still on probation when prefill ends — is demoted for good.  With
//! the knob off (the default) nothing is retained or retried and the
//! session is byte-identical to the pre-rejoin driver.
//!
//! [`GlobalKvFrame`]: crate::fedattn::protocol::GlobalKvFrame
//!
//! Device-resident execution (shared per-round KV uploads, frozen decode
//! caches + `[R]` tails) and pool-parallel per-participant loops carry
//! over from the pre-protocol session; a parallel session is
//! byte-identical to a sequential one (ordered collection, sequential
//! host-side reductions).
//!
//! [`NetSim::exchange_round`]: crate::net::NetSim::exchange_round
//! [`Aggregator`]: crate::fedattn::aggregate::Aggregator
//! [`TransportDriver`]: crate::fedattn::transport::TransportDriver

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::data::Partition;
use crate::exec::Pool;
use crate::fedattn::aggregate::{self, Aggregator, PartRows};
use crate::fedattn::kv::GlobalKv;
use crate::fedattn::masks::global_mask;
use crate::fedattn::node::{BlockCache, Participant, ParticipantNode};
use crate::fedattn::protocol::{requantize_row, GlobalKvFrame, KvContribution, KvPrecision};
use crate::fedattn::relevance::{self, RelevanceTracker};
use crate::fedattn::schedule::SyncSchedule;
use crate::fedattn::sparse::{KvExchangePolicy, LocalSparsity, TxContext};
use crate::fedattn::transport::{read_timeout_for_deadline, RemoteParticipant, Transport};
use crate::net::{NetReport, NetSim};
use crate::runtime::Engine;
use crate::tensor::HostTensor;
use crate::tokenizer;
use crate::util::prng::Xoshiro256ss;

/// Session knobs (one FedAttn task).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub schedule: SyncSchedule,
    pub local_sparsity: LocalSparsity,
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Collect every participant's final hidden states (error analysis /
    /// divergence metrics; costs memory, off for serving).  Rejected in
    /// wire mode: hidden states never leave their node.
    pub record_hidden: bool,
    /// Keep KV caches and decode a response for *every* participant (the
    /// paper's Fig. 5 reports mean/min/max EM across participants).  The
    /// default caches and decodes only the task publisher.
    pub decode_all: bool,
    /// Coordinator-allocated per-participant KV row budgets (heterogeneous
    /// links); overrides the budget embedded in budgeted policies.  For
    /// [`KvExchangePolicy::ByteBudget`] with no explicit allocation the
    /// session derives one from the network simulator's link specs.
    pub kv_row_budgets: Option<Vec<usize>>,
    /// Thread-pool width for the per-participant loops (1 = sequential).
    /// Parallel sessions are byte-identical to sequential ones (ordered
    /// result collection + sequential host-side reductions).
    pub workers: usize,
    /// Freeze decode caches on the device and ship only the decode tail
    /// per token step.  Ignored (with a host-path fallback) when the
    /// artifact set predates decode-tail variants.
    pub device_decode: bool,
    /// Per-node, per-round attendance dropout probability in `[0, 1]`:
    /// each scheduled attendance is independently dropped with this
    /// probability (its own seeded RNG stream, so `0.0` is byte-identical
    /// to no dropout).  A dropped node runs the local path for that block
    /// and its peers aggregate without it — the federated-inference
    /// straggler/dropout scenario as a schedule input.
    pub dropout_prob: f64,
    /// Per-sync-round contribution deadline in **simulated** milliseconds
    /// (`federation.round_deadline_ms` / `--round-deadline`).  With a
    /// deadline, [`NetSim`] link latency + jitter *schedule* each uplink's
    /// arrival ([`NetSim::uplink_arrivals`]); contributions that land
    /// after the deadline are excluded from the round — not billed, not
    /// aggregated — and the late participant runs the local path (partial
    /// aggregation, the FL straggler analogue).  A round where every
    /// attendee misses the cut degrades to local attention exactly like a
    /// fully-dropped round.  `None` (the default) disables the deadline
    /// entirely: no arrivals are scheduled, no extra RNG is consumed, and
    /// behaviour is byte-identical to the pre-deadline driver.
    ///
    /// [`NetSim`]: crate::net::NetSim
    /// [`NetSim::uplink_arrivals`]: crate::net::NetSim::uplink_arrivals
    pub round_deadline_ms: Option<f64>,
    /// Delta-encode the downlink (`federation.delta_frames` /
    /// `--delta-frames`, default on): each attendee receives a
    /// [`GlobalKvDeltaFrame`] carrying only the transmitted rows of
    /// *other* participants — its own rows ride as a retain-list of
    /// round-scoped row ids resolved against the fresh KV it contributed,
    /// and untransmitted remote rows (masked for it anyway) are elided.
    /// Downlink billing is the delta (`total - own_tx`, the accounting
    /// the protocol has always used), and any cache miss automatically
    /// falls back to a full frame.  With the knob **off**, full
    /// [`GlobalKvFrame`]s ship and every attendee is billed every packed
    /// row — the pre-delta wire cost, kept as the measurable baseline
    /// (`BENCH_comm_delta.json`).  Decoded transcripts are byte-identical
    /// either way: elided rows are invisible to the attendee by
    /// construction.
    ///
    /// [`GlobalKvDeltaFrame`]: crate::fedattn::protocol::GlobalKvDeltaFrame
    /// [`GlobalKvFrame`]: crate::fedattn::protocol::GlobalKvFrame
    pub delta_frames: bool,
    /// Churn recovery (`federation.rejoin` / `--rejoin`, default off):
    /// in wire mode, a node whose transport fails enters *probation*
    /// instead of being demoted outright, and at each following round
    /// boundary the driver tries to readmit it through the
    /// `Rejoin`/`Resync` handshake (requires a reconnector — see
    /// [`TransportDriver::with_reconnector`]; without one the knob is
    /// inert).  Off, behaviour is byte-identical to the pre-rejoin
    /// driver: no resync frames are retained, no retry ever runs.
    pub rejoin: bool,
    /// Probation budget: how many failed reconnect attempts a node may
    /// accumulate before probation hardens into permanent demotion.
    pub rejoin_max_attempts: u32,
    /// Test fixture: force participant `p` late at block `m` for every
    /// `(m, p)` listed, after real deadline arrivals are folded in.  This
    /// is the reference world for the rejoin differential test — a node
    /// that "merely missed rounds r..r+k via deadline misses" — and draws
    /// no RNG, so `None` (the default) is byte-identical to not having
    /// the field at all.
    pub late_overrides: Option<Vec<(usize, usize)>>,
    /// Wire precision of K/V row payloads (`federation.kv_precision` /
    /// `--kv-precision`, default `f32`).  Reduced precisions quantize
    /// every *transmitted* row at the value plane — the quantized values
    /// are what contributions carry, what the aggregated round holds,
    /// and what attendee caches absorb, identically in-process and over
    /// the wire — and all byte accounting (uplink billing, downlink
    /// billing, deadline arrival scheduling, `ByteBudget` row budgets)
    /// follows [`KvPrecision::wire_row_bytes`].  A participant's *own*
    /// untransmitted rows never cross a wire and stay raw; `f32` is
    /// byte-identical to the pre-quantization driver.
    ///
    /// [`KvPrecision::wire_row_bytes`]: crate::fedattn::protocol::KvPrecision::wire_row_bytes
    pub kv_precision: KvPrecision,
    /// Liveness heartbeats (`federation.heartbeat_ms` / `--heartbeat`,
    /// default off): in wire mode the driver pings every `Alive` node at
    /// each block-round boundary and waits up to this window (ms) for
    /// the echoed `Pong`.  A node that misses
    /// [`SessionConfig::heartbeat_max_missed`] consecutive beats is
    /// handed to the churn machinery — probation when rejoin is armed,
    /// demotion otherwise — so a wedged host is caught in
    /// O(heartbeat_ms) instead of a round-deadline read timeout.  `None`
    /// sends nothing and is byte-identical to the pre-heartbeat driver;
    /// in-process sessions ignore it.
    pub heartbeat_ms: Option<f64>,
    /// Consecutive missed beats (retried back-to-back within one
    /// boundary) tolerated before demotion.  Clamped to ≥ 1.
    pub heartbeat_max_missed: u32,
}

impl SessionConfig {
    pub fn new(schedule: SyncSchedule) -> Self {
        Self {
            schedule,
            local_sparsity: LocalSparsity::full(),
            kv_policy: KvExchangePolicy::Full,
            max_new_tokens: 12,
            seed: 0,
            record_hidden: false,
            decode_all: false,
            kv_row_budgets: None,
            workers: 1,
            device_decode: true,
            dropout_prob: 0.0,
            round_deadline_ms: None,
            delta_frames: true,
            rejoin: false,
            rejoin_max_attempts: 3,
            late_overrides: None,
            kv_precision: KvPrecision::F32,
            heartbeat_ms: None,
            heartbeat_max_missed: 2,
        }
    }
}

/// Prefill result (before decoding).
pub struct PrefillOutput {
    /// Final hidden states per participant (only when `record_hidden`),
    /// trimmed to valid rows.
    pub hidden: Vec<Option<HostTensor>>,
    /// Positions of each participant's valid tokens.
    pub positions: Vec<Vec<i32>>,
    pub net: NetReport,
    pub wall_ms: f64,
}

/// Full session result.
pub struct SessionReport {
    /// The task publisher's decoded answer.
    pub answer: String,
    pub generated_tokens: usize,
    /// Per-participant answers (only participants that kept caches decode;
    /// others — and wire-mode nodes demoted by transport loss — are
    /// `None`).  `answers[publisher]` equals `answer`.
    pub answers: Vec<Option<String>>,
    pub net: NetReport,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Final hidden per participant (when `record_hidden`).
    pub hidden: Vec<Option<HostTensor>>,
    pub positions: Vec<Vec<i32>>,
}

/// Wire-mode link state for one participant: the two-stage demotion
/// machine.  `Alive → Probation` on a transport failure when churn
/// recovery is on (straight to `Demoted` otherwise), `Probation → Alive`
/// on a successful rejoin, `Probation → Demoted` when the retry budget
/// is exhausted or the rejoin window (prefill) closes.  `Demoted` is
/// terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WireState {
    Alive,
    Probation { attempts: u32 },
    Demoted,
}

/// A source of replacement transports for churn recovery: given a
/// participant index, dial a fresh connection to that participant's node
/// host (or fail, consuming one probation retry).
pub type Reconnector<'a> = Box<dyn FnMut(usize) -> Result<Box<dyn Transport>> + 'a>;

/// One retained sync round for rejoin resync: the aggregated frame
/// (already encoded) plus who effectively attended it — a rejoining node
/// replays exactly the rounds where its own `attend_eff` bit was set.
struct ResyncRound {
    block: usize,
    epoch: usize,
    frame: Vec<u8>,
    attended: Vec<bool>,
}

/// Resolve a probation node when no [`Reconnector`] is installed: there
/// is nothing to retry against, so the node is demoted like a deadline
/// miss — recorded in the [`NetReport`], never a panic.  Kept as a free
/// function so the no-reconnector contract is unit-testable without an
/// engine.
fn demote_stranded_probation(p: usize, wire_state: &mut [WireState], net: &mut NetSim) {
    wire_state[p] = WireState::Demoted;
    net.record_demotion();
    log::warn!(
        "node {p} on probation with no reconnector installed: demoted \
         (rejoin recovery requires TransportDriver::with_reconnector)"
    );
}

/// Run `f(0..n)` across the pool (ordered results) or inline when no pool
/// is configured.  Errors are stringly-typed so closure results satisfy
/// the pool's `Send + 'static` bound.
fn run_parallel<T, F>(pool: Option<&Arc<Pool>>, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(usize) -> Result<T, String> + Send + Sync + 'static,
{
    let outs: Vec<Result<T, String>> = match pool {
        Some(pool) => pool
            .scope_map(n, f)
            .map_err(|e| anyhow::anyhow!("parallel section failed: {e}"))?,
        None => (0..n).map(f).collect(),
    };
    outs.into_iter().map(|r| r.map_err(anyhow::Error::msg)).collect()
}

/// Drives one collaborative task through the engine by exchanging typed
/// round messages between [`ParticipantNode`]s.
pub struct SessionDriver<'a> {
    engine: &'a Engine,
    cfg: SessionConfig,
    /// One node per participant, each owning exactly its own state.  In
    /// wire mode these hold only the shard metadata (ids, positions,
    /// valid counts) the driver plans with — the authoritative hidden
    /// states and caches live at the node hosts.
    nodes: Vec<ParticipantNode>,
    /// Effective attendance after dropout (== `cfg.schedule` when
    /// `dropout_prob` is 0).
    schedule: SyncSchedule,
    /// Aggregation policy object (selection + merge).
    aggregator: Box<dyn Aggregator>,
    net: NetSim,
    rng: Xoshiro256ss,
    publisher: usize,
    total_len: usize,
    /// Per-row attention-mass accumulator (only for relevance policies).
    relevance: Option<RelevanceTracker>,
    /// Worker pool for the per-participant loops (`workers > 1`).
    pool: Option<Arc<Pool>>,
    /// Wire deployment: one transport-backed proxy per participant.  When
    /// set, the session is node-resident — every block forward pass and
    /// the decode run at the node hosts, and each round is a set of
    /// protocol-message turns.  `None` is the fully in-process session.
    remotes: Option<Vec<RemoteParticipant>>,
    /// Wire mode: per-node link state (the probation → demotion machine).
    /// A node not `Alive` is folded into every remaining round exactly
    /// like a permanent deadline miss until (and unless) it rejoins.
    /// Empty in-process.
    wire_state: Vec<WireState>,
    /// Churn recovery: dials replacement transports for probation nodes.
    /// `None` (always, unless [`TransportDriver::with_reconnector`] was
    /// called) leaves `cfg.rejoin` inert.
    reconnector: Option<Reconnector<'a>>,
    /// True only while wire prefill runs — the rejoin window.  A
    /// transport failure outside it (decode phase) demotes immediately:
    /// nothing would ever retry a probation entered after the last
    /// round boundary.
    rejoin_window: bool,
}

impl<'a> SessionDriver<'a> {
    pub fn new(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
    ) -> Result<Self> {
        let n = partition.n_participants();
        anyhow::ensure!(net.n_participants() == n, "net sim participant count");
        anyhow::ensure!(cfg.schedule.n_participants() == n, "schedule participant count");
        anyhow::ensure!(
            cfg.schedule.n_blocks() == engine.manifest.model.n_layers,
            "schedule block count"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.dropout_prob),
            "dropout_prob must be in [0, 1], got {}",
            cfg.dropout_prob
        );
        if let Some(d) = cfg.round_deadline_ms {
            // NaN fails the comparison; +inf is allowed (it still
            // schedules arrivals, unlike None which skips scheduling).
            anyhow::ensure!(
                d >= 0.0,
                "round_deadline_ms must be >= 0, got {d}"
            );
        }
        if let Some(hb) = cfg.heartbeat_ms {
            // The window bounds a real socket wait, so unlike the round
            // deadline it must be finite and strictly positive.
            anyhow::ensure!(
                hb > 0.0 && hb.is_finite(),
                "heartbeat_ms must be finite and > 0, got {hb}"
            );
        }
        let mut rng = Xoshiro256ss::new(cfg.seed ^ 0x5E55_10);
        let publisher = partition.publisher();

        // Build one node per participant: apply local sparsity, pad, embed.
        let mut nodes = Vec::with_capacity(n);
        for p in 0..n {
            let (s, e) = partition.spans[p];
            let span_ids = &partition.ids[s..e];
            // Protect the tail of the publisher (the "A:" anchor) from
            // local-sparsity dropping.
            let protect = if p == publisher { 3 } else { 0 };
            let keep = cfg.local_sparsity.select(span_ids.len(), protect, &mut rng);
            let ids: Vec<i32> = keep.iter().map(|&i| span_ids[i]).collect();
            let pos: Vec<i32> = keep.iter().map(|&i| (s + i) as i32).collect();
            let keep_caches = p == publisher || cfg.decode_all;
            nodes.push(ParticipantNode::build(engine, p, &ids, pos, keep_caches)?);
        }

        if let Some(b) = &cfg.kv_row_budgets {
            anyhow::ensure!(b.len() == n, "kv_row_budgets length {} != {n}", b.len());
        }
        let relevance = cfg.kv_policy.needs_relevance().then(|| {
            RelevanceTracker::new(&nodes.iter().map(|s| s.valid).collect::<Vec<_>>())
        });
        let pool = (cfg.workers > 1).then(|| Arc::new(Pool::new(cfg.workers)));
        let aggregator = aggregate::for_policy(cfg.kv_policy);

        // Dropout draws come from their own seeded stream: with prob 0 no
        // stream is even created, so the default path stays byte-identical
        // to the pre-dropout driver.
        let schedule = if cfg.dropout_prob > 0.0 {
            let mut drng = Xoshiro256ss::new(cfg.seed ^ 0xD80F_F00D);
            cfg.schedule.with_dropout(cfg.dropout_prob, &mut drng)
        } else {
            cfg.schedule.clone()
        };

        Ok(Self {
            engine,
            cfg,
            nodes,
            schedule,
            aggregator,
            net,
            rng,
            publisher,
            total_len: partition.len(),
            relevance,
            pool,
            remotes: None,
            wire_state: Vec::new(),
            reconnector: None,
            rejoin_window: false,
        })
    }

    /// A node-resident wire deployment of the session: one [`Transport`]
    /// per participant, each leading to a node host (see
    /// [`transport::NodeHost`]) that owns that participant's *entire*
    /// state — engine, hidden states, decode caches.  Runs the
    /// hidden-state-free `Join` handshake with every host (token ids and
    /// positions only; the host re-embeds locally) and validates that
    /// each host rebuilt the same shard against the same model geometry.
    ///
    /// [`transport::NodeHost`]: crate::fedattn::transport::NodeHost
    pub fn new_with_remotes(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
        transports: Vec<Box<dyn Transport>>,
    ) -> Result<Self> {
        anyhow::ensure!(
            !cfg.record_hidden,
            "record_hidden is unsupported over the wire: hidden states never leave their node"
        );
        let mut driver = Self::new(engine, partition, cfg, net)?;
        let n = driver.nodes.len();
        anyhow::ensure!(
            transports.len() == n,
            "got {} transports for {n} participants",
            transports.len()
        );
        let md = &engine.manifest.model;
        let mut remotes = Vec::with_capacity(n);
        // Fan every Join out before collecting any ack: the hosts embed
        // their shards concurrently.
        for (p, t) in transports.into_iter().enumerate() {
            let keep = p == driver.publisher || driver.cfg.decode_all;
            let node = &mut driver.nodes[p];
            // The remote host owns the authoritative caches; the local
            // mirror keeps only the planning metadata.
            node.caches = Vec::new();
            let mut rp = RemoteParticipant::new(p, node.pos.clone(), node.valid, keep, t);
            rp.set_delta_frames(driver.cfg.delta_frames);
            rp.set_kv_precision(driver.cfg.kv_precision);
            rp.join_send(&node.ids, driver.cfg.round_deadline_ms)?;
            remotes.push(rp);
        }
        for rp in remotes.iter_mut() {
            rp.join_recv(md.n_layers, md.n_kv_heads, md.head_dim)?;
        }
        driver.remotes = Some(remotes);
        driver.wire_state = vec![WireState::Alive; n];
        Ok(driver)
    }

    /// Attach a reconnector for churn recovery (wire mode): with
    /// `cfg.rejoin` set, a node whose transport fails goes on probation
    /// and this callback is asked for a replacement transport at each
    /// following round boundary.
    pub fn set_reconnector(&mut self, reconnector: Reconnector<'a>) {
        self.reconnector = Some(reconnector);
    }

    /// Is wire node `p` currently a full participant?
    fn wire_ok(&self, p: usize) -> bool {
        self.wire_state[p] == WireState::Alive
    }

    /// The effective attendance schedule (after dropout masking).
    pub fn effective_schedule(&self) -> &SyncSchedule {
        &self.schedule
    }

    /// Does participant `p` keep decode caches (locally or at its remote
    /// host)?
    fn keeps_caches_for(&self, p: usize) -> bool {
        match &self.remotes {
            Some(r) => r[p].keeps_caches(),
            None => self.nodes[p].keeps_caches(),
        }
    }

    /// Take wire node `p` out of the session: its transport failed, so
    /// it is excluded from every remaining round exactly like a deadline
    /// miss instead of killing the session.  With churn recovery on and
    /// the rejoin window open this is stage one — *probation*, retried
    /// at the next round boundary; otherwise (knob off, no reconnector,
    /// or decode phase) the node is demoted for good.  Either way the
    /// event lands in the session's [`NetReport`] — churn is part of the
    /// structured output, not just a log line.
    fn demote(&mut self, p: usize, why: &anyhow::Error) {
        if self.wire_state[p] != WireState::Alive {
            return;
        }
        let recoverable =
            self.cfg.rejoin && self.reconnector.is_some() && self.rejoin_window;
        if recoverable {
            self.wire_state[p] = WireState::Probation { attempts: 0 };
            log::warn!("node {p} lost its transport, on probation: {why:#}");
        } else {
            self.wire_state[p] = WireState::Demoted;
            self.net.record_demotion();
            log::warn!("node {p} demoted for the rest of the session: {why:#}");
        }
    }

    /// One round-boundary heartbeat pass: ping every `Alive` node with a
    /// fresh sequence number, retrying a missed beat back-to-back up to
    /// `heartbeat_max_missed` times before handing the node to
    /// [`SessionDriver::demote`] (probation when rejoin is armed).
    /// Heartbeats are control-plane traffic: not billed, invisible to
    /// byte accounting, and a session where every beat answers is
    /// byte-identical to one that never pinged.
    fn heartbeat_round(
        &mut self,
        remotes: &mut [RemoteParticipant],
        window_ms: f64,
        seq: &mut u32,
    ) {
        let window = std::time::Duration::from_secs_f64(window_ms / 1e3);
        // After the beat the transport must wait like any protocol turn
        // again (the dial-site grace default applies; a custom grace only
        // shifts this bound, never the heartbeat's own window).
        let restore = read_timeout_for_deadline(self.cfg.round_deadline_ms);
        for p in 0..self.wire_state.len() {
            if self.wire_state[p] != WireState::Alive {
                continue;
            }
            let mut last_err: Option<anyhow::Error> = None;
            for _ in 0..self.cfg.heartbeat_max_missed.max(1) {
                *seq = seq.wrapping_add(1);
                match remotes[p].ping(*seq, window, restore) {
                    Ok(()) => {
                        last_err = None;
                        break;
                    }
                    Err(e) => last_err = Some(e),
                }
            }
            if let Some(e) = last_err {
                let why = e.context(format!(
                    "node {p} missed {} consecutive heartbeats ({window_ms} ms window)",
                    self.cfg.heartbeat_max_missed.max(1)
                ));
                self.demote(p, &why);
            }
        }
    }

    /// Close the rejoin window: any node still on probation is demoted
    /// for good (nothing will retry it once the round loop is over).
    fn finalize_probation(&mut self) {
        self.rejoin_window = false;
        for p in 0..self.wire_state.len() {
            if let WireState::Probation { .. } = self.wire_state[p] {
                self.wire_state[p] = WireState::Demoted;
                self.net.record_demotion();
                log::warn!("node {p} still on probation at end of prefill: demoted");
            }
        }
    }

    /// One round-boundary rejoin pass: for every probation node, dial a
    /// replacement transport and run the `Rejoin` handshake, shipping one
    /// retained `Resync` frame per round the node attended pre-demotion
    /// so it replays itself to `resume_block`.  Success readmits the node
    /// (bit-identical to having merely missed the demoted rounds via
    /// deadline misses); failure consumes one probation retry.
    fn try_rejoins(
        &mut self,
        remotes: &mut [RemoteParticipant],
        resync_log: &[ResyncRound],
        resume_block: usize,
    ) {
        for p in 0..self.wire_state.len() {
            let WireState::Probation { attempts } = self.wire_state[p] else {
                continue;
            };
            if self.reconnector.is_none() {
                // Probation requires a reconnector to ever resolve; a
                // node stranded here (e.g. a driver constructed without
                // `with_reconnector`) is demoted like a deadline miss
                // instead of panicking mid-session.
                demote_stranded_probation(p, &mut self.wire_state, &mut self.net);
                continue;
            }
            let resync: Vec<(usize, usize, Vec<u8>)> = resync_log
                .iter()
                .filter(|r| r.attended[p])
                .map(|r| (r.block, r.epoch, r.frame.clone()))
                .collect();
            let resync_bytes: u64 = resync.iter().map(|(_, _, f)| f.len() as u64).sum();
            let keep = remotes[p].keeps_caches();
            let md = self.engine.manifest.model.clone();
            let attempt = (|| -> Result<RemoteParticipant> {
                let reconnect = self
                    .reconnector
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("probation without a reconnector"))?;
                let t = reconnect(p)?;
                let node = &self.nodes[p];
                let mut rp = RemoteParticipant::new(p, node.pos.clone(), node.valid, keep, t);
                rp.set_delta_frames(self.cfg.delta_frames);
                rp.set_kv_precision(self.cfg.kv_precision);
                rp.rejoin(
                    &node.ids,
                    self.cfg.round_deadline_ms,
                    resume_block,
                    &resync,
                    md.n_layers,
                    md.n_kv_heads,
                    md.head_dim,
                )?;
                Ok(rp)
            })();
            match attempt {
                Ok(rp) => {
                    remotes[p] = rp;
                    self.wire_state[p] = WireState::Alive;
                    self.net.record_rejoin(resync_bytes);
                    log::info!(
                        "node {p} rejoined at block {resume_block} \
                         ({} resync rounds, {resync_bytes} B)",
                        resync.len()
                    );
                }
                Err(e) => {
                    let attempts = attempts + 1;
                    self.net.record_retry();
                    if attempts >= self.cfg.rejoin_max_attempts.max(1) {
                        self.wire_state[p] = WireState::Demoted;
                        self.net.record_demotion();
                        log::warn!(
                            "node {p} exhausted {attempts} rejoin attempts, demoted: {e:#}"
                        );
                    } else {
                        self.wire_state[p] = WireState::Probation { attempts };
                        log::warn!("node {p} rejoin attempt {attempts} failed: {e:#}");
                    }
                }
            }
        }
    }

    /// Run the federated prefill (Alg. 1 lines 2–14).
    pub fn prefill(&mut self) -> Result<PrefillOutput> {
        if self.remotes.is_some() {
            self.prefill_wire()
        } else {
            self.prefill_local()
        }
    }

    /// In-process prefill: the driver runs every node's forward pass on
    /// its own engine (pool-parallel).
    fn prefill_local(&mut self) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let md = self.engine.manifest.model.clone();
        let n = self.nodes.len();
        let n_layers = md.n_layers;
        // Wire bytes of one K+V row pair at the session precision — the
        // unit all planning and billing runs in (== `GlobalKv::row_bytes`
        // at the default `f32`, so budgets, arrivals, and reports are
        // byte-identical to the pre-quantization driver there).
        let row_bytes_usize = self.cfg.kv_precision.wire_row_bytes(md.n_kv_heads, md.head_dim);

        // Budgeted policies: resolve per-participant row budgets once per
        // session.  ByteBudget's total is split across heterogeneous links
        // proportionally to bandwidth unless the coordinator already did.
        let budgets: Option<Vec<usize>> =
            match (&self.cfg.kv_row_budgets, self.cfg.kv_policy) {
                (Some(b), _) => Some(b.clone()),
                (None, KvExchangePolicy::ByteBudget { bytes_per_round }) => {
                    Some(crate::net::allocate_row_budgets(
                        self.net.links(),
                        bytes_per_round / row_bytes_usize.max(1),
                    ))
                }
                _ => None,
            };

        for m in 0..n_layers {
            let attend = self.schedule.attend[m].clone();

            // Round planning.  Row selection runs first — it depends only
            // on relevance accumulated at *earlier* sync rounds, never on
            // this block's compute, and its RNG draws happen in
            // participant order exactly as before, so the session stream
            // is unchanged.  With a deadline, the planned payload sizes
            // (a pure function of the selected rows) are handed to the
            // network simulator to *schedule* each uplink's arrival; the
            // stragglers whose contribution lands past the deadline are
            // demoted to the local path before any compute is placed.
            let plan = if attend.iter().any(|&b| b) {
                let mut tx_flags: Vec<Vec<bool>> = Vec::with_capacity(n);
                for p in 0..n {
                    let ctx = TxContext {
                        who: p,
                        publisher: self.publisher,
                        len: self.nodes[p].valid,
                        row_bytes: row_bytes_usize,
                        relevance: self.relevance.as_ref().map(|t| t.scores(p)),
                        row_budget: budgets.as_ref().map(|b| b[p]),
                    };
                    tx_flags.push(self.aggregator.select(&ctx, &mut self.rng));
                }
                let payloads: Vec<u64> = tx_flags
                    .iter()
                    .map(|tx| {
                        tx.iter().filter(|&&b| b).count() as u64 * row_bytes_usize as u64
                    })
                    .collect();
                let (mut on_time, arrivals) = match self.cfg.round_deadline_ms {
                    Some(d) => {
                        let arr = self.net.uplink_arrivals(&payloads);
                        (arr.iter().map(|&a| a <= d).collect::<Vec<bool>>(), Some(arr))
                    }
                    // No deadline: nobody is late and no arrival is ever
                    // drawn (byte-identical to the pre-deadline driver).
                    None => (vec![true; n], None),
                };
                // Forced lateness (test fixture, RNG-free): folded in
                // after real arrivals, exactly like a deadline miss.
                if let Some(ov) = &self.cfg.late_overrides {
                    for &(blk, p) in ov {
                        if blk == m && p < n {
                            on_time[p] = false;
                        }
                    }
                }
                let attend_eff: Vec<bool> =
                    attend.iter().zip(&on_time).map(|(&a, &o)| a && o).collect();
                attend_eff
                    .iter()
                    .any(|&b| b)
                    .then_some((tx_flags, on_time, arrivals, attend_eff))
            } else {
                None
            };

            let Some((tx_flags, on_time, arrivals, attend)) = plan else {
                // Phase I only — either nobody is scheduled at this block
                // or every scheduled attendee missed the deadline.  Both
                // run a fused local block for everyone (pool-parallel;
                // ordered collection keeps determinism) with no exchange
                // and no round recorded: deadline starvation degrades
                // exactly like a fully-dropped round.
                let inputs: Vec<_> = self
                    .nodes
                    .iter()
                    .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                    .collect();
                let engine = self.engine.clone();
                let outs = run_parallel(self.pool.as_ref(), n, move |p| {
                    let (x, pos, lmask) = &inputs[p];
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map_err(|e| format!("{e:#}"))
                })?;
                for (p, (xo, k, v)) in outs.into_iter().enumerate() {
                    self.nodes[p].set_hidden(xo);
                    if self.keeps_caches_for(p) {
                        self.nodes[p].absorb_local(m, &k, &v)?;
                    }
                }
                continue;
            };

            // Sync block: everyone produces (q,)k,v; attendees do global
            // attention over the aggregated KV.  Phase 1 is pool-parallel.
            let inputs: Vec<_> = self
                .nodes
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let engine = self.engine.clone();
            let phase1 = run_parallel(self.pool.as_ref(), n, move |p| {
                let (x, pos, lmask) = &inputs[p];
                if attend_in[p] {
                    engine
                        .qkv_project(m, x.as_ref(), pos.as_slice())
                        .map(|(q, k, v)| (Some(q), k, v, None))
                } else {
                    // Non-attendee: plain local block; its fresh K/V are
                    // what it would transmit to attendees.
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map(|(xo, k, v)| (None, k, v, Some(xo)))
                }
                .map_err(|e| format!("{e:#}"))
            })?;
            let mut qs: Vec<Option<HostTensor>> = Vec::with_capacity(n);
            let mut ks: Vec<HostTensor> = Vec::with_capacity(n);
            let mut vs: Vec<HostTensor> = Vec::with_capacity(n);
            for (p, (q, k, v, xo)) in phase1.into_iter().enumerate() {
                qs.push(q);
                ks.push(k);
                vs.push(v);
                if let Some(xo) = xo {
                    self.nodes[p].set_hidden(xo);
                }
            }

            // Quantize the value plane once per round.  The transmitted
            // rows of every on-time participant are exactly what the
            // protocol ships, so at reduced precision they are
            // re-quantized into *wire copies*: contributions, the
            // aggregated round, and attendee caches all see the values a
            // wire decode yields — identical to a deployed session.  The
            // raw tensors stay untouched for the local path (late nodes
            // and a non-attendee's own caches hold full-precision rows on
            // a real node too, since those rows never crossed a wire).
            let wire_kv: Option<(Vec<HostTensor>, Vec<HostTensor>)> =
                (self.cfg.kv_precision != KvPrecision::F32).then(|| {
                    let mut wks = ks.clone();
                    let mut wvs = vs.clone();
                    for p in 0..n {
                        if !on_time[p] {
                            continue;
                        }
                        for (i, &t) in tx_flags[p].iter().enumerate() {
                            if !t {
                                continue;
                            }
                            requantize_row(wks[p].row_mut(i), self.cfg.kv_precision);
                            requantize_row(wvs[p].row_mut(i), self.cfg.kv_precision);
                        }
                    }
                    (wks, wvs)
                });
            let (wks, wvs): (&[HostTensor], &[HostTensor]) = match &wire_kv {
                Some((a, b)) => (a, b),
                None => (&ks, &vs),
            };

            // Round messages: each on-time node packages its uplink
            // KvContribution.  A late node contributes nothing this round
            // (its rows are excluded from aggregation, the FL-straggler
            // partial-aggregation analogue).  The message carries the
            // real row payload so accounting is measured, not estimated.
            // Node contributions are pure and the `session_golden`
            // fixtures pin this sequential loop byte-for-byte.
            let contributions: Vec<Option<KvContribution>> = {
                let mut out = Vec::with_capacity(n);
                for p in 0..n {
                    if !on_time[p] {
                        out.push(None);
                        continue;
                    }
                    let scores = self.relevance.as_ref().map(|t| t.scores(p));
                    out.push(Some(
                        self.nodes[p]
                            .contribute(m, &wks[p], &wvs[p], &tx_flags[p], scores)?
                            .with_precision(self.cfg.kv_precision),
                    ));
                }
                out
            };

            // Aggregate the on-time contributions into the global KV
            // (Eq. 20); a late participant's rows are excluded entirely
            // (valid = 0 keeps the owner numbering stable).
            let rows_total: usize = (0..n)
                .map(|p| if on_time[p] { self.nodes[p].valid } else { 0 })
                .sum();
            let g_pad = self.engine.manifest.pick_g(rows_total)?;
            let parts_refs: Vec<PartRows<'_>> = (0..n)
                .map(|p| {
                    (
                        &wks[p],
                        &wvs[p],
                        self.nodes[p].pos.as_slice(),
                        if on_time[p] { self.nodes[p].valid } else { 0 },
                        tx_flags[p].as_slice(),
                    )
                })
                .collect();
            let gkv = self.aggregator.aggregate(
                &parts_refs,
                g_pad,
                self.relevance.as_ref().map(|t| t.all_scores()),
            )?;
            let (kv_pos, kv_owner, kv_tx) = gkv.meta_columns();

            // Communication accounting + simulated transfer time: the
            // bytes on the wire are the encoded contribution payloads —
            // the protocol messages are the single source of truth.  Late
            // contributions never arrived, so they bill nothing: round
            // bytes are exactly the sum of on-time payloads.
            let tx_bytes: Vec<u64> = contributions
                .iter()
                .map(|c| c.as_ref().map_or(0, |c| c.payload_bytes()))
                .collect();
            #[cfg(debug_assertions)]
            {
                // The packed rows and the wire messages must tell the same
                // story, uplink and downlink (also pinned, with real
                // payloads, by tests/protocol_messages.rs).
                let row_bytes = row_bytes_usize as u64;
                let from_pack: Vec<u64> = gkv
                    .tx_rows_by_owner(n)
                    .iter()
                    .map(|&r| r as u64 * row_bytes)
                    .collect();
                debug_assert_eq!(tx_bytes, from_pack, "uplink bytes drifted from pack");
                let frame = crate::fedattn::protocol::GlobalKvFrame::from_global(m, &gkv)
                    .with_precision(self.cfg.kv_precision);
                let total: u64 = tx_bytes.iter().sum();
                for p in 0..n {
                    debug_assert_eq!(
                        frame.payload_bytes_for(p),
                        total - tx_bytes[p],
                        "downlink bytes drifted from frame"
                    );
                }
                debug_assert_eq!(
                    frame.full_payload_bytes(),
                    gkv.rows() as u64 * row_bytes_usize as u64,
                    "full-frame bytes drifted from packed rows"
                );
            }
            // Downlink billing follows the frames actually shipped: with
            // delta frames (default) each attendee is billed the
            // transmitted rows of its peers (`total - own_tx` — the
            // accounting the protocol has always used, so the default is
            // byte-identical to the pre-delta driver); with full frames
            // every attendee is billed every packed row, the pre-delta
            // wire cost kept as the measurable baseline.
            let rx_full: Option<Vec<u64>> = (!self.cfg.delta_frames)
                .then(|| vec![gkv.rows() as u64 * row_bytes_usize as u64; n]);
            match (&arrivals, &rx_full) {
                // Deadline path: reuse the pre-drawn uplink times so the
                // round is billed against the very arrivals that decided
                // who made the cut.
                (Some(arr), None) => self.net.exchange_round_scheduled(&tx_bytes, &attend, arr),
                (None, None) => self.net.exchange_round(&tx_bytes, &attend),
                (Some(arr), Some(rx)) => {
                    self.net.exchange_round_scheduled_with_downlink(&tx_bytes, &attend, arr, rx)
                }
                (None, Some(rx)) => self.net.exchange_round_with_downlink(&tx_bytes, &attend, rx),
            };

            // Upload the packed global KV to the device ONCE per sync
            // round; every attendee's attention shares the handles (the
            // buffers are immutable, so read-only sharing holds by
            // construction).
            let gk_dev = self.engine.upload(&gkv.k)?;
            let gv_dev = self.engine.upload(&gkv.v)?;

            // Global attention + FFN for attendees (Eq. 21 + 19),
            // pool-parallel.  When a relevance policy is active, each
            // attendee also computes the column marginals of its attention
            // (row-sum of the attention weights) inside its task; the
            // accumulation below stays sequential in participant order so
            // the result is bit-identical to a sequential session.
            let gkv = Arc::new(gkv);
            let qs = Arc::new(qs);
            let kv_meta = Arc::new((kv_pos, kv_owner, kv_tx));
            let pinputs: Vec<_> = self
                .nodes
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), st.valid))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let track_mass = self.relevance.is_some();
            let engine = self.engine.clone();
            let rows = gkv.rows();
            let gkv_in = Arc::clone(&gkv);
            type AttnOut = Option<(HostTensor, Option<Vec<f64>>)>;
            let outs: Vec<AttnOut> = run_parallel(self.pool.as_ref(), n, move |p| {
                if !attend_in[p] {
                    return Ok(None);
                }
                let (x, pos_pad, valid) = &pinputs[p];
                let q = qs[p].as_ref().ok_or("missing q for attendee")?;
                let (kv_pos, kv_owner, kv_tx) = &*kv_meta;
                let mask = global_mask(
                    pos_pad.as_slice(),
                    *valid,
                    g_pad,
                    kv_pos,
                    kv_owner,
                    kv_tx,
                    rows,
                    p,
                );
                let mass = track_mass
                    .then(|| relevance::attention_mass(q, &gkv_in.k, &mask, *valid, rows));
                let xo = engine
                    .attn_ffn_dev(m, x.as_ref(), q, &gk_dev, &gv_dev, &mask)
                    .map_err(|e| format!("{e:#}"))?;
                Ok(Some((xo, mass)))
            })?;
            let mut round_mass: Option<Vec<f64>> =
                self.relevance.as_ref().map(|_| vec![0.0; gkv.rows()]);
            for (p, out) in outs.into_iter().enumerate() {
                let Some((xo, mass)) = out else { continue };
                if let (Some(acc), Some(mass)) = (round_mass.as_mut(), mass) {
                    for (a, x) in acc.iter_mut().zip(&mass) {
                        *a += x;
                    }
                }
                self.nodes[p].set_hidden(xo);
            }
            if let (Some(tr), Some(acc)) = (self.relevance.as_mut(), round_mass) {
                tr.observe(&gkv.meta, &acc);
            }

            // Decode caches for this block (paper §IV-C): nodes that
            // (effectively) attended absorb the aggregated frame
            // (restricted to what they could see); others — including
            // deadline stragglers — absorb their own local KV.
            for p in 0..n {
                if !self.keeps_caches_for(p) {
                    continue;
                }
                if attend[p] {
                    self.nodes[p].absorb_frame(m, &gkv)?;
                } else {
                    self.nodes[p].absorb_local(m, &ks[p], &vs[p])?;
                }
            }
        }

        let hidden = self.collect_hidden();
        Ok(PrefillOutput {
            hidden,
            positions: self.nodes.iter().map(|s| s.pos.clone()).collect(),
            net: self.net.report().clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Wire prefill: take the proxies out of `self` for the round loop
    /// and put them back whatever happens, so a failed session can still
    /// shut the surviving hosts down.
    fn prefill_wire(&mut self) -> Result<PrefillOutput> {
        let mut remotes = self.remotes.take().expect("wire prefill without remotes");
        let out = self.wire_rounds(&mut remotes);
        self.remotes = Some(remotes);
        out
    }

    /// Node-resident prefill: the same planning, aggregation and billing
    /// as [`SessionDriver::prefill_local`] — identical RNG draws in
    /// identical order — but every block forward pass is a message turn
    /// executed at the node hosts.  The driver never touches hidden
    /// states; it sees only the transmitted KV rows, scattered into
    /// zeroed per-participant tensors for aggregation (an untransmitted
    /// row's zeros are invisible: masked for every other attendee, and
    /// the owner restores its own rows node-side from the fresh KV it
    /// kept).  Any transport failure demotes that node — folded into the
    /// next plan as a deadline miss — instead of killing the round.
    fn wire_rounds(&mut self, remotes: &mut [RemoteParticipant]) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let md = self.engine.manifest.model.clone();
        let n = self.nodes.len();
        let n_layers = md.n_layers;
        // Wire bytes per K+V row pair at the session precision (matches
        // prefill_local and the coordinator's ByteBudget divisor).
        let row_bytes_usize = self.cfg.kv_precision.wire_row_bytes(md.n_kv_heads, md.head_dim);
        let row_len = md.n_kv_heads * md.head_dim;
        let track_mass = self.relevance.is_some();

        let budgets: Option<Vec<usize>> =
            match (&self.cfg.kv_row_budgets, self.cfg.kv_policy) {
                (Some(b), _) => Some(b.clone()),
                (None, KvExchangePolicy::ByteBudget { bytes_per_round }) => {
                    Some(crate::net::allocate_row_budgets(
                        self.net.links(),
                        bytes_per_round / row_bytes_usize.max(1),
                    ))
                }
                _ => None,
            };

        // Executed-sync-round ordinal: stamped on sync turns and delta
        // downlink frames so a node can tie a delta's retain-list to the
        // fresh-KV generation it references.
        let mut epoch = 0usize;
        // Churn recovery: while the rejoin window is open, every executed
        // sync round's aggregated frame is retained (encoded once) so a
        // probation node can replay the rounds it attended.  Off — or
        // with no reconnector — nothing is retained and demotion stays
        // single-stage, byte-identical to the pre-rejoin driver.
        let recovery = self.cfg.rejoin && self.reconnector.is_some();
        self.rejoin_window = recovery;
        let mut resync_log: Vec<ResyncRound> = Vec::new();
        // Heartbeat sequence counter: one stream per session, so a
        // straggler pong can never match a later beat.
        let mut hb_seq = 0u32;
        for m in 0..n_layers {
            // Round boundary: readmit probation nodes before this block's
            // planning, so a rejoined node is a full participant from
            // block `m` on (replayed up to exactly here).
            if recovery {
                self.try_rejoins(remotes, &resync_log, m);
            }
            // Liveness heartbeats: probe every Alive node before this
            // block's turns, so a wedged host fails fast here (and feeds
            // the same probation/demotion machinery as any transport
            // fault) instead of stalling a protocol turn until the
            // round-deadline read timeout.
            if let Some(hb) = self.cfg.heartbeat_ms {
                self.heartbeat_round(remotes, hb, &mut hb_seq);
            }
            let attend = self.schedule.attend[m].clone();

            // Identical planning to the in-process driver (same RNG draws
            // in the same order, for every participant — including
            // demoted ones, so the session stream never forks).  A
            // demoted node is then folded in exactly like a deadline
            // miss: not billed, not aggregated, not attending.
            let plan = if attend.iter().any(|&b| b) {
                let mut tx_flags: Vec<Vec<bool>> = Vec::with_capacity(n);
                for p in 0..n {
                    let ctx = TxContext {
                        who: p,
                        publisher: self.publisher,
                        len: self.nodes[p].valid,
                        row_bytes: row_bytes_usize,
                        relevance: self.relevance.as_ref().map(|t| t.scores(p)),
                        row_budget: budgets.as_ref().map(|b| b[p]),
                    };
                    tx_flags.push(self.aggregator.select(&ctx, &mut self.rng));
                }
                let payloads: Vec<u64> = tx_flags
                    .iter()
                    .map(|tx| {
                        tx.iter().filter(|&&b| b).count() as u64 * row_bytes_usize as u64
                    })
                    .collect();
                let (mut on_time, arrivals) = match self.cfg.round_deadline_ms {
                    Some(d) => {
                        let arr = self.net.uplink_arrivals(&payloads);
                        (arr.iter().map(|&a| a <= d).collect::<Vec<bool>>(), Some(arr))
                    }
                    None => (vec![true; n], None),
                };
                // Forced lateness (test fixture, RNG-free): folded in
                // after real arrivals, exactly like a deadline miss.
                if let Some(ov) = &self.cfg.late_overrides {
                    for &(blk, p) in ov {
                        if blk == m && p < n {
                            on_time[p] = false;
                        }
                    }
                }
                let on_time: Vec<bool> = (0..n)
                    .map(|p| on_time[p] && self.wire_ok(p))
                    .collect();
                let attend_eff: Vec<bool> =
                    attend.iter().zip(&on_time).map(|(&a, &o)| a && o).collect();
                attend_eff
                    .iter()
                    .any(|&b| b)
                    .then_some((tx_flags, on_time, arrivals, attend_eff))
            } else {
                None
            };

            let Some((tx_flags, mut on_time, arrivals, mut attend_eff)) = plan else {
                // No exchange at this block (nobody scheduled, everyone
                // late, or all scheduled attendees demoted): every
                // surviving node runs the local path at home.
                for p in 0..n {
                    if !self.wire_ok(p) {
                        continue;
                    }
                    if let Err(e) = remotes[p].advance_local(m) {
                        self.demote(p, &e);
                    }
                }
                continue;
            };

            let round_epoch = epoch;
            epoch += 1;

            // Fan this round's block turns out to every surviving node
            // before reading any reply: the nodes compute concurrently,
            // so the wire round costs the slowest node rather than the
            // sum.  On-time nodes get the sync turn (attendee or
            // contribute-only); late nodes run the local path.
            for p in 0..n {
                if !self.wire_ok(p) {
                    continue;
                }
                remotes[p].begin_round(round_epoch);
                let sent = if on_time[p] {
                    let scores: Option<Vec<f32>> = self
                        .relevance
                        .as_ref()
                        .map(|t| t.scores(p).iter().map(|&s| s as f32).collect());
                    remotes[p].advance_sync(
                        m,
                        attend_eff[p],
                        attend_eff[p] && track_mass,
                        &tx_flags[p],
                        scores,
                    )
                } else {
                    remotes[p].advance_local(m)
                };
                if let Err(e) = sent {
                    self.demote(p, &e);
                    on_time[p] = false;
                    attend_eff[p] = false;
                }
            }

            // Collect the uplink contributions by participant index
            // (never arrival order), so aggregation — and the session —
            // stays deterministic.
            let mut contributions: Vec<Option<KvContribution>> = Vec::with_capacity(n);
            for p in 0..n {
                if !(self.wire_ok(p) && on_time[p]) {
                    contributions.push(None);
                    continue;
                }
                match remotes[p].contribute_recv(m) {
                    Ok(c) => contributions.push(Some(c)),
                    Err(e) => {
                        self.demote(p, &e);
                        on_time[p] = false;
                        attend_eff[p] = false;
                        contributions.push(None);
                    }
                }
            }

            // Scatter each contribution's transmitted rows into a zeroed
            // `[valid, Hkv, hd]` tensor for aggregation.  Untransmitted
            // rows stay zero on the driver — their values never crossed
            // the wire.  A malformed contribution is a protocol
            // violation: the node is demoted and its rows excluded.
            let mut ks: Vec<HostTensor> = Vec::with_capacity(n);
            let mut vs: Vec<HostTensor> = Vec::with_capacity(n);
            for p in 0..n {
                let valid = self.nodes[p].valid;
                let mut k = HostTensor::zeros(&[valid.max(1), md.n_kv_heads, md.head_dim]);
                let mut v = HostTensor::zeros(&[valid.max(1), md.n_kv_heads, md.head_dim]);
                let mut scattered = false;
                if let Some(c) = contributions[p].as_ref() {
                    let flagged: Vec<usize> = tx_flags[p]
                        .iter()
                        .enumerate()
                        .filter_map(|(i, &b)| b.then_some(i))
                        .collect();
                    let good = c.kv_heads == md.n_kv_heads
                        && c.head_dim == md.head_dim
                        && c.precision == self.cfg.kv_precision
                        && c.k.len() == flagged.len() * row_len
                        && c.v.len() == c.k.len();
                    if good {
                        for (j, &i) in flagged.iter().enumerate() {
                            k.row_mut(i)
                                .copy_from_slice(&c.k[j * row_len..(j + 1) * row_len]);
                            v.row_mut(i)
                                .copy_from_slice(&c.v[j * row_len..(j + 1) * row_len]);
                        }
                        scattered = true;
                    }
                }
                if contributions[p].is_some() && !scattered {
                    self.demote(
                        p,
                        &anyhow::anyhow!("contribution geometry does not match the plan"),
                    );
                    on_time[p] = false;
                    attend_eff[p] = false;
                    contributions[p] = None;
                }
                ks.push(k);
                vs.push(v);
            }

            // Aggregate the received contributions into the global KV;
            // late/demoted participants' rows are excluded entirely
            // (valid = 0 keeps the owner numbering stable).
            let rows_total: usize = (0..n)
                .map(|p| if on_time[p] { self.nodes[p].valid } else { 0 })
                .sum();
            let g_pad = self.engine.manifest.pick_g(rows_total)?;
            let parts_refs: Vec<PartRows<'_>> = (0..n)
                .map(|p| {
                    (
                        &ks[p],
                        &vs[p],
                        self.nodes[p].pos.as_slice(),
                        if on_time[p] { self.nodes[p].valid } else { 0 },
                        tx_flags[p].as_slice(),
                    )
                })
                .collect();
            let gkv = self.aggregator.aggregate(
                &parts_refs,
                g_pad,
                self.relevance.as_ref().map(|t| t.all_scores()),
            )?;

            // Billing: same single source of truth — the encoded
            // contribution payloads that really crossed a transport.
            let tx_bytes: Vec<u64> = contributions
                .iter()
                .map(|c| c.as_ref().map_or(0, |c| c.payload_bytes()))
                .collect();
            #[cfg(debug_assertions)]
            {
                let row_bytes = row_bytes_usize as u64;
                let from_pack: Vec<u64> = gkv
                    .tx_rows_by_owner(n)
                    .iter()
                    .map(|&r| r as u64 * row_bytes)
                    .collect();
                debug_assert_eq!(tx_bytes, from_pack, "uplink bytes drifted from pack");
            }
            let rx_full: Option<Vec<u64>> = (!self.cfg.delta_frames)
                .then(|| vec![gkv.rows() as u64 * row_bytes_usize as u64; n]);
            match (&arrivals, &rx_full) {
                (Some(arr), None) => {
                    self.net.exchange_round_scheduled(&tx_bytes, &attend_eff, arr)
                }
                (None, None) => self.net.exchange_round(&tx_bytes, &attend_eff),
                (Some(arr), Some(rx)) => self.net.exchange_round_scheduled_with_downlink(
                    &tx_bytes,
                    &attend_eff,
                    arr,
                    rx,
                ),
                (None, Some(rx)) => {
                    self.net.exchange_round_with_downlink(&tx_bytes, &attend_eff, rx)
                }
            };

            // Downlink: ship the aggregated round to every surviving
            // attendee (delta-encoded against the fresh KV it holds when
            // the knob is on); the node runs the global attention — and
            // absorbs its decode-cache rows — at home.
            for p in 0..n {
                if !(self.wire_ok(p) && attend_eff[p]) {
                    continue;
                }
                if let Err(e) = remotes[p].send_frame(m, &gkv) {
                    self.demote(p, &e);
                    attend_eff[p] = false;
                }
            }

            // Relevance feedback: collect per-row attention masses from
            // the attendees in participant order with a sequential f64
            // accumulation — the same reduction order as the in-process
            // driver, so the tracker state is bit-identical.
            if track_mass {
                let rows = gkv.rows();
                let mut acc = vec![0.0f64; rows];
                for p in 0..n {
                    if !(self.wire_ok(p) && attend_eff[p]) {
                        continue;
                    }
                    match remotes[p].recv_mass(m, rows) {
                        Ok(mass) => {
                            for (a, x) in acc.iter_mut().zip(&mass) {
                                *a += x;
                            }
                        }
                        Err(e) => self.demote(p, &e),
                    }
                }
                if let Some(tr) = self.relevance.as_mut() {
                    tr.observe(&gkv.meta, &acc);
                }
            }

            // Retain this round for rejoin resync: the full aggregated
            // frame (what `send_frame` ships, pre-delta) plus who ended
            // up attending it.  `attend_eff` is read *after* every
            // downlink/mass turn, so a node whose link died before its
            // frame landed is recorded as a non-attendee — its replay
            // runs the local path for this block, exactly like the
            // deadline-miss world.
            if recovery {
                resync_log.push(ResyncRound {
                    block: m,
                    epoch: round_epoch,
                    frame: GlobalKvFrame::from_global(m, &gkv)
                        .with_precision(self.cfg.kv_precision)
                        .encode(),
                    attended: attend_eff.clone(),
                });
            }
        }

        // The round loop is over: nothing will retry a probation node
        // again, so close the window (remaining probations harden into
        // demotions, counted in the report).
        if recovery {
            self.finalize_probation();
        }

        Ok(PrefillOutput {
            // record_hidden is rejected for wire sessions up front:
            // hidden states never leave their node.
            hidden: vec![None; n],
            positions: self.nodes.iter().map(|s| s.pos.clone()).collect(),
            net: self.net.report().clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    fn collect_hidden(&self) -> Vec<Option<HostTensor>> {
        self.nodes
            .iter()
            .map(|st| {
                if self.cfg.record_hidden {
                    let mut h = HostTensor::zeros(&[st.valid, st.x.shape()[1]]);
                    h.copy_rows_from(st.x.as_ref(), 0..st.valid, 0);
                    Some(h)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Greedy decode from participant `p`'s KV caches (requires that `p`
    /// kept caches).  Returns the decoded text and token count.  In wire
    /// mode the decode runs at `p`'s node host (which owns the caches,
    /// the final hidden state and its own engine) and the tokens stream
    /// back as `TokenBroadcast` frames.
    pub fn decode_participant(&mut self, p: usize) -> Result<(String, usize)> {
        anyhow::ensure!(self.keeps_caches_for(p), "participant {p} has no caches");
        if let Some(remotes) = self.remotes.as_mut() {
            anyhow::ensure!(
                self.wire_ok(p),
                "participant {p} was demoted (transport lost) and cannot decode"
            );
            let (total_len, max_new, dev) =
                (self.total_len, self.cfg.max_new_tokens, self.cfg.device_decode);
            return remotes[p].decode(total_len, max_new, dev);
        }
        // Fallible: a zero-valid-row shard has no final prompt token to
        // decode from (an error, not an underflow panic).
        let h_last = self.nodes[p].last_hidden()?;
        let mut caches = std::mem::take(&mut self.nodes[p].caches);
        let res = decode_from_caches(
            self.engine,
            &mut caches,
            &h_last,
            self.total_len,
            self.cfg.max_new_tokens,
            self.cfg.device_decode,
        );
        self.nodes[p].caches = caches;
        res
    }

    /// Decode the task publisher.
    pub fn decode(&mut self) -> Result<(String, usize)> {
        self.decode_participant(self.publisher)
    }

    /// Prefill + decode, returning the full report.  With `decode_all`
    /// and `workers > 1` the per-participant decodes run pool-parallel
    /// (each participant's caches are independent).
    pub fn run(mut self) -> Result<SessionReport> {
        let pre = self.prefill()?;
        let t0 = std::time::Instant::now();
        let n = self.nodes.len();
        let mut answers: Vec<Option<String>> = vec![None; n];
        let mut generated = 0usize;

        if self.remotes.is_some() {
            // Wire mode: decode sequentially through each surviving host
            // (tokens are independent of decode order, and parallel
            // decodes would only contend the transports).  A
            // non-publisher failure — node died mid-decode, or was
            // already demoted during prefill — just leaves that answer
            // absent; a publisher failure is fatal.  Either way every
            // surviving host is released before returning.
            let decoders: Vec<usize> = (0..n).filter(|&p| self.keeps_caches_for(p)).collect();
            let mut failed: Option<anyhow::Error> = None;
            for &p in &decoders {
                if !self.wire_ok(p) {
                    if p == self.publisher {
                        failed = Some(anyhow::anyhow!(
                            "publisher node {p} was demoted mid-session"
                        ));
                        break;
                    }
                    continue;
                }
                match self.decode_participant(p) {
                    Ok((text, tokens)) => {
                        if p == self.publisher {
                            generated = tokens;
                        }
                        answers[p] = Some(text);
                    }
                    Err(e) if p != self.publisher => self.demote(p, &e),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            for (p, r) in self.remotes.as_mut().unwrap().iter_mut().enumerate() {
                if self.wire_ok(p) {
                    let _ = r.shutdown();
                }
            }
            if let Some(e) = failed {
                return Err(e);
            }
        } else {
            // In-process: a zero-valid-row participant has no final token
            // to decode from — its answer is reported absent instead of
            // panicking the session (the publisher's protected tail keeps
            // it decodable in any realistic partition).
            let decoders: Vec<usize> = (0..n)
                .filter(|&p| self.keeps_caches_for(p) && self.nodes[p].valid > 0)
                .collect();
            // Move each decoding participant's caches + kick-off hidden
            // state into a slot the (shared) pool closure can take
            // exactly once.
            let slots: Vec<Mutex<Option<(Vec<BlockCache>, HostTensor)>>> = decoders
                .iter()
                .map(|&p| {
                    let caches = std::mem::take(&mut self.nodes[p].caches);
                    let h_last = self.nodes[p].last_hidden()?;
                    Ok(Mutex::new(Some((caches, h_last))))
                })
                .collect::<Result<_>>()?;
            let slots = Arc::new(slots);
            let engine = self.engine.clone();
            let (total_len, max_new, device_decode) =
                (self.total_len, self.cfg.max_new_tokens, self.cfg.device_decode);
            let slots_in = Arc::clone(&slots);
            let decoded: Vec<(String, usize)> =
                run_parallel(self.pool.as_ref(), decoders.len(), move |i| {
                    let (mut caches, h_last) = slots_in[i]
                        .lock()
                        .unwrap()
                        .take()
                        .ok_or("decode slot taken twice")?;
                    decode_from_caches(
                        &engine,
                        &mut caches,
                        &h_last,
                        total_len,
                        max_new,
                        device_decode,
                    )
                    .map_err(|e| format!("{e:#}"))
                })?;
            for (&p, (text, tokens)) in decoders.iter().zip(decoded) {
                if p == self.publisher {
                    generated = tokens;
                }
                answers[p] = Some(text);
            }
        }

        // A missing publisher answer is a failed session, not an empty
        // string masquerading as a response: every path above either
        // fills `answers[publisher]` or returns the underlying error,
        // so hitting this is a driver invariant violation (e.g. a
        // publisher shard with zero valid rows skipped by the decoder
        // filter) that must be loud.
        let answer = answers[self.publisher].clone().ok_or_else(|| {
            anyhow::anyhow!(
                "publisher participant {} produced no answer",
                self.publisher
            )
        })?;
        Ok(SessionReport {
            answer,
            generated_tokens: generated,
            answers,
            net: self.net.into_report(),
            prefill_ms: pre.wall_ms,
            decode_ms: t0.elapsed().as_secs_f64() * 1e3,
            hidden: pre.hidden,
            positions: pre.positions,
        })
    }

    /// Prefill only (error-analysis paths that do not decode).
    pub fn run_prefill_only(mut self) -> Result<PrefillOutput> {
        self.prefill()
    }

    /// Run prefill, then hand the *publisher's* decode to the caller as a
    /// resumable [`DecodeHandle`] instead of looping to completion — the
    /// serving-fabric entry point ([`DecodeStep`] protocol).  Requires the
    /// default publisher-only decode (`decode_all = false`; a fabric task
    /// wanting every participant's answer runs [`SessionDriver::run`]) and
    /// an in-process session: wire sessions decode node-resident, so
    /// there is no coordinator-side cache to step.
    pub fn into_publisher_decode(mut self) -> Result<(DecodeHandle, PrefillOutput)> {
        anyhow::ensure!(
            self.remotes.is_none(),
            "into_publisher_decode requires an in-process session (wire decode is node-resident)"
        );
        anyhow::ensure!(
            !self.cfg.decode_all,
            "into_publisher_decode decodes only the publisher (decode_all is set)"
        );
        let pre = self.prefill()?;
        let p = self.publisher;
        anyhow::ensure!(
            self.nodes[p].valid > 0,
            "publisher participant {p} has no valid rows to decode from"
        );
        let caches = std::mem::take(&mut self.nodes[p].caches);
        anyhow::ensure!(!caches.is_empty(), "publisher participant {p} kept no decode caches");
        let h_last = self.nodes[p].last_hidden()?;
        let machine = DecodeMachine::new(
            self.engine,
            &h_last,
            self.total_len,
            self.cfg.max_new_tokens,
            self.cfg.device_decode,
        )?;
        Ok((DecodeHandle { machine, caches }, pre))
    }

    /// Attach a shared worker pool (e.g. the coordinator's, reused across
    /// tasks) instead of the session-owned one `workers > 1` would spawn.
    /// Pass `workers = 1` in the config when using this to avoid creating
    /// a throwaway pool in [`SessionDriver::new`].
    pub fn with_shared_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

/// Greedy decode over one participant's per-layer caches.
///
/// When `device_decode` is set and the artifact set has a decode-tail
/// variant wide enough for the horizon, each cache is frozen on the
/// device first and every step uploads only the `[R]` tail (O(1) bytes
/// per step in the cache capacity); otherwise the host path uploads the
/// full cache per layer per step, as before.
fn decode_from_caches(
    engine: &Engine,
    caches: &mut [BlockCache],
    h_last: &HostTensor,
    total_len: usize,
    max_new_tokens: usize,
    device_decode: bool,
) -> Result<(String, usize)> {
    let ids =
        decode_ids_from_caches(engine, caches, h_last, total_len, max_new_tokens, device_decode)?;
    Ok((tokenizer::decode(&ids), ids.len()))
}

/// [`decode_from_caches`] at the token level: the raw greedy token ids,
/// before detokenization.  The wire transport's node host uses this to
/// stream each generated token back as a `TokenBroadcast` frame.
pub(crate) fn decode_ids_from_caches(
    engine: &Engine,
    caches: &mut [BlockCache],
    h_last: &HostTensor,
    total_len: usize,
    max_new_tokens: usize,
    device_decode: bool,
) -> Result<Vec<i32>> {
    let mut machine =
        DecodeMachine::new(engine, h_last, total_len, max_new_tokens, device_decode)?;
    loop {
        match machine.poll() {
            DecodeStep::Done => break,
            DecodeStep::Ready { .. } | DecodeStep::NeedsDispatch => {
                machine.dispatch(engine, caches)?;
            }
        }
    }
    Ok(machine.into_ids())
}

/// What a decode state machine wants next (serving-fabric contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeStep {
    /// A new token was produced and its decode pass is now owed; the same
    /// pass must run (via [`DecodeMachine::dispatch`] or a batched cohort
    /// step) before the next token can be produced.  The final token of a
    /// budget-exhausted decode is *not* announced this way — it needs no
    /// pass, so the machine reports [`DecodeStep::Done`] directly (read it
    /// from [`DecodeMachine::ids`]).
    Ready { token: i32 },
    /// A decode pass is owed for an already-announced token.
    NeedsDispatch,
    /// Decode finished (EOS or token budget).
    Done,
}

/// The per-session greedy decode loop of [`decode_ids_from_caches`], split
/// into a resumable state machine the serving fabric can drive: `poll` is
/// pure control flow, `dispatch` runs exactly one engine decode pass.
///
/// Driving `poll`/`dispatch` to completion issues the *identical* engine
/// call sequence as the old inline loop (kick-off logits at construction;
/// lazy cache freeze immediately before the first dispatch; one
/// embed → per-layer decode → logits chain per emitted non-final token),
/// so transcripts are byte-identical however the steps are interleaved
/// across sessions.
pub struct DecodeMachine {
    total_len: usize,
    max_new_tokens: usize,
    /// Chosen decode-tail capacity, `None` for the host (full-cache) path.
    tail_r: Option<usize>,
    frozen: bool,
    out_ids: Vec<i32>,
    /// Logits awaiting consumption by the next `poll`; `None` while a
    /// dispatch is owed.
    logits: Option<Vec<f32>>,
    /// Token whose decode pass has not run yet.
    pending: Option<i32>,
    done: bool,
}

impl DecodeMachine {
    /// Start a decode from a participant's final prompt hidden state.
    /// Runs the kick-off `logits` call (same as the old loop's first
    /// engine call); everything after is driven by `poll`/`dispatch`.
    pub fn new(
        engine: &Engine,
        h_last: &HostTensor,
        total_len: usize,
        max_new_tokens: usize,
        device_decode: bool,
    ) -> Result<Self> {
        // A step appends at most one row per layer, and the final step
        // never appends: at most max_new_tokens - 1 tail rows per decode.
        let steps = max_new_tokens.saturating_sub(1);
        let tail_r = (device_decode && steps > 0)
            .then(|| engine.manifest.pick_decode_tail(steps))
            .flatten();
        Ok(Self {
            total_len,
            max_new_tokens,
            tail_r,
            frozen: false,
            out_ids: Vec::new(),
            logits: Some(engine.logits(h_last)?),
            pending: None,
            done: false,
        })
    }

    /// Advance the control flow without touching the engine.
    pub fn poll(&mut self) -> DecodeStep {
        if self.done {
            return DecodeStep::Done;
        }
        if self.pending.is_some() {
            return DecodeStep::NeedsDispatch;
        }
        let logits = self.logits.take().expect("machine has logits when no dispatch is owed");
        let next = argmax(&logits);
        if next == tokenizer::EOS {
            self.done = true;
            return DecodeStep::Done;
        }
        self.out_ids.push(next);
        if self.out_ids.len() == self.max_new_tokens {
            // Budget exhausted: the token is recorded but needs no decode
            // pass, exactly like the old loop's `step + 1 == max` break.
            self.done = true;
            return DecodeStep::Done;
        }
        self.pending = Some(next);
        DecodeStep::Ready { token: next }
    }

    /// Run the owed decode pass for the pending token over `caches`
    /// (per-session path; a batched cohort uses [`Self::pending_token`] /
    /// [`Self::complete_dispatch`] and runs the pass itself).
    pub fn dispatch(&mut self, engine: &Engine, caches: &mut [BlockCache]) -> Result<()> {
        let next =
            self.pending.ok_or_else(|| anyhow::anyhow!("dispatch without a pending token"))?;
        // Freeze lazily, right before the first real decode pass — a
        // decode that terminates on its kick-off logits (immediate EOS)
        // uploads nothing at all, same as the host path.
        if let (Some(r), false) = (self.tail_r, self.frozen) {
            let steps = self.max_new_tokens.saturating_sub(1);
            for cache in caches.iter_mut() {
                // A previous decode may have part-filled this cache's
                // tail; when the remaining capacity can't fit this
                // horizon, drop the stale prefix so freeze_device
                // re-uploads a fresh one (current cache state, empty
                // tail).
                let len = cache.len;
                let stale = cache
                    .dev
                    .as_ref()
                    .is_some_and(|dev| len - dev.base_len + steps > dev.k_tail.shape()[0]);
                if stale {
                    cache.dev = None;
                }
                cache.freeze_device(engine, r)?;
            }
            self.frozen = true;
        }
        // One decode pass to produce logits for the following token.
        let pos = self.dispatch_pos();
        let mut x = engine.embed(&[next])?;
        for (m, cache) in caches.iter_mut().enumerate() {
            let (xo, kn, vn) = match cache.dev.as_ref() {
                Some(dev) => engine.decode_block_tail(
                    m,
                    &x,
                    pos,
                    &dev.k,
                    &dev.v,
                    &dev.mask,
                    &dev.k_tail,
                    &dev.v_tail,
                    &dev.tail_mask,
                )?,
                None => engine.decode_block(m, &x, pos, &cache.k, &cache.v, &cache.dmask)?,
            };
            x = xo;
            cache.push_rows(&kn, &vn, 1, &[true]);
        }
        self.complete_dispatch(engine.logits(&x)?);
        Ok(())
    }

    /// Token ids emitted so far (final answer once `poll` returns `Done`).
    pub fn ids(&self) -> &[i32] {
        &self.out_ids
    }

    pub fn into_ids(self) -> Vec<i32> {
        self.out_ids
    }

    /// The token whose decode pass is owed, if any.
    pub(crate) fn pending_token(&self) -> Option<i32> {
        self.pending
    }

    /// Global position of the pending token (valid while a dispatch is
    /// owed): the token at out_ids index `len - 1` sits at
    /// `total_len + len - 1`, matching the old loop's `total_len + step`.
    pub(crate) fn dispatch_pos(&self) -> i32 {
        (self.total_len + self.out_ids.len() - 1) as i32
    }

    /// Upper bound on decode passes still owed (including the pending
    /// one) — the tail capacity a batched cohort must reserve.
    pub(crate) fn remaining_dispatches(&self) -> usize {
        self.max_new_tokens.saturating_sub(self.out_ids.len())
    }

    /// Finish an externally-executed decode pass (batched cohort step):
    /// clear the pending token and install the logits it produced.
    pub(crate) fn complete_dispatch(&mut self, logits: Vec<f32>) {
        debug_assert!(self.pending.is_some(), "complete_dispatch without a pending token");
        self.pending = None;
        self.logits = Some(logits);
    }

    #[cfg(test)]
    fn for_test(kickoff_logits: Vec<f32>, max_new_tokens: usize) -> Self {
        Self {
            total_len: 10,
            max_new_tokens,
            tail_r: None,
            frozen: false,
            out_ids: Vec::new(),
            logits: Some(kickoff_logits),
            pending: None,
            done: false,
        }
    }
}

/// A publisher decode detached from its [`SessionDriver`]: the state
/// machine plus the caches it decodes over, ready for the serving fabric
/// to drive (created by [`SessionDriver::into_publisher_decode`]).
pub struct DecodeHandle {
    machine: DecodeMachine,
    caches: Vec<BlockCache>,
}

impl DecodeHandle {
    pub fn poll(&mut self) -> DecodeStep {
        self.machine.poll()
    }

    /// Run the owed decode pass on the session's own caches.
    pub fn dispatch(&mut self, engine: &Engine) -> Result<()> {
        let Self { machine, caches } = self;
        machine.dispatch(engine, caches)
    }

    pub fn ids(&self) -> &[i32] {
        self.machine.ids()
    }

    /// Detokenized answer for the tokens emitted so far.
    pub fn text(&self) -> String {
        tokenizer::decode(self.machine.ids())
    }

    /// Machine + caches, for batched cohort steps that run the decode
    /// pass themselves.
    pub(crate) fn parts_mut(&mut self) -> (&mut DecodeMachine, &mut [BlockCache]) {
        (&mut self.machine, &mut self.caches)
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    /// Logits vector whose argmax is `tok`.
    fn logits_for(tok: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; 8];
        l[tok as usize] = 1.0;
        l
    }

    #[test]
    fn decode_machine_done_on_kickoff_eos() {
        // Immediate EOS: no token, no dispatch ever owed.
        let mut m = DecodeMachine::for_test(logits_for(tokenizer::EOS), 4);
        assert_eq!(m.poll(), DecodeStep::Done);
        assert_eq!(m.poll(), DecodeStep::Done);
        assert!(m.ids().is_empty());
    }

    #[test]
    fn decode_machine_budget_of_one_skips_dispatch() {
        // A 1-token budget records the token but owes no decode pass —
        // the machine goes straight to Done (matching the old loop's
        // `step + 1 == max` break before any engine call).
        let mut m = DecodeMachine::for_test(logits_for(5), 1);
        assert_eq!(m.poll(), DecodeStep::Done);
        assert_eq!(m.ids(), &[5]);
    }

    #[test]
    fn decode_machine_steps_through_pending_protocol() {
        let mut m = DecodeMachine::for_test(logits_for(5), 3);
        assert_eq!(m.poll(), DecodeStep::Ready { token: 5 });
        // Until the dispatch runs, the machine keeps asking for it.
        assert_eq!(m.poll(), DecodeStep::NeedsDispatch);
        assert_eq!(m.pending_token(), Some(5));
        assert_eq!(m.dispatch_pos(), 10); // total_len 10 + step 0
        assert_eq!(m.remaining_dispatches(), 2);
        m.complete_dispatch(logits_for(6));
        assert_eq!(m.poll(), DecodeStep::Ready { token: 6 });
        assert_eq!(m.dispatch_pos(), 11);
        m.complete_dispatch(logits_for(7));
        // Third token exhausts the budget: recorded, no dispatch owed.
        assert_eq!(m.poll(), DecodeStep::Done);
        assert_eq!(m.ids(), &[5, 6, 7]);
    }

    #[test]
    fn decode_machine_stops_on_eos_mid_stream() {
        let mut m = DecodeMachine::for_test(logits_for(4), 8);
        assert_eq!(m.poll(), DecodeStep::Ready { token: 4 });
        m.complete_dispatch(logits_for(tokenizer::EOS));
        assert_eq!(m.poll(), DecodeStep::Done);
        assert_eq!(m.into_ids(), vec![4]);
    }

    #[test]
    fn run_parallel_matches_sequential_and_reports_errors() {
        let pool = Arc::new(Pool::new(3));
        let seq = run_parallel(None, 8, |i| Ok::<usize, String>(i * i)).unwrap();
        let par = run_parallel(Some(&pool), 8, |i| Ok::<usize, String>(i * i)).unwrap();
        assert_eq!(seq, par);
        let err = run_parallel(Some(&pool), 4, |i| {
            if i == 2 {
                Err("boom".to_string())
            } else {
                Ok(i)
            }
        });
        assert!(err.is_err());
    }

    #[test]
    fn session_config_rejects_bad_dropout() {
        // Validated in SessionDriver::new; the config itself is plain data.
        let cfg = SessionConfig::new(SyncSchedule::uniform(4, 2, 2));
        assert_eq!(cfg.dropout_prob, 0.0);
    }

    #[test]
    fn stranded_probation_demotes_instead_of_panicking() {
        // A node can sit in `Probation` with no reconnector installed
        // (TransportDriver built without `with_reconnector` while
        // `cfg.rejoin` is on).  The rejoin sweep must demote it like a
        // deadline miss — counted in the report — not panic on the
        // missing reconnector.
        use crate::net::{LinkSpec, Topology};
        let mut net = NetSim::uniform(Topology::Star, 3, LinkSpec::default(), 7);
        let mut wire_state =
            vec![WireState::Alive, WireState::Probation { attempts: 1 }, WireState::Alive];
        demote_stranded_probation(1, &mut wire_state, &mut net);
        assert!(matches!(wire_state[1], WireState::Demoted));
        assert!(matches!(wire_state[0], WireState::Alive));
        assert_eq!(net.report().demotions, 1);
    }
}
