//! Synchronization schedules: which participants attend globally at which
//! Transformer blocks.
//!
//! Covers the paper's experiments: uniform H (Fig. 5), the four placement
//! schemes of Fig. 7 (Shallow-Half / Deep-Half / Progressive / Regressive),
//! and per-participant intervals (Fig. 8's publisher sweep).  Attendance
//! perturbations — per-node dropout ([`SyncSchedule::with_dropout`]) —
//! are applied to the schedule itself, so the session driver never
//! special-cases a missing participant: a dropped node is simply not
//! scheduled for that round.

use crate::util::prng::Xoshiro256ss;

/// Per-block, per-participant attendance matrix.
#[derive(Debug, Clone)]
pub struct SyncSchedule {
    /// `attend[m][n]` — participant `n` performs global attention at block `m`.
    pub attend: Vec<Vec<bool>>,
}

/// Named schemes from the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Eq. Alg.1: every participant attends every `h`-th block.
    Uniform { h: usize },
    /// All sync rounds concentrated in the shallower half (Fig. 7a).
    ShallowHalf { rounds: usize },
    /// All sync rounds concentrated in the deeper half (Fig. 7b).
    DeepHalf { rounds: usize },
    /// Sync intervals increase with depth (Fig. 7c).
    Progressive { rounds: usize },
    /// Sync intervals decrease with depth (Fig. 7d).
    Regressive { rounds: usize },
}

impl Scheme {
    pub fn as_str(self) -> &'static str {
        match self {
            Scheme::Uniform { .. } => "uniform",
            Scheme::ShallowHalf { .. } => "shallow-half",
            Scheme::DeepHalf { .. } => "deep-half",
            Scheme::Progressive { .. } => "progressive",
            Scheme::Regressive { .. } => "regressive",
        }
    }

    /// The set of sync blocks (0-indexed) this scheme places in `m` blocks.
    pub fn sync_blocks(self, m: usize) -> Vec<usize> {
        match self {
            Scheme::Uniform { h } => {
                let h = h.clamp(1, m);
                (0..m).filter(|b| (b + 1) % h == 0).collect()
            }
            Scheme::ShallowHalf { rounds } => {
                let r = rounds.min(m / 2);
                (0..r).collect()
            }
            Scheme::DeepHalf { rounds } => {
                let r = rounds.min(m - m / 2);
                (m - r..m).collect()
            }
            Scheme::Progressive { rounds } => spaced_blocks(m, rounds, false),
            Scheme::Regressive { rounds } => spaced_blocks(m, rounds, true),
        }
    }
}

/// Place `rounds` sync blocks with geometrically growing gaps; `reverse`
/// mirrors the placement (gaps shrink with depth).
fn spaced_blocks(m: usize, rounds: usize, reverse: bool) -> Vec<usize> {
    let rounds = rounds.clamp(1, m);
    // Positions at geometric depths: block index ~ m * (2^i - 1)/(2^r - 1).
    let denom = (1u64 << rounds) - 1;
    let blocks: Vec<usize> = (1..=rounds)
        .map(|i| {
            let num = (1u64 << i) - 1;
            (((m as u64) * num) / denom).saturating_sub(1) as usize
        })
        .collect();
    // Resolve collisions by pushing later blocks forward.
    let mut used = vec![false; m];
    let mut out = Vec::with_capacity(rounds);
    for b in blocks {
        let mut b = b.min(m - 1);
        while used[b] {
            b = (b + 1) % m;
        }
        used[b] = true;
        out.push(b);
    }
    out.sort_unstable();
    if reverse {
        let rev: Vec<usize> = out.iter().map(|&b| m - 1 - b).collect();
        let mut rev: Vec<usize> = rev.into_iter().collect();
        rev.sort_unstable();
        rev
    } else {
        out
    }
}

impl SyncSchedule {
    /// All participants attend at the scheme's sync blocks.
    pub fn from_scheme(scheme: Scheme, m: usize, n: usize) -> Self {
        let sync = scheme.sync_blocks(m);
        let mut attend = vec![vec![false; n]; m];
        for b in sync {
            attend[b] = vec![true; n];
        }
        Self { attend }
    }

    /// Uniform interval `h` for every participant (Alg. 1).
    pub fn uniform(m: usize, n: usize, h: usize) -> Self {
        Self::from_scheme(Scheme::Uniform { h }, m, n)
    }

    /// Per-participant intervals: participant `i` attends every `hs[i]`-th
    /// block (Fig. 8's publisher sweep).
    pub fn per_participant(m: usize, hs: &[usize]) -> Self {
        let attend = (0..m)
            .map(|b| {
                hs.iter()
                    .map(|&h| {
                        let h = h.clamp(1, m);
                        (b + 1) % h == 0
                    })
                    .collect()
            })
            .collect();
        Self { attend }
    }

    /// Fully local (H = M): LocAttn baseline.
    pub fn local_only(m: usize, n: usize) -> Self {
        let mut s = Self { attend: vec![vec![false; n]; m] };
        if m > 0 {
            // H = M still syncs once at the last block per Alg. 1.
            s.attend[m - 1] = vec![true; n];
        }
        s
    }

    /// No sync at all (strictly local inference; used for ablations).
    pub fn never(m: usize, n: usize) -> Self {
        Self { attend: vec![vec![false; n]; m] }
    }

    pub fn n_blocks(&self) -> usize {
        self.attend.len()
    }

    pub fn n_participants(&self) -> usize {
        self.attend.first().map(Vec::len).unwrap_or(0)
    }

    /// Does anyone attend globally at block `m`?
    pub fn any_attending(&self, m: usize) -> bool {
        self.attend[m].iter().any(|&b| b)
    }

    /// Blocks at which at least one participant attends.
    pub fn sync_blocks(&self) -> Vec<usize> {
        (0..self.n_blocks()).filter(|&m| self.any_attending(m)).collect()
    }

    /// Total attendance events (= global-attention executions).
    pub fn total_attendances(&self) -> usize {
        self.attend.iter().flatten().filter(|&&b| b).count()
    }

    /// Mask each scheduled attendance independently with probability
    /// `prob` (per-node dropout: flaky links, stragglers past the round
    /// deadline, duty-cycled devices).  Only `true` slots draw from the
    /// RNG, never-attending slots stay untouched, and `prob <= 0` returns
    /// the schedule unchanged without consuming randomness.  If every
    /// attendee of a block drops, the block degrades to local attention
    /// for everyone — the same path a never-syncing schedule takes.
    pub fn with_dropout(&self, prob: f64, rng: &mut Xoshiro256ss) -> SyncSchedule {
        if prob <= 0.0 {
            return self.clone();
        }
        let attend = self
            .attend
            .iter()
            .map(|row| row.iter().map(|&a| a && !rng.bernoulli(prob)).collect())
            .collect();
        SyncSchedule { attend }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_h1_syncs_everywhere() {
        let s = SyncSchedule::uniform(8, 3, 1);
        assert_eq!(s.sync_blocks(), (0..8).collect::<Vec<_>>());
        assert_eq!(s.total_attendances(), 24);
    }

    #[test]
    fn uniform_h2_syncs_every_other() {
        let s = SyncSchedule::uniform(8, 2, 2);
        assert_eq!(s.sync_blocks(), vec![1, 3, 5, 7]);
    }

    #[test]
    fn uniform_hm_syncs_last_only() {
        let s = SyncSchedule::uniform(8, 2, 8);
        assert_eq!(s.sync_blocks(), vec![7]);
    }

    #[test]
    fn halves_are_disjoint() {
        let sh = Scheme::ShallowHalf { rounds: 4 }.sync_blocks(8);
        let dh = Scheme::DeepHalf { rounds: 4 }.sync_blocks(8);
        assert_eq!(sh, vec![0, 1, 2, 3]);
        assert_eq!(dh, vec![4, 5, 6, 7]);
    }

    #[test]
    fn progressive_gaps_grow() {
        let p = Scheme::Progressive { rounds: 4 }.sync_blocks(8);
        assert_eq!(p.len(), 4);
        let gaps: Vec<isize> =
            p.windows(2).map(|w| w[1] as isize - w[0] as isize).collect();
        for w in gaps.windows(2) {
            assert!(w[1] >= w[0], "gaps should not shrink: {p:?}");
        }
        assert!(p[0] <= 1, "progressive starts shallow: {p:?}");
    }

    #[test]
    fn regressive_is_mirror_of_progressive() {
        let p = Scheme::Progressive { rounds: 4 }.sync_blocks(8);
        let r = Scheme::Regressive { rounds: 4 }.sync_blocks(8);
        let mirrored: Vec<usize> = p.iter().map(|&b| 7 - b).rev().collect();
        assert_eq!(r, mirrored);
    }

    #[test]
    fn per_participant_intervals() {
        let s = SyncSchedule::per_participant(8, &[2, 8]);
        // participant 0 attends blocks 1,3,5,7; participant 1 only block 7.
        assert!(s.attend[1][0] && !s.attend[1][1]);
        assert!(s.attend[7][0] && s.attend[7][1]);
        assert_eq!(s.total_attendances(), 5);
    }

    #[test]
    fn schemes_have_requested_rounds() {
        for scheme in [
            Scheme::ShallowHalf { rounds: 4 },
            Scheme::DeepHalf { rounds: 4 },
            Scheme::Progressive { rounds: 4 },
            Scheme::Regressive { rounds: 4 },
        ] {
            assert_eq!(scheme.sync_blocks(8).len(), 4, "{scheme:?}");
        }
    }

    #[test]
    fn dropout_zero_is_identity_and_draws_nothing() {
        let s = SyncSchedule::uniform(8, 3, 2);
        let mut rng = Xoshiro256ss::new(1);
        let masked = s.with_dropout(0.0, &mut rng);
        assert_eq!(masked.attend, s.attend);
        // No randomness consumed: the next draw matches a fresh stream.
        let mut fresh = Xoshiro256ss::new(1);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn dropout_only_removes_attendance() {
        let s = SyncSchedule::uniform(8, 4, 2);
        let mut rng = Xoshiro256ss::new(7);
        let masked = s.with_dropout(0.5, &mut rng);
        assert_eq!(masked.n_blocks(), s.n_blocks());
        assert_eq!(masked.n_participants(), s.n_participants());
        for (m, row) in masked.attend.iter().enumerate() {
            for (p, &a) in row.iter().enumerate() {
                assert!(!a || s.attend[m][p], "dropout added attendance at ({m}, {p})");
            }
        }
        assert!(masked.total_attendances() <= s.total_attendances());
    }

    #[test]
    fn dropout_deterministic_and_rate_plausible() {
        let s = SyncSchedule::uniform(64, 8, 1); // 512 attendance slots
        let mut r1 = Xoshiro256ss::new(11);
        let mut r2 = Xoshiro256ss::new(11);
        let a = s.with_dropout(0.3, &mut r1);
        let b = s.with_dropout(0.3, &mut r2);
        assert_eq!(a.attend, b.attend, "same seed must give the same mask");
        let kept = a.total_attendances() as f64 / s.total_attendances() as f64;
        assert!((kept - 0.7).abs() < 0.1, "kept fraction {kept}");
        // Full dropout silences every round.
        let mut r3 = Xoshiro256ss::new(3);
        assert_eq!(s.with_dropout(1.0, &mut r3).total_attendances(), 0);
    }

    #[test]
    fn sync_blocks_sorted_unique() {
        for m in [4usize, 6, 8, 12, 16] {
            for rounds in 1..=4usize {
                for scheme in [
                    Scheme::Progressive { rounds },
                    Scheme::Regressive { rounds },
                ] {
                    let b = scheme.sync_blocks(m);
                    for w in b.windows(2) {
                        assert!(w[0] < w[1], "{scheme:?} m={m}: {b:?}");
                    }
                    assert!(b.iter().all(|&x| x < m));
                }
            }
        }
    }
}
