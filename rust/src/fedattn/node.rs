//! One participant, one node: the state owned by a single FedAttn
//! participant and the typed protocol surface it exposes.
//!
//! The paper's participants are peers that compute local self-attention
//! and exchange KV messages; a [`ParticipantNode`] owns exactly one
//! participant's state — token representations, per-block decode caches,
//! device handles — and the [`Participant`] trait is the message-level
//! contract the session driver speaks to it through:
//!
//! * [`Participant::contribute`] — package this round's transmitted KV
//!   rows as a [`KvContribution`] (the uplink).
//! * [`Participant::absorb_frame`] / [`Participant::absorb_local`] — fold
//!   the round's aggregated KV (or, off-round, the node's own local KV)
//!   into the per-block decode caches.
//!
//! The trait pins the *message-level contract* of a round — what crosses
//! the participant boundary and in which order.  Two implementations
//! exist: the in-process [`ParticipantNode`] (the [`SessionDriver`]'s
//! pool-parallel loops snapshot its `Arc`'d compute state directly) and
//! the wire-backed [`RemoteParticipant`] proxy, whose protocol plane —
//! contributions, frames, decode — actually crosses a
//! [`Transport`] (see the [`transport`] module).
//!
//! [`SessionDriver`]: crate::fedattn::driver::SessionDriver
//! [`RemoteParticipant`]: crate::fedattn::transport::RemoteParticipant
//! [`Transport`]: crate::fedattn::transport::Transport
//! [`transport`]: crate::fedattn::transport

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::fedattn::kv::GlobalKv;
use crate::fedattn::masks::{decode_mask_set_visible, local_mask};
use crate::fedattn::protocol::KvContribution;
use crate::runtime::Engine;
use crate::tensor::{DeviceTensor, HostTensor, NEG_MASK};

/// The frozen device half of a [`BlockCache`]: the prefill-time cache and
/// its visibility mask live on the device (uploaded once), while rows
/// appended during decode accumulate in a small host-side tail that is
/// re-uploaded per step.
pub(crate) struct DevCache {
    pub(crate) k: DeviceTensor,
    pub(crate) v: DeviceTensor,
    pub(crate) mask: DeviceTensor,
    /// Cache rows at freeze time; later appends land in the tail.
    pub(crate) base_len: usize,
    /// `[R, Hkv, hd]` decode-appended rows (zero-padded; occupancy is
    /// encoded by `tail_mask`).
    pub(crate) k_tail: HostTensor,
    pub(crate) v_tail: HostTensor,
    /// `[1, R]` tail visibility mask.
    pub(crate) tail_mask: HostTensor,
}

/// A participant's KV cache for one block, sized to the decode-cache
/// capacity `C`.
pub(crate) struct BlockCache {
    pub(crate) k: HostTensor,
    pub(crate) v: HostTensor,
    /// Visibility flags per cache row (for the decode mask).
    pub(crate) visible: Vec<bool>,
    /// Next free row.
    pub(crate) len: usize,
    /// Incremental `[1, C]` decode mask, kept in lockstep with `visible`
    /// (only the newly appended columns flip on `push_rows`).
    pub(crate) dmask: HostTensor,
    /// Device-frozen prefix + growing tail (device-resident decode).
    pub(crate) dev: Option<DevCache>,
}

impl BlockCache {
    pub(crate) fn new(c: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self {
            k: HostTensor::zeros(&[c, kv_heads, head_dim]),
            v: HostTensor::zeros(&[c, kv_heads, head_dim]),
            visible: vec![false; c],
            len: 0,
            dmask: HostTensor::full(&[1, c], NEG_MASK),
            dev: None,
        }
    }

    pub(crate) fn push_rows(
        &mut self,
        k: &HostTensor,
        v: &HostTensor,
        rows: usize,
        visible: &[bool],
    ) {
        let c = self.k.shape()[0];
        assert!(self.len + rows <= c, "decode cache overflow: {} + {rows} > {c}", self.len);
        self.k.copy_rows_from(k, 0..rows, self.len);
        self.v.copy_rows_from(v, 0..rows, self.len);
        self.visible[self.len..self.len + rows].copy_from_slice(&visible[..rows]);
        for (i, &vis) in visible[..rows].iter().enumerate() {
            if vis {
                decode_mask_set_visible(&mut self.dmask, self.len + i);
            }
        }
        // The device prefix is frozen: post-freeze rows go to the tail.  A
        // full tail (e.g. repeated decodes on one participant) drops the
        // frozen prefix — the host cache is always complete, so the
        // session falls back to full-cache uploads (or re-freezes a fresh
        // prefix at the next decode) instead of failing.
        let len = self.len;
        let tail_full = self
            .dev
            .as_ref()
            .is_some_and(|dev| len + rows - dev.base_len > dev.k_tail.shape()[0]);
        if tail_full {
            self.dev = None;
        } else if let Some(dev) = self.dev.as_mut() {
            for i in 0..rows {
                let t = len + i - dev.base_len;
                dev.k_tail.copy_rows_from(k, i..i + 1, t);
                dev.v_tail.copy_rows_from(v, i..i + 1, t);
                if visible[i] {
                    decode_mask_set_visible(&mut dev.tail_mask, t);
                }
            }
        }
        self.len += rows;
    }

    /// Upload the cache (K, V, visibility mask) to the device once and
    /// start routing appended rows into an `[R]` tail.  Idempotent.
    pub(crate) fn freeze_device(&mut self, engine: &Engine, r: usize) -> Result<()> {
        if self.dev.is_some() {
            return Ok(());
        }
        let (hkv, hd) = (self.k.shape()[1], self.k.shape()[2]);
        self.dev = Some(DevCache {
            k: engine.upload(&self.k)?,
            v: engine.upload(&self.v)?,
            mask: engine.upload(&self.dmask)?,
            base_len: self.len,
            k_tail: HostTensor::zeros(&[r, hkv, hd]),
            v_tail: HostTensor::zeros(&[r, hkv, hd]),
            tail_mask: HostTensor::full(&[1, r], NEG_MASK),
        });
        Ok(())
    }
}

/// The message-level contract between the session driver and one
/// participant.  [`ParticipantNode`] is the in-process implementation and
/// [`RemoteParticipant`] the wire-backed one: every protocol step is
/// fallible because a real deployment can lose its transport mid-round
/// (the in-process node never fails).
///
/// [`RemoteParticipant`]: crate::fedattn::transport::RemoteParticipant
pub trait Participant {
    /// This participant's index in the federation.
    fn id(&self) -> usize;

    /// Valid (non-padding) token rows this node holds.
    fn valid_rows(&self) -> usize;

    /// Global positions of this node's valid tokens.
    fn positions(&self) -> &[i32];

    /// Whether this node keeps per-block decode caches (publishers and,
    /// under `decode_all`, everyone).
    fn keeps_caches(&self) -> bool;

    /// Package the rows flagged in `tx` of this round's fresh K/V as the
    /// node's uplink message for `block`.
    fn contribute(
        &mut self,
        block: usize,
        k: &HostTensor,
        v: &HostTensor,
        tx: &[bool],
        relevance: Option<&[f64]>,
    ) -> Result<KvContribution>;

    /// Attendee path: fold the aggregated round frame into the decode
    /// cache for `block`.  Rows this node owns or that were transmitted
    /// are visible; everything else is masked (it never saw those rows).
    fn absorb_frame(&mut self, block: usize, gkv: &GlobalKv) -> Result<()>;

    /// Non-attendee path: cache this node's own local K/V for `block`.
    fn absorb_local(&mut self, block: usize, k: &HostTensor, v: &HostTensor) -> Result<()>;
}

/// In-process participant: owns one participant's token representations,
/// padded positions, local mask, and per-block decode caches.  The hidden
/// state and masks are `Arc`'d so the driver's pool-parallel loops can
/// snapshot them into `'static` closures without copying.
pub struct ParticipantNode {
    id: usize,
    /// Post-sparsity token ids (the node-resident wire handshake re-sends
    /// these so a remote node can rebuild identical state; they are plain
    /// vocabulary indices, never embeddings or hidden states).
    pub(crate) ids: Vec<i32>,
    /// Global positions of the kept tokens (after local sparsity).
    pub(crate) pos: Vec<i32>,
    /// Padded positions array (`l_pad` long; padding repeats the last pos).
    pub(crate) pos_pad: Arc<Vec<i32>>,
    pub(crate) valid: usize,
    /// Hidden states `[l_pad, d]`.
    pub(crate) x: Arc<HostTensor>,
    /// Cached local causal mask (reused across local blocks).
    pub(crate) lmask: Arc<HostTensor>,
    /// Per-layer decode caches; empty for nodes that will not decode.
    pub(crate) caches: Vec<BlockCache>,
}

impl ParticipantNode {
    /// Build a node from its post-sparsity token ids and global positions.
    /// `keep_caches` allocates one [`BlockCache`]-backed decode cache per
    /// layer (capacity = the manifest's decode-cache size).
    pub(crate) fn build(
        engine: &Engine,
        id: usize,
        ids: &[i32],
        pos: Vec<i32>,
        keep_caches: bool,
    ) -> Result<Self> {
        let md = &engine.manifest.model;
        let l_pad = engine.manifest.pick_l(ids.len())?;
        let mut pos_pad = pos.clone();
        let last = *pos_pad.last().unwrap_or(&0);
        pos_pad.resize(l_pad, last);
        let mut x = HostTensor::zeros(&[l_pad, md.d_model]);
        let emb = engine.embed(ids)?;
        x.copy_rows_from(&emb, 0..ids.len(), 0);
        let valid = ids.len();
        let lmask = local_mask(&pos_pad, valid);
        let caches = if keep_caches {
            let c = engine.manifest.decode_cache;
            (0..md.n_layers)
                .map(|_| BlockCache::new(c, md.n_kv_heads, md.head_dim))
                .collect()
        } else {
            Vec::new()
        };
        Ok(Self {
            id,
            ids: ids.to_vec(),
            pos,
            pos_pad: Arc::new(pos_pad),
            valid,
            x: Arc::new(x),
            lmask: Arc::new(lmask),
            caches,
        })
    }

    /// Replace the hidden state after a block (the driver collects block
    /// outputs in participant order, so updates stay deterministic).
    pub(crate) fn set_hidden(&mut self, x: HostTensor) {
        self.x = Arc::new(x);
    }

    /// The node's final hidden state for its last valid token, `[1, d]`
    /// (decode kick-off).  Fails for a node with zero valid rows — an
    /// empty shard has no last token, and `valid - 1` would wrap.
    pub(crate) fn last_hidden(&self) -> Result<HostTensor> {
        ensure!(
            self.valid > 0,
            "participant {} has no valid rows: cannot produce a decode hidden state",
            self.id
        );
        let last_row = self.valid - 1;
        let d = self.x.shape()[1];
        let mut h = HostTensor::zeros(&[1, d]);
        h.copy_rows_from(self.x.as_ref(), last_row..last_row + 1, 0);
        Ok(h)
    }

    /// Bounds-check a cache index before `absorb_*` touches it: the block
    /// index arrives off the wire on the node-resident path, so a hostile
    /// or stale value (or a cache-less node) must surface as an `Err`,
    /// not an out-of-bounds panic.
    fn cache_for(&mut self, block: usize, rows: usize) -> Result<&mut BlockCache> {
        ensure!(
            block < self.caches.len(),
            "participant {}: no decode cache for block {block} ({} caches)",
            self.id,
            self.caches.len()
        );
        let cache = &mut self.caches[block];
        let cap = cache.k.shape()[0];
        ensure!(
            cache.len + rows <= cap,
            "participant {}: block {block} decode cache overflow ({} + {rows} > {cap})",
            self.id,
            cache.len
        );
        Ok(cache)
    }
}

impl Participant for ParticipantNode {
    fn id(&self) -> usize {
        self.id
    }

    fn valid_rows(&self) -> usize {
        self.valid
    }

    fn positions(&self) -> &[i32] {
        &self.pos
    }

    fn keeps_caches(&self) -> bool {
        !self.caches.is_empty()
    }

    fn contribute(
        &mut self,
        block: usize,
        k: &HostTensor,
        v: &HostTensor,
        tx: &[bool],
        relevance: Option<&[f64]>,
    ) -> Result<KvContribution> {
        Ok(KvContribution::from_rows(block, self.id, k, v, &self.pos, tx, relevance))
    }

    fn absorb_frame(&mut self, block: usize, gkv: &GlobalKv) -> Result<()> {
        let vis: Vec<bool> = gkv
            .meta
            .iter()
            .map(|r| r.owner == self.id || r.transmitted)
            .collect();
        let rows = gkv.rows();
        self.cache_for(block, rows)?.push_rows(&gkv.k, &gkv.v, rows, &vis);
        Ok(())
    }

    fn absorb_local(&mut self, block: usize, k: &HostTensor, v: &HostTensor) -> Result<()> {
        let vis = vec![true; self.valid];
        let rows = self.valid;
        self.cache_for(block, rows)?.push_rows(k, v, rows, &vis);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fedattn::kv::KvRowMeta;
    use crate::fedattn::masks::decode_mask;
    use crate::fedattn::sparse::LocalSparsity;
    use crate::util::prng::Xoshiro256ss;

    /// A hand-built node (no engine required): `valid` tokens out of a
    /// 4-row padded hidden state, with `n_caches` capacity-4 block caches.
    fn bare_node(valid: usize, n_caches: usize) -> ParticipantNode {
        ParticipantNode {
            id: 0,
            ids: (0..valid as i32).collect(),
            pos: (0..valid as i32).collect(),
            pos_pad: Arc::new(vec![0; 4]),
            valid,
            x: Arc::new(HostTensor::zeros(&[4, 8])),
            lmask: Arc::new(HostTensor::zeros(&[4, 4])),
            caches: (0..n_caches).map(|_| BlockCache::new(4, 1, 2)).collect(),
        }
    }

    fn gkv_rows(rows: usize) -> GlobalKv {
        GlobalKv {
            k: HostTensor::zeros(&[rows, 1, 2]),
            v: HostTensor::zeros(&[rows, 1, 2]),
            meta: (0..rows)
                .map(|i| KvRowMeta {
                    pos: i as i32,
                    owner: 0,
                    row: i,
                    transmitted: true,
                    relevance: 0.0,
                })
                .collect(),
        }
    }

    #[test]
    fn block_cache_push_and_overflow() {
        let mut c = BlockCache::new(4, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        let v = k.clone();
        c.push_rows(&k, &v, 2, &[true, false]);
        assert_eq!(c.len, 2);
        assert_eq!(c.visible[..2], [true, false]);
        c.push_rows(&k, &v, 2, &[true, true]);
        assert_eq!(c.len, 4);
    }

    #[test]
    #[should_panic(expected = "decode cache overflow")]
    fn block_cache_overflow_panics() {
        let mut c = BlockCache::new(2, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![0.0; 4]).unwrap();
        c.push_rows(&k, &k.clone(), 2, &[true, true]);
        c.push_rows(&k, &k.clone(), 1, &[true]);
    }

    #[test]
    fn block_cache_incremental_mask_matches_fresh_build() {
        // The per-cache [1, C] mask flips only the newly appended columns
        // on push_rows; it must equal a from-scratch decode_mask build at
        // every state.
        let mut c = BlockCache::new(6, 1, 2);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        let k = HostTensor::new(&[2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        c.push_rows(&k, &k.clone(), 2, &[true, false]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        c.push_rows(&k, &k.clone(), 2, &[false, true]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        c.push_rows(&k, &k.clone(), 1, &[true]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
    }

    #[test]
    fn last_hidden_errs_on_zero_valid_rows() {
        // Regression: `self.valid - 1` used to wrap at valid == 0 and
        // panic on the subsequent slice.  A zero-valid participant only
        // arises from an empty shard — every sparsity preset keeps at
        // least one token for len > 0 — but an empty shard is legal.
        let node = bare_node(0, 0);
        let err = node.last_hidden().unwrap_err();
        assert!(err.to_string().contains("no valid rows"), "{err}");
        let h = bare_node(2, 0).last_hidden().unwrap();
        assert_eq!(h.shape(), &[1, 8]);
    }

    #[test]
    fn sparsity_presets_never_strand_a_nonempty_shard() {
        // The zero-valid edge case is reachable only through an empty
        // shard: even ratio-0 sparsity keeps >= 1 token for len > 0, so
        // the presets themselves can never produce `valid == 0`.
        let mut rng = Xoshiro256ss::new(9);
        for ratio in [0.0, 0.1, 0.5, 0.9, 1.0] {
            let sp = LocalSparsity { ratio };
            for len in [1usize, 2, 7] {
                assert!(!sp.select(len, 0, &mut rng).is_empty(), "ratio {ratio} len {len}");
            }
            assert!(sp.select(0, 3, &mut rng).is_empty());
        }
    }

    #[test]
    fn absorb_rejects_out_of_range_block() {
        // Regression: `self.caches[block]` used to panic for a hostile or
        // stale block index and for cache-less nodes.
        let k = HostTensor::zeros(&[2, 1, 2]);
        let mut cacheless = bare_node(2, 0);
        let err = cacheless.absorb_local(0, &k, &k.clone()).unwrap_err();
        assert!(err.to_string().contains("no decode cache"), "{err}");

        let mut node = bare_node(2, 2);
        assert!(node.absorb_local(1, &k, &k.clone()).is_ok());
        let err = node.absorb_local(2, &k, &k.clone()).unwrap_err();
        assert!(err.to_string().contains("no decode cache for block 2"), "{err}");
        let err = node.absorb_frame(9999, &gkv_rows(2)).unwrap_err();
        assert!(err.to_string().contains("no decode cache for block 9999"), "{err}");
        assert!(node.absorb_frame(0, &gkv_rows(2)).is_ok());
    }

    #[test]
    fn absorb_errs_instead_of_panicking_on_cache_overflow() {
        // A hostile frame can carry more rows than the decode cache has
        // room for; the fallible path must refuse it before push_rows's
        // internal assert fires.
        let mut node = bare_node(2, 1);
        assert!(node.absorb_frame(0, &gkv_rows(3)).is_ok());
        let err = node.absorb_frame(0, &gkv_rows(2)).unwrap_err();
        assert!(err.to_string().contains("decode cache overflow"), "{err}");
    }
}
