//! One collaborative-inference task: the federated prefill (Alg. 1) and the
//! publisher's autoregressive decode over the per-block KV caches (§IV-C).
//!
//! Device-resident execution (paper §VI computation/communication
//! co-design):
//!
//! * At every sync block the packed global KV is uploaded to the device
//!   **once** and all attendees attend over the shared handles
//!   ([`Engine::attn_ffn_dev`]); upload bytes per round no longer scale
//!   with the attendee count.
//! * At decode time each block cache is **frozen** on the device after
//!   prefill ([`BlockCache::freeze_device`]): the `[C]` K/V buffers and
//!   the `[1, C]` visibility mask ship once, and each token step uploads
//!   only the small `[R]` decode tail — O(1) bytes per step in `C`.
//!   Falls back to full-cache uploads when the artifact set has no
//!   decode-tail variants.
//! * The per-participant loops (local blocks, QKV projection, attendee
//!   attention, multi-participant decode) run on an [`exec::Pool`] when
//!   `SessionConfig::workers > 1`.  Results are collected in participant
//!   order and all host-side reductions stay sequential, so a parallel
//!   session is byte-identical to the sequential one.
//!
//! [`exec::Pool`]: crate::exec::Pool

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::data::Partition;
use crate::exec::Pool;
use crate::fedattn::kv::GlobalKv;
use crate::fedattn::masks::{decode_mask_set_visible, global_mask, local_mask};
use crate::fedattn::relevance::{self, RelevanceTracker};
use crate::fedattn::schedule::SyncSchedule;
use crate::fedattn::sparse::{KvExchangePolicy, LocalSparsity, TxContext};
use crate::net::{NetReport, NetSim};
use crate::runtime::Engine;
use crate::tensor::{DeviceTensor, HostTensor, NEG_MASK};
use crate::tokenizer;
use crate::util::prng::Xoshiro256ss;

/// Session knobs (one FedAttn task).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub schedule: SyncSchedule,
    pub local_sparsity: LocalSparsity,
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Collect every participant's final hidden states (error analysis /
    /// divergence metrics; costs memory, off for serving).
    pub record_hidden: bool,
    /// Keep KV caches and decode a response for *every* participant (the
    /// paper's Fig. 5 reports mean/min/max EM across participants).  The
    /// default caches and decodes only the task publisher.
    pub decode_all: bool,
    /// Coordinator-allocated per-participant KV row budgets (heterogeneous
    /// links); overrides the budget embedded in budgeted policies.  For
    /// [`KvExchangePolicy::ByteBudget`] with no explicit allocation the
    /// session derives one from the network simulator's link specs.
    pub kv_row_budgets: Option<Vec<usize>>,
    /// Thread-pool width for the per-participant loops (1 = sequential).
    /// Parallel sessions are byte-identical to sequential ones (ordered
    /// result collection + sequential host-side reductions).
    pub workers: usize,
    /// Freeze decode caches on the device and ship only the decode tail
    /// per token step.  Ignored (with a host-path fallback) when the
    /// artifact set predates decode-tail variants.
    pub device_decode: bool,
}

impl SessionConfig {
    pub fn new(schedule: SyncSchedule) -> Self {
        Self {
            schedule,
            local_sparsity: LocalSparsity::full(),
            kv_policy: KvExchangePolicy::Full,
            max_new_tokens: 12,
            seed: 0,
            record_hidden: false,
            decode_all: false,
            kv_row_budgets: None,
            workers: 1,
            device_decode: true,
        }
    }
}

/// Per-participant mutable state during prefill.  The per-layer tensors
/// are `Arc`'d so the parallel loops can borrow them from `'static` pool
/// closures without copying.
struct PState {
    /// Global positions of the kept tokens (after local sparsity).
    pos: Vec<i32>,
    /// Padded positions array (`l_pad` long; padding repeats the last pos).
    pos_pad: Arc<Vec<i32>>,
    valid: usize,
    /// Hidden states `[l_pad, d]`.
    x: Arc<HostTensor>,
    /// Cached local causal mask (reused across local blocks).
    lmask: Arc<HostTensor>,
}

/// The frozen device half of a [`BlockCache`]: the prefill-time cache and
/// its visibility mask live on the device (uploaded once), while rows
/// appended during decode accumulate in a small host-side tail that is
/// re-uploaded per step.
struct DevCache {
    k: DeviceTensor,
    v: DeviceTensor,
    mask: DeviceTensor,
    /// Cache rows at freeze time; later appends land in the tail.
    base_len: usize,
    /// `[R, Hkv, hd]` decode-appended rows (zero-padded; occupancy is
    /// encoded by `tail_mask`).
    k_tail: HostTensor,
    v_tail: HostTensor,
    /// `[1, R]` tail visibility mask.
    tail_mask: HostTensor,
}

/// The publisher's KV cache for one block, sized to the decode-cache
/// capacity `C`.
struct BlockCache {
    k: HostTensor,
    v: HostTensor,
    /// Visibility flags per cache row (for the decode mask).
    visible: Vec<bool>,
    /// Next free row.
    len: usize,
    /// Incremental `[1, C]` decode mask, kept in lockstep with `visible`
    /// (only the newly appended columns flip on `push_rows`).
    dmask: HostTensor,
    /// Device-frozen prefix + growing tail (device-resident decode).
    dev: Option<DevCache>,
}

impl BlockCache {
    fn new(c: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self {
            k: HostTensor::zeros(&[c, kv_heads, head_dim]),
            v: HostTensor::zeros(&[c, kv_heads, head_dim]),
            visible: vec![false; c],
            len: 0,
            dmask: HostTensor::full(&[1, c], NEG_MASK),
            dev: None,
        }
    }

    fn push_rows(&mut self, k: &HostTensor, v: &HostTensor, rows: usize, visible: &[bool]) {
        let c = self.k.shape()[0];
        assert!(self.len + rows <= c, "decode cache overflow: {} + {rows} > {c}", self.len);
        self.k.copy_rows_from(k, 0..rows, self.len);
        self.v.copy_rows_from(v, 0..rows, self.len);
        self.visible[self.len..self.len + rows].copy_from_slice(&visible[..rows]);
        for (i, &vis) in visible[..rows].iter().enumerate() {
            if vis {
                decode_mask_set_visible(&mut self.dmask, self.len + i);
            }
        }
        // The device prefix is frozen: post-freeze rows go to the tail.  A
        // full tail (e.g. repeated decodes on one participant) drops the
        // frozen prefix — the host cache is always complete, so the
        // session falls back to full-cache uploads (or re-freezes a fresh
        // prefix at the next decode) instead of failing.
        let len = self.len;
        let tail_full = self
            .dev
            .as_ref()
            .is_some_and(|dev| len + rows - dev.base_len > dev.k_tail.shape()[0]);
        if tail_full {
            self.dev = None;
        } else if let Some(dev) = self.dev.as_mut() {
            for i in 0..rows {
                let t = len + i - dev.base_len;
                dev.k_tail.copy_rows_from(k, i..i + 1, t);
                dev.v_tail.copy_rows_from(v, i..i + 1, t);
                if visible[i] {
                    decode_mask_set_visible(&mut dev.tail_mask, t);
                }
            }
        }
        self.len += rows;
    }

    /// Upload the cache (K, V, visibility mask) to the device once and
    /// start routing appended rows into an `[R]` tail.  Idempotent.
    fn freeze_device(&mut self, engine: &Engine, r: usize) -> Result<()> {
        if self.dev.is_some() {
            return Ok(());
        }
        let (hkv, hd) = (self.k.shape()[1], self.k.shape()[2]);
        self.dev = Some(DevCache {
            k: engine.upload(&self.k)?,
            v: engine.upload(&self.v)?,
            mask: engine.upload(&self.dmask)?,
            base_len: self.len,
            k_tail: HostTensor::zeros(&[r, hkv, hd]),
            v_tail: HostTensor::zeros(&[r, hkv, hd]),
            tail_mask: HostTensor::full(&[1, r], NEG_MASK),
        });
        Ok(())
    }
}

/// Prefill result (before decoding).
pub struct PrefillOutput {
    /// Final hidden states per participant (only when `record_hidden`),
    /// trimmed to valid rows.
    pub hidden: Vec<Option<HostTensor>>,
    /// Positions of each participant's valid tokens.
    pub positions: Vec<Vec<i32>>,
    pub net: NetReport,
    pub wall_ms: f64,
}

/// Full session result.
pub struct SessionReport {
    /// The task publisher's decoded answer.
    pub answer: String,
    pub generated_tokens: usize,
    /// Per-participant answers (only participants that kept caches decode;
    /// others are `None`).  `answers[publisher]` equals `answer`.
    pub answers: Vec<Option<String>>,
    pub net: NetReport,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Final hidden per participant (when `record_hidden`).
    pub hidden: Vec<Option<HostTensor>>,
    pub positions: Vec<Vec<i32>>,
}

/// Run `f(0..n)` across the pool (ordered results) or inline when no pool
/// is configured.  Errors are stringly-typed so closure results satisfy
/// the pool's `Send + 'static` bound.
fn run_parallel<T, F>(pool: Option<&Arc<Pool>>, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(usize) -> Result<T, String> + Send + Sync + 'static,
{
    let outs: Vec<Result<T, String>> = match pool {
        Some(pool) => pool
            .scope_map(n, f)
            .map_err(|e| anyhow::anyhow!("parallel section failed: {e}"))?,
        None => (0..n).map(f).collect(),
    };
    outs.into_iter().map(|r| r.map_err(anyhow::Error::msg)).collect()
}

/// Drives one collaborative task through the engine.
pub struct FedSession<'a> {
    engine: &'a Engine,
    cfg: SessionConfig,
    parts: Vec<PState>,
    /// `caches[p]` — per-layer KV caches for participant `p`; empty vec for
    /// participants that will not decode.
    caches: Vec<Vec<BlockCache>>,
    net: NetSim,
    rng: Xoshiro256ss,
    publisher: usize,
    total_len: usize,
    /// Per-row attention-mass accumulator (only for relevance policies).
    relevance: Option<RelevanceTracker>,
    /// Worker pool for the per-participant loops (`workers > 1`).
    pool: Option<Arc<Pool>>,
}

impl<'a> FedSession<'a> {
    pub fn new(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
    ) -> Result<Self> {
        let n = partition.n_participants();
        anyhow::ensure!(net.n_participants() == n, "net sim participant count");
        anyhow::ensure!(cfg.schedule.n_participants() == n, "schedule participant count");
        anyhow::ensure!(
            cfg.schedule.n_blocks() == engine.manifest.model.n_layers,
            "schedule block count"
        );
        let mut rng = Xoshiro256ss::new(cfg.seed ^ 0x5E55_10);
        let md = &engine.manifest.model;

        // Build per-participant state: apply local sparsity, pad, embed.
        let mut parts = Vec::with_capacity(n);
        for p in 0..n {
            let (s, e) = partition.spans[p];
            let span_ids = &partition.ids[s..e];
            // Protect the tail of the publisher (the "A:" anchor) from
            // local-sparsity dropping.
            let protect = if p == partition.publisher() { 3 } else { 0 };
            let keep = cfg.local_sparsity.select(span_ids.len(), protect, &mut rng);
            let ids: Vec<i32> = keep.iter().map(|&i| span_ids[i]).collect();
            let pos: Vec<i32> = keep.iter().map(|&i| (s + i) as i32).collect();
            let l_pad = engine.manifest.pick_l(ids.len())?;
            let mut pos_pad = pos.clone();
            let last = *pos_pad.last().unwrap_or(&0);
            pos_pad.resize(l_pad, last);
            let mut x = HostTensor::zeros(&[l_pad, md.d_model]);
            let emb = engine.embed(&ids)?;
            x.copy_rows_from(&emb, 0..ids.len(), 0);
            let valid = ids.len();
            let lmask = local_mask(&pos_pad, valid);
            parts.push(PState {
                pos,
                pos_pad: Arc::new(pos_pad),
                valid,
                x: Arc::new(x),
                lmask: Arc::new(lmask),
            });
        }

        let c = engine.manifest.decode_cache;
        let publisher = partition.publisher();
        let caches: Vec<Vec<BlockCache>> = (0..n)
            .map(|p| {
                if p == publisher || cfg.decode_all {
                    (0..md.n_layers)
                        .map(|_| BlockCache::new(c, md.n_kv_heads, md.head_dim))
                        .collect()
                } else {
                    Vec::new()
                }
            })
            .collect();

        if let Some(b) = &cfg.kv_row_budgets {
            anyhow::ensure!(b.len() == n, "kv_row_budgets length {} != {n}", b.len());
        }
        let relevance = cfg.kv_policy.needs_relevance().then(|| {
            RelevanceTracker::new(&parts.iter().map(|s| s.valid).collect::<Vec<_>>())
        });
        let pool = (cfg.workers > 1).then(|| Arc::new(Pool::new(cfg.workers)));

        Ok(Self {
            engine,
            cfg,
            parts,
            caches,
            net,
            rng,
            publisher,
            total_len: partition.len(),
            relevance,
            pool,
        })
    }

    /// Run the federated prefill (Alg. 1 lines 2–14).
    pub fn prefill(&mut self) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let md = self.engine.manifest.model.clone();
        let n = self.parts.len();
        let n_layers = md.n_layers;
        let row_bytes_usize = GlobalKv::row_bytes(md.n_kv_heads, md.head_dim);
        let row_bytes = row_bytes_usize as u64;

        // Budgeted policies: resolve per-participant row budgets once per
        // session.  ByteBudget's total is split across heterogeneous links
        // proportionally to bandwidth unless the coordinator already did.
        let budgets: Option<Vec<usize>> =
            match (&self.cfg.kv_row_budgets, self.cfg.kv_policy) {
                (Some(b), _) => Some(b.clone()),
                (None, KvExchangePolicy::ByteBudget { bytes_per_round }) => {
                    Some(crate::net::allocate_row_budgets(
                        self.net.links(),
                        bytes_per_round / row_bytes_usize.max(1),
                    ))
                }
                _ => None,
            };

        for m in 0..n_layers {
            let attend = self.cfg.schedule.attend[m].clone();
            let any = attend.iter().any(|&b| b);

            if !any {
                // Phase I only: every participant runs a fused local block
                // (pool-parallel; ordered collection keeps determinism).
                let inputs: Vec<_> = self
                    .parts
                    .iter()
                    .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                    .collect();
                let engine = self.engine.clone();
                let outs = run_parallel(self.pool.as_ref(), n, move |p| {
                    let (x, pos, lmask) = &inputs[p];
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map_err(|e| format!("{e:#}"))
                })?;
                for (p, (xo, k, v)) in outs.into_iter().enumerate() {
                    self.parts[p].x = Arc::new(xo);
                    if !self.caches[p].is_empty() {
                        let valid = self.parts[p].valid;
                        let vis = vec![true; valid];
                        self.caches[p][m].push_rows(&k, &v, valid, &vis);
                    }
                }
                continue;
            }

            // Sync block: everyone produces (q,)k,v; attendees do global
            // attention over the aggregated KV.  Phase 1 is pool-parallel.
            let inputs: Vec<_> = self
                .parts
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), Arc::clone(&st.lmask)))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let engine = self.engine.clone();
            let phase1 = run_parallel(self.pool.as_ref(), n, move |p| {
                let (x, pos, lmask) = &inputs[p];
                if attend_in[p] {
                    engine
                        .qkv_project(m, x.as_ref(), pos.as_slice())
                        .map(|(q, k, v)| (Some(q), k, v, None))
                } else {
                    // Non-attendee: plain local block; its fresh K/V are
                    // what it would transmit to attendees.
                    engine
                        .block_fused(m, x.as_ref(), pos.as_slice(), lmask.as_ref())
                        .map(|(xo, k, v)| (None, k, v, Some(xo)))
                }
                .map_err(|e| format!("{e:#}"))
            })?;
            let mut qs: Vec<Option<HostTensor>> = Vec::with_capacity(n);
            let mut ks: Vec<HostTensor> = Vec::with_capacity(n);
            let mut vs: Vec<HostTensor> = Vec::with_capacity(n);
            for (p, (q, k, v, xo)) in phase1.into_iter().enumerate() {
                qs.push(q);
                ks.push(k);
                vs.push(v);
                if let Some(xo) = xo {
                    self.parts[p].x = Arc::new(xo);
                }
            }

            // Sparse/adaptive KV exchange: per-participant transmitted-row
            // flags.  Relevance policies see only mass accumulated at
            // *earlier* sync rounds (causal selection).
            let tx_flags: Vec<Vec<bool>> = (0..n)
                .map(|p| {
                    let ctx = TxContext {
                        who: p,
                        publisher: self.publisher,
                        len: self.parts[p].valid,
                        row_bytes: row_bytes_usize,
                        relevance: self.relevance.as_ref().map(|t| t.scores(p)),
                        row_budget: budgets.as_ref().map(|b| b[p]),
                    };
                    self.cfg.kv_policy.transmitted_ctx(&ctx, &mut self.rng)
                })
                .collect();

            // Pack the global KV (Eq. 20).
            let rows_total: usize = self.parts.iter().map(|s| s.valid).sum();
            let g_pad = self.engine.manifest.pick_g(rows_total)?;
            let parts_refs: Vec<_> = (0..n)
                .map(|p| {
                    (
                        &ks[p],
                        &vs[p],
                        self.parts[p].pos.as_slice(),
                        self.parts[p].valid,
                        tx_flags[p].as_slice(),
                    )
                })
                .collect();
            let mut gkv = GlobalKv::pack(&parts_refs, g_pad)?;
            if let Some(tr) = &self.relevance {
                gkv.attach_relevance(tr.all_scores());
            }
            let (kv_pos, kv_owner, kv_tx) = gkv.meta_columns();

            // Communication accounting + simulated transfer time.
            let tx_rows = gkv.tx_rows_by_owner(n);
            let tx_bytes: Vec<u64> =
                tx_rows.iter().map(|&r| r as u64 * row_bytes).collect();
            self.net.exchange_round(&tx_bytes, &attend);

            // Upload the packed global KV to the device ONCE per sync
            // round; every attendee's attention shares the handles (the
            // buffers are immutable, so read-only sharing holds by
            // construction).
            let gk_dev = self.engine.upload(&gkv.k)?;
            let gv_dev = self.engine.upload(&gkv.v)?;

            // Global attention + FFN for attendees (Eq. 21 + 19),
            // pool-parallel.  When a relevance policy is active, each
            // attendee also computes the column marginals of its attention
            // (row-sum of the attention weights) inside its task; the
            // accumulation below stays sequential in participant order so
            // the result is bit-identical to a sequential session.
            let gkv = Arc::new(gkv);
            let qs = Arc::new(qs);
            let kv_meta = Arc::new((kv_pos, kv_owner, kv_tx));
            let pinputs: Vec<_> = self
                .parts
                .iter()
                .map(|st| (Arc::clone(&st.x), Arc::clone(&st.pos_pad), st.valid))
                .collect();
            let attend_in = Arc::new(attend.clone());
            let track_mass = self.relevance.is_some();
            let engine = self.engine.clone();
            let rows = gkv.rows();
            let gkv_in = Arc::clone(&gkv);
            type AttnOut = Option<(HostTensor, Option<Vec<f64>>)>;
            let outs: Vec<AttnOut> = run_parallel(self.pool.as_ref(), n, move |p| {
                if !attend_in[p] {
                    return Ok(None);
                }
                let (x, pos_pad, valid) = &pinputs[p];
                let q = qs[p].as_ref().ok_or("missing q for attendee")?;
                let (kv_pos, kv_owner, kv_tx) = &*kv_meta;
                let mask = global_mask(
                    pos_pad.as_slice(),
                    *valid,
                    g_pad,
                    kv_pos,
                    kv_owner,
                    kv_tx,
                    rows,
                    p,
                );
                let mass = track_mass
                    .then(|| relevance::attention_mass(q, &gkv_in.k, &mask, *valid, rows));
                let xo = engine
                    .attn_ffn_dev(m, x.as_ref(), q, &gk_dev, &gv_dev, &mask)
                    .map_err(|e| format!("{e:#}"))?;
                Ok(Some((xo, mass)))
            })?;
            let mut round_mass: Option<Vec<f64>> =
                self.relevance.as_ref().map(|_| vec![0.0; gkv.rows()]);
            for (p, out) in outs.into_iter().enumerate() {
                let Some((xo, mass)) = out else { continue };
                if let (Some(acc), Some(mass)) = (round_mass.as_mut(), mass) {
                    for (a, x) in acc.iter_mut().zip(&mass) {
                        *a += x;
                    }
                }
                self.parts[p].x = Arc::new(xo);
            }
            if let (Some(tr), Some(acc)) = (self.relevance.as_mut(), round_mass) {
                tr.observe(&gkv.meta, &acc);
            }

            // Decode caches for this block (paper §IV-C): participants that
            // attended cache the global KV (restricted to what they could
            // see); others cache their own local KV.
            for p in 0..n {
                if self.caches[p].is_empty() {
                    continue;
                }
                if attend[p] {
                    let vis: Vec<bool> = gkv
                        .meta
                        .iter()
                        .map(|r| r.owner == p || r.transmitted)
                        .collect();
                    self.caches[p][m].push_rows(&gkv.k, &gkv.v, gkv.rows(), &vis);
                } else {
                    let vis = vec![true; self.parts[p].valid];
                    self.caches[p][m].push_rows(&ks[p], &vs[p], self.parts[p].valid, &vis);
                }
            }
        }

        let hidden = self.collect_hidden();
        Ok(PrefillOutput {
            hidden,
            positions: self.parts.iter().map(|s| s.pos.clone()).collect(),
            net: self.net.report().clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    fn collect_hidden(&self) -> Vec<Option<HostTensor>> {
        self.parts
            .iter()
            .map(|st| {
                if self.cfg.record_hidden {
                    let mut h = HostTensor::zeros(&[st.valid, st.x.shape()[1]]);
                    h.copy_rows_from(st.x.as_ref(), 0..st.valid, 0);
                    Some(h)
                } else {
                    None
                }
            })
            .collect()
    }

    /// The publisher's final prompt hidden state `[1, d]` for participant
    /// `p` (decode kick-off).
    fn last_hidden(&self, p: usize) -> HostTensor {
        let last_row = self.parts[p].valid - 1;
        let d = self.engine.manifest.model.d_model;
        let mut h = HostTensor::zeros(&[1, d]);
        h.copy_rows_from(self.parts[p].x.as_ref(), last_row..last_row + 1, 0);
        h
    }

    /// Greedy decode from participant `p`'s KV caches (requires that `p`
    /// kept caches).  Returns the decoded text and token count.
    pub fn decode_participant(&mut self, p: usize) -> Result<(String, usize)> {
        anyhow::ensure!(!self.caches[p].is_empty(), "participant {p} has no caches");
        let h_last = self.last_hidden(p);
        let mut caches = std::mem::take(&mut self.caches[p]);
        let res = decode_from_caches(
            self.engine,
            &mut caches,
            &h_last,
            self.total_len,
            self.cfg.max_new_tokens,
            self.cfg.device_decode,
        );
        self.caches[p] = caches;
        res
    }

    /// Decode the task publisher.
    pub fn decode(&mut self) -> Result<(String, usize)> {
        self.decode_participant(self.publisher)
    }

    /// Prefill + decode, returning the full report.  With `decode_all`
    /// and `workers > 1` the per-participant decodes run pool-parallel
    /// (each participant's caches are independent).
    pub fn run(mut self) -> Result<SessionReport> {
        let pre = self.prefill()?;
        let t0 = std::time::Instant::now();
        let n = self.parts.len();
        let decoders: Vec<usize> =
            (0..n).filter(|&p| !self.caches[p].is_empty()).collect();

        // Move each decoding participant's caches + kick-off hidden state
        // into a slot the (shared) pool closure can take exactly once.
        let slots: Vec<Mutex<Option<(Vec<BlockCache>, HostTensor)>>> = decoders
            .iter()
            .map(|&p| {
                let caches = std::mem::take(&mut self.caches[p]);
                Mutex::new(Some((caches, self.last_hidden(p))))
            })
            .collect();
        let slots = Arc::new(slots);
        let engine = self.engine.clone();
        let (total_len, max_new, device_decode) =
            (self.total_len, self.cfg.max_new_tokens, self.cfg.device_decode);
        let slots_in = Arc::clone(&slots);
        let decoded: Vec<(String, usize)> =
            run_parallel(self.pool.as_ref(), decoders.len(), move |i| {
                let (mut caches, h_last) = slots_in[i]
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or("decode slot taken twice")?;
                decode_from_caches(&engine, &mut caches, &h_last, total_len, max_new, device_decode)
                    .map_err(|e| format!("{e:#}"))
            })?;

        let mut answers: Vec<Option<String>> = vec![None; n];
        let mut generated = 0usize;
        let mut answer = String::new();
        for (&p, (text, tokens)) in decoders.iter().zip(decoded) {
            if p == self.publisher {
                answer = text.clone();
                generated = tokens;
            }
            answers[p] = Some(text);
        }
        Ok(SessionReport {
            answer,
            generated_tokens: generated,
            answers,
            net: self.net.into_report(),
            prefill_ms: pre.wall_ms,
            decode_ms: t0.elapsed().as_secs_f64() * 1e3,
            hidden: pre.hidden,
            positions: pre.positions,
        })
    }

    /// Prefill only (error-analysis paths that do not decode).
    pub fn run_prefill_only(mut self) -> Result<PrefillOutput> {
        self.prefill()
    }

    /// Attach a shared worker pool (e.g. the coordinator's, reused across
    /// tasks) instead of the session-owned one `workers > 1` would spawn.
    /// Pass `workers = 1` in the config when using this to avoid creating
    /// a throwaway pool in [`FedSession::new`].
    pub fn with_shared_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

/// Greedy decode over one participant's per-layer caches.
///
/// When `device_decode` is set and the artifact set has a decode-tail
/// variant wide enough for the horizon, each cache is frozen on the
/// device first and every step uploads only the `[R]` tail (O(1) bytes
/// per step in the cache capacity); otherwise the host path uploads the
/// full cache per layer per step, as before.
fn decode_from_caches(
    engine: &Engine,
    caches: &mut [BlockCache],
    h_last: &HostTensor,
    total_len: usize,
    max_new_tokens: usize,
    device_decode: bool,
) -> Result<(String, usize)> {
    // A step appends at most one row per layer, and the final step never
    // appends: at most max_new_tokens - 1 tail rows per decode.
    let steps = max_new_tokens.saturating_sub(1);
    let tail_r = (device_decode && steps > 0)
        .then(|| engine.manifest.pick_decode_tail(steps))
        .flatten();
    // Freeze lazily, right before the first real decode pass — a decode
    // that terminates on its kick-off logits (immediate EOS) uploads
    // nothing at all, same as the host path.
    let mut frozen = false;

    // Kick-off logits from the participant's final prompt token.
    let mut logits = engine.logits(h_last)?;
    let mut out_ids: Vec<i32> = Vec::new();
    for step in 0..max_new_tokens {
        let next = argmax(&logits);
        if next == tokenizer::EOS {
            break;
        }
        out_ids.push(next);
        if step + 1 == max_new_tokens {
            break;
        }
        if let (Some(r), false) = (tail_r, frozen) {
            for cache in caches.iter_mut() {
                // A previous decode may have part-filled this cache's
                // tail; when the remaining capacity can't fit this
                // horizon, drop the stale prefix so freeze_device
                // re-uploads a fresh one (current cache state, empty
                // tail).
                let len = cache.len;
                let stale = cache
                    .dev
                    .as_ref()
                    .is_some_and(|dev| len - dev.base_len + steps > dev.k_tail.shape()[0]);
                if stale {
                    cache.dev = None;
                }
                cache.freeze_device(engine, r)?;
            }
            frozen = true;
        }
        // One decode pass to produce logits for the following token.
        let pos = (total_len + step) as i32;
        let mut x = engine.embed(&[next])?;
        for (m, cache) in caches.iter_mut().enumerate() {
            let (xo, kn, vn) = match cache.dev.as_ref() {
                Some(dev) => engine.decode_block_tail(
                    m,
                    &x,
                    pos,
                    &dev.k,
                    &dev.v,
                    &dev.mask,
                    &dev.k_tail,
                    &dev.v_tail,
                    &dev.tail_mask,
                )?,
                None => engine.decode_block(m, &x, pos, &cache.k, &cache.v, &cache.dmask)?,
            };
            x = xo;
            cache.push_rows(&kn, &vn, 1, &[true]);
        }
        logits = engine.logits(&x)?;
    }
    Ok((tokenizer::decode(&out_ids), out_ids.len()))
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fedattn::masks::decode_mask;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn block_cache_push_and_overflow() {
        let mut c = BlockCache::new(4, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        let v = k.clone();
        c.push_rows(&k, &v, 2, &[true, false]);
        assert_eq!(c.len, 2);
        assert_eq!(c.visible[..2], [true, false]);
        c.push_rows(&k, &v, 2, &[true, true]);
        assert_eq!(c.len, 4);
    }

    #[test]
    #[should_panic(expected = "decode cache overflow")]
    fn block_cache_overflow_panics() {
        let mut c = BlockCache::new(2, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![0.0; 4]).unwrap();
        c.push_rows(&k, &k.clone(), 2, &[true, true]);
        c.push_rows(&k, &k.clone(), 1, &[true]);
    }

    #[test]
    fn block_cache_incremental_mask_matches_fresh_build() {
        // The per-cache [1, C] mask flips only the newly appended columns
        // on push_rows; it must equal a from-scratch decode_mask build at
        // every state.
        let mut c = BlockCache::new(6, 1, 2);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        let k = HostTensor::new(&[2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        c.push_rows(&k, &k.clone(), 2, &[true, false]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        c.push_rows(&k, &k.clone(), 2, &[false, true]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
        c.push_rows(&k, &k.clone(), 1, &[true]);
        assert_eq!(c.dmask, decode_mask(6, &c.visible));
    }

    #[test]
    fn run_parallel_matches_sequential_and_reports_errors() {
        let pool = Arc::new(Pool::new(3));
        let seq = run_parallel(None, 8, |i| Ok::<usize, String>(i * i)).unwrap();
        let par = run_parallel(Some(&pool), 8, |i| Ok::<usize, String>(i * i)).unwrap();
        assert_eq!(seq, par);
        let err = run_parallel(Some(&pool), 4, |i| {
            if i == 2 {
                Err("boom".to_string())
            } else {
                Ok(i)
            }
        });
        assert!(err.is_err());
    }
}
