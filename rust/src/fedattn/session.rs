//! `FedSession` — the stable session facade.
//!
//! The session layer proper lives in the participant-protocol modules:
//! [`driver`] orchestrates rounds as typed messages ([`protocol`])
//! between per-participant [`node`]s under a pluggable [`aggregate`]
//! policy.  `FedSession` wraps [`SessionDriver`] one-to-one so existing
//! callers (coordinator, benches, examples, golden fixtures) keep their
//! API; its output is byte-identical to the pre-protocol session, which
//! the `session_golden` fixture pins across policies, schedules and
//! worker counts.
//!
//! [`driver`]: crate::fedattn::driver
//! [`protocol`]: crate::fedattn::protocol
//! [`node`]: crate::fedattn::node
//! [`aggregate`]: crate::fedattn::aggregate

use std::sync::Arc;

use anyhow::Result;

use crate::data::Partition;
use crate::exec::Pool;
use crate::net::NetSim;
use crate::runtime::Engine;

pub use crate::fedattn::driver::{
    DecodeHandle, DecodeMachine, DecodeStep, PrefillOutput, SessionConfig, SessionDriver,
    SessionReport,
};

/// Drives one collaborative task through the engine.  Thin facade over
/// [`SessionDriver`]; see the [`driver`] module for the round protocol.
///
/// [`driver`]: crate::fedattn::driver
pub struct FedSession<'a> {
    driver: SessionDriver<'a>,
}

impl<'a> FedSession<'a> {
    pub fn new(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
    ) -> Result<Self> {
        Ok(Self { driver: SessionDriver::new(engine, partition, cfg, net)? })
    }

    /// Run the federated prefill (Alg. 1 lines 2–14).
    pub fn prefill(&mut self) -> Result<PrefillOutput> {
        self.driver.prefill()
    }

    /// Greedy decode from participant `p`'s KV caches (requires that `p`
    /// kept caches).  Returns the decoded text and token count.
    pub fn decode_participant(&mut self, p: usize) -> Result<(String, usize)> {
        self.driver.decode_participant(p)
    }

    /// Decode the task publisher.
    pub fn decode(&mut self) -> Result<(String, usize)> {
        self.driver.decode()
    }

    /// Prefill + decode, returning the full report.
    pub fn run(self) -> Result<SessionReport> {
        self.driver.run()
    }

    /// Prefill only (error-analysis paths that do not decode).
    pub fn run_prefill_only(self) -> Result<PrefillOutput> {
        self.driver.run_prefill_only()
    }

    /// Prefill, then hand the publisher's decode back as a resumable
    /// [`DecodeHandle`] for the serving fabric to drive step by step.
    pub fn into_publisher_decode(self) -> Result<(DecodeHandle, PrefillOutput)> {
        self.driver.into_publisher_decode()
    }

    /// Attach a shared worker pool (e.g. the coordinator's, reused across
    /// tasks) instead of the session-owned one `workers > 1` would spawn.
    /// Pass `workers = 1` in the config when using this to avoid creating
    /// a throwaway pool in [`FedSession::new`].
    pub fn with_shared_pool(self, pool: Arc<Pool>) -> Self {
        Self { driver: self.driver.with_shared_pool(pool) }
    }
}
