//! One collaborative-inference task: the federated prefill (Alg. 1) and the
//! publisher's autoregressive decode over the per-block KV caches (§IV-C).

use anyhow::{Context, Result};

use crate::data::Partition;
use crate::fedattn::kv::GlobalKv;
use crate::fedattn::masks::{decode_mask, global_mask, local_mask};
use crate::fedattn::relevance::{self, RelevanceTracker};
use crate::fedattn::schedule::SyncSchedule;
use crate::fedattn::sparse::{KvExchangePolicy, LocalSparsity, TxContext};
use crate::net::{NetReport, NetSim};
use crate::runtime::Engine;
use crate::tensor::HostTensor;
use crate::tokenizer;
use crate::util::prng::Xoshiro256ss;

/// Session knobs (one FedAttn task).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub schedule: SyncSchedule,
    pub local_sparsity: LocalSparsity,
    pub kv_policy: KvExchangePolicy,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Collect every participant's final hidden states (error analysis /
    /// divergence metrics; costs memory, off for serving).
    pub record_hidden: bool,
    /// Keep KV caches and decode a response for *every* participant (the
    /// paper's Fig. 5 reports mean/min/max EM across participants).  The
    /// default caches and decodes only the task publisher.
    pub decode_all: bool,
    /// Coordinator-allocated per-participant KV row budgets (heterogeneous
    /// links); overrides the budget embedded in budgeted policies.  For
    /// [`KvExchangePolicy::ByteBudget`] with no explicit allocation the
    /// session derives one from the network simulator's link specs.
    pub kv_row_budgets: Option<Vec<usize>>,
}

impl SessionConfig {
    pub fn new(schedule: SyncSchedule) -> Self {
        Self {
            schedule,
            local_sparsity: LocalSparsity::full(),
            kv_policy: KvExchangePolicy::Full,
            max_new_tokens: 12,
            seed: 0,
            record_hidden: false,
            decode_all: false,
            kv_row_budgets: None,
        }
    }
}

/// Per-participant mutable state during prefill.
struct PState {
    /// Global positions of the kept tokens (after local sparsity).
    pos: Vec<i32>,
    /// Padded positions array (`l_pad` long; padding repeats the last pos).
    pos_pad: Vec<i32>,
    valid: usize,
    /// Hidden states `[l_pad, d]`.
    x: HostTensor,
    /// Cached local causal mask (reused across local blocks).
    lmask: HostTensor,
}

/// The publisher's KV cache for one block, sized to the decode-cache
/// capacity `C`.
struct BlockCache {
    k: HostTensor,
    v: HostTensor,
    /// Visibility flags per cache row (for the decode mask).
    visible: Vec<bool>,
    /// Next free row.
    len: usize,
}

impl BlockCache {
    fn new(c: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self {
            k: HostTensor::zeros(&[c, kv_heads, head_dim]),
            v: HostTensor::zeros(&[c, kv_heads, head_dim]),
            visible: vec![false; c],
            len: 0,
        }
    }

    fn push_rows(&mut self, k: &HostTensor, v: &HostTensor, rows: usize, visible: &[bool]) {
        let c = self.k.shape()[0];
        assert!(self.len + rows <= c, "decode cache overflow: {} + {rows} > {c}", self.len);
        self.k.copy_rows_from(k, 0..rows, self.len);
        self.v.copy_rows_from(v, 0..rows, self.len);
        self.visible[self.len..self.len + rows].copy_from_slice(&visible[..rows]);
        self.len += rows;
    }
}

/// Prefill result (before decoding).
pub struct PrefillOutput {
    /// Final hidden states per participant (only when `record_hidden`),
    /// trimmed to valid rows.
    pub hidden: Vec<Option<HostTensor>>,
    /// Positions of each participant's valid tokens.
    pub positions: Vec<Vec<i32>>,
    pub net: NetReport,
    pub wall_ms: f64,
}

/// Full session result.
pub struct SessionReport {
    /// The task publisher's decoded answer.
    pub answer: String,
    pub generated_tokens: usize,
    /// Per-participant answers (only participants that kept caches decode;
    /// others are `None`).  `answers[publisher]` equals `answer`.
    pub answers: Vec<Option<String>>,
    pub net: NetReport,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Final hidden per participant (when `record_hidden`).
    pub hidden: Vec<Option<HostTensor>>,
    pub positions: Vec<Vec<i32>>,
}

/// Drives one collaborative task through the engine.
pub struct FedSession<'a> {
    engine: &'a Engine,
    cfg: SessionConfig,
    parts: Vec<PState>,
    /// `caches[p]` — per-layer KV caches for participant `p`; empty vec for
    /// participants that will not decode.
    caches: Vec<Vec<BlockCache>>,
    net: NetSim,
    rng: Xoshiro256ss,
    publisher: usize,
    total_len: usize,
    /// Per-row attention-mass accumulator (only for relevance policies).
    relevance: Option<RelevanceTracker>,
}

impl<'a> FedSession<'a> {
    pub fn new(
        engine: &'a Engine,
        partition: &'a Partition,
        cfg: SessionConfig,
        net: NetSim,
    ) -> Result<Self> {
        let n = partition.n_participants();
        anyhow::ensure!(net.n_participants() == n, "net sim participant count");
        anyhow::ensure!(cfg.schedule.n_participants() == n, "schedule participant count");
        anyhow::ensure!(
            cfg.schedule.n_blocks() == engine.manifest.model.n_layers,
            "schedule block count"
        );
        let mut rng = Xoshiro256ss::new(cfg.seed ^ 0x5E55_10);
        let md = &engine.manifest.model;

        // Build per-participant state: apply local sparsity, pad, embed.
        let mut parts = Vec::with_capacity(n);
        for p in 0..n {
            let (s, e) = partition.spans[p];
            let span_ids = &partition.ids[s..e];
            // Protect the tail of the publisher (the "A:" anchor) from
            // local-sparsity dropping.
            let protect = if p == partition.publisher() { 3 } else { 0 };
            let keep = cfg.local_sparsity.select(span_ids.len(), protect, &mut rng);
            let ids: Vec<i32> = keep.iter().map(|&i| span_ids[i]).collect();
            let pos: Vec<i32> = keep.iter().map(|&i| (s + i) as i32).collect();
            let l_pad = engine.manifest.pick_l(ids.len())?;
            let mut pos_pad = pos.clone();
            let last = *pos_pad.last().unwrap_or(&0);
            pos_pad.resize(l_pad, last);
            let mut x = HostTensor::zeros(&[l_pad, md.d_model]);
            let emb = engine.embed(&ids)?;
            x.copy_rows_from(&emb, 0..ids.len(), 0);
            let valid = ids.len();
            let lmask = local_mask(&pos_pad, valid);
            parts.push(PState { pos, pos_pad, valid, x, lmask });
        }

        let c = engine.manifest.decode_cache;
        let publisher = partition.publisher();
        let caches: Vec<Vec<BlockCache>> = (0..n)
            .map(|p| {
                if p == publisher || cfg.decode_all {
                    (0..md.n_layers)
                        .map(|_| BlockCache::new(c, md.n_kv_heads, md.head_dim))
                        .collect()
                } else {
                    Vec::new()
                }
            })
            .collect();

        if let Some(b) = &cfg.kv_row_budgets {
            anyhow::ensure!(b.len() == n, "kv_row_budgets length {} != {n}", b.len());
        }
        let relevance = cfg.kv_policy.needs_relevance().then(|| {
            RelevanceTracker::new(&parts.iter().map(|s| s.valid).collect::<Vec<_>>())
        });

        Ok(Self {
            engine,
            cfg,
            parts,
            caches,
            net,
            rng,
            publisher,
            total_len: partition.len(),
            relevance,
        })
    }

    /// Run the federated prefill (Alg. 1 lines 2–14).
    pub fn prefill(&mut self) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let md = self.engine.manifest.model.clone();
        let n = self.parts.len();
        let n_layers = md.n_layers;
        let row_bytes_usize = GlobalKv::row_bytes(md.n_kv_heads, md.head_dim);
        let row_bytes = row_bytes_usize as u64;

        // Budgeted policies: resolve per-participant row budgets once per
        // session.  ByteBudget's total is split across heterogeneous links
        // proportionally to bandwidth unless the coordinator already did.
        let budgets: Option<Vec<usize>> =
            match (&self.cfg.kv_row_budgets, self.cfg.kv_policy) {
                (Some(b), _) => Some(b.clone()),
                (None, KvExchangePolicy::ByteBudget { bytes_per_round }) => {
                    Some(crate::net::allocate_row_budgets(
                        self.net.links(),
                        bytes_per_round / row_bytes_usize.max(1),
                    ))
                }
                _ => None,
            };

        for m in 0..n_layers {
            let attend = self.cfg.schedule.attend[m].clone();
            let any = attend.iter().any(|&b| b);

            if !any {
                // Phase I only: every participant runs a fused local block.
                for p in 0..n {
                    let st = &mut self.parts[p];
                    let (xo, k, v) =
                        self.engine.block_fused(m, &st.x, &st.pos_pad, &st.lmask)?;
                    st.x = xo;
                    if !self.caches[p].is_empty() {
                        let valid = self.parts[p].valid;
                        let vis = vec![true; valid];
                        self.caches[p][m].push_rows(&k, &v, valid, &vis);
                    }
                }
                continue;
            }

            // Sync block: everyone produces (q,)k,v; attendees do global
            // attention over the aggregated KV.
            let mut qs: Vec<Option<HostTensor>> = (0..n).map(|_| None).collect();
            let mut ks: Vec<HostTensor> = Vec::with_capacity(n);
            let mut vs: Vec<HostTensor> = Vec::with_capacity(n);
            for p in 0..n {
                let st = &self.parts[p];
                if attend[p] {
                    let (q, k, v) = self.engine.qkv_project(m, &st.x, &st.pos_pad)?;
                    qs[p] = Some(q);
                    ks.push(k);
                    vs.push(v);
                } else {
                    // Non-attendee: plain local block; its fresh K/V are
                    // what it would transmit to attendees.
                    let (xo, k, v) =
                        self.engine.block_fused(m, &st.x, &st.pos_pad, &st.lmask)?;
                    ks.push(k);
                    vs.push(v);
                    self.parts[p].x = xo;
                }
            }

            // Sparse/adaptive KV exchange: per-participant transmitted-row
            // flags.  Relevance policies see only mass accumulated at
            // *earlier* sync rounds (causal selection).
            let tx_flags: Vec<Vec<bool>> = (0..n)
                .map(|p| {
                    let ctx = TxContext {
                        who: p,
                        publisher: self.publisher,
                        len: self.parts[p].valid,
                        row_bytes: row_bytes_usize,
                        relevance: self.relevance.as_ref().map(|t| t.scores(p)),
                        row_budget: budgets.as_ref().map(|b| b[p]),
                    };
                    self.cfg.kv_policy.transmitted_ctx(&ctx, &mut self.rng)
                })
                .collect();

            // Pack the global KV (Eq. 20).
            let rows_total: usize = self.parts.iter().map(|s| s.valid).sum();
            let g_pad = self.engine.manifest.pick_g(rows_total)?;
            let parts_refs: Vec<_> = (0..n)
                .map(|p| {
                    (
                        &ks[p],
                        &vs[p],
                        self.parts[p].pos.as_slice(),
                        self.parts[p].valid,
                        tx_flags[p].as_slice(),
                    )
                })
                .collect();
            let mut gkv = GlobalKv::pack(&parts_refs, g_pad)?;
            if let Some(tr) = &self.relevance {
                gkv.attach_relevance(tr.all_scores());
            }
            let (kv_pos, kv_owner, kv_tx) = gkv.meta_columns();

            // Communication accounting + simulated transfer time.
            let tx_rows = gkv.tx_rows_by_owner(n);
            let tx_bytes: Vec<u64> =
                tx_rows.iter().map(|&r| r as u64 * row_bytes).collect();
            self.net.exchange_round(&tx_bytes, &attend);

            // Global attention + FFN for attendees (Eq. 21 + 19).  When a
            // relevance policy is active, also accumulate the column
            // marginals of every attendee's attention (row-sum of the
            // attention weights) for the tracker.
            let mut round_mass: Option<Vec<f64>> =
                self.relevance.as_ref().map(|_| vec![0.0; gkv.rows()]);
            for p in 0..n {
                if !attend[p] {
                    continue;
                }
                let st = &self.parts[p];
                let q = qs[p].take().context("missing q for attendee")?;
                let mask = global_mask(
                    &st.pos_pad,
                    st.valid,
                    g_pad,
                    &kv_pos,
                    &kv_owner,
                    &kv_tx,
                    gkv.rows(),
                    p,
                );
                if let Some(acc) = round_mass.as_mut() {
                    let mass =
                        relevance::attention_mass(&q, &gkv.k, &mask, st.valid, gkv.rows());
                    for (a, x) in acc.iter_mut().zip(&mass) {
                        *a += x;
                    }
                }
                let xo = self.engine.attn_ffn(m, &st.x, &q, &gkv.k, &gkv.v, &mask)?;
                self.parts[p].x = xo;
            }
            if let (Some(tr), Some(acc)) = (self.relevance.as_mut(), round_mass) {
                tr.observe(&gkv.meta, &acc);
            }

            // Decode caches for this block (paper §IV-C): participants that
            // attended cache the global KV (restricted to what they could
            // see); others cache their own local KV.
            for p in 0..n {
                if self.caches[p].is_empty() {
                    continue;
                }
                if attend[p] {
                    let vis: Vec<bool> = gkv
                        .meta
                        .iter()
                        .map(|r| r.owner == p || r.transmitted)
                        .collect();
                    self.caches[p][m].push_rows(&gkv.k, &gkv.v, gkv.rows(), &vis);
                } else {
                    let vis = vec![true; self.parts[p].valid];
                    self.caches[p][m].push_rows(&ks[p], &vs[p], self.parts[p].valid, &vis);
                }
            }
        }

        let hidden = self.collect_hidden();
        Ok(PrefillOutput {
            hidden,
            positions: self.parts.iter().map(|s| s.pos.clone()).collect(),
            net: self.net.report().clone(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    fn collect_hidden(&self) -> Vec<Option<HostTensor>> {
        self.parts
            .iter()
            .map(|st| {
                if self.cfg.record_hidden {
                    let mut h = HostTensor::zeros(&[st.valid, st.x.shape()[1]]);
                    h.copy_rows_from(&st.x, 0..st.valid, 0);
                    Some(h)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Greedy decode from participant `p`'s KV caches (requires that `p`
    /// kept caches).  Returns the decoded text and token count.
    pub fn decode_participant(&mut self, p: usize) -> Result<(String, usize)> {
        anyhow::ensure!(!self.caches[p].is_empty(), "participant {p} has no caches");
        let md = self.engine.manifest.model.clone();
        let c = self.engine.manifest.decode_cache;

        // Kick-off logits from the participant's final prompt token.
        let last_row = self.parts[p].valid - 1;
        let mut h_last = HostTensor::zeros(&[1, md.d_model]);
        h_last.copy_rows_from(&self.parts[p].x, last_row..last_row + 1, 0);
        let mut logits = self.engine.logits(&h_last)?;

        let mut out_ids: Vec<i32> = Vec::new();
        for step in 0..self.cfg.max_new_tokens {
            let next = argmax(&logits);
            if next == tokenizer::EOS {
                break;
            }
            out_ids.push(next);
            if step + 1 == self.cfg.max_new_tokens {
                break;
            }
            // One decode pass to produce logits for the following token.
            let pos = (self.total_len + step) as i32;
            let mut x = self.engine.embed(&[next])?;
            for m in 0..md.n_layers {
                let cache = &self.caches[p][m];
                let mask = decode_mask(c, &cache.visible);
                let (xo, kn, vn) =
                    self.engine
                        .decode_block(m, &x, pos, &cache.k, &cache.v, &mask)?;
                x = xo;
                let cache = &mut self.caches[p][m];
                cache.push_rows(&kn, &vn, 1, &[true]);
            }
            logits = self.engine.logits(&x)?;
        }
        Ok((tokenizer::decode(&out_ids), out_ids.len()))
    }

    /// Decode the task publisher.
    pub fn decode(&mut self) -> Result<(String, usize)> {
        self.decode_participant(self.publisher)
    }

    /// Prefill + decode, returning the full report.
    pub fn run(mut self) -> Result<SessionReport> {
        let pre = self.prefill()?;
        let t0 = std::time::Instant::now();
        let n = self.parts.len();
        let mut answers: Vec<Option<String>> = vec![None; n];
        let mut generated = 0usize;
        let mut answer = String::new();
        for p in 0..n {
            if self.caches[p].is_empty() {
                continue;
            }
            let (text, tokens) = self.decode_participant(p)?;
            if p == self.publisher {
                answer = text.clone();
                generated = tokens;
            }
            answers[p] = Some(text);
        }
        Ok(SessionReport {
            answer,
            generated_tokens: generated,
            answers,
            net: self.net.into_report(),
            prefill_ms: pre.wall_ms,
            decode_ms: t0.elapsed().as_secs_f64() * 1e3,
            hidden: pre.hidden,
            positions: pre.positions,
        })
    }

    /// Prefill only (error-analysis paths that do not decode).
    pub fn run_prefill_only(mut self) -> Result<PrefillOutput> {
        self.prefill()
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn block_cache_push_and_overflow() {
        let mut c = BlockCache::new(4, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![1., 2., 3., 4.]).unwrap();
        let v = k.clone();
        c.push_rows(&k, &v, 2, &[true, false]);
        assert_eq!(c.len, 2);
        assert_eq!(c.visible[..2], [true, false]);
        c.push_rows(&k, &v, 2, &[true, true]);
        assert_eq!(c.len, 4);
    }

    #[test]
    #[should_panic(expected = "decode cache overflow")]
    fn block_cache_overflow_panics() {
        let mut c = BlockCache::new(2, 1, 2);
        let k = HostTensor::new(&[2, 1, 2], vec![0.0; 4]).unwrap();
        c.push_rows(&k, &k.clone(), 2, &[true, true]);
        c.push_rows(&k, &k.clone(), 1, &[true]);
    }
}
