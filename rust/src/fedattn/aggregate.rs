//! Pluggable KV aggregation — the FedAvg analogue of the paper's duality.
//!
//! Federated optimization separates *what clients send* (deltas, possibly
//! compressed) from *how the server merges them* (averaging, weighting).
//! FedAttn has the same split (§V): an exchange policy decides which KV
//! rows each participant transmits, and the aggregation step merges the
//! contributions into the global KV every attendee attends over (Eq. 20).
//! The [`Aggregator`] trait packages both halves as one policy object the
//! session driver treats as opaque:
//!
//! * [`ConcatAggregator`] — positional concatenation with a blind
//!   selection policy (`full` / `random` / `publisher-priority` /
//!   `recent-budget`): the FedSGD-style baseline.
//! * [`AdaptiveAggregator`] — relevance-weighted adaptive aggregation
//!   (`top-k-relevance` / `byte-budget`, §V Obs. 4): selection is driven
//!   by accumulated attention mass, and the packed rows carry their
//!   relevance scores so downstream consumers can re-weight.
//!
//! Both merge by packed concatenation (attention is KV-permutation
//! invariant once positions ride along — see [`GlobalKv::pack`]), so the
//! trait's `aggregate` has a shared default; an implementation that
//! actually re-weights or deduplicates rows overrides it.
//!
//! Packing also stamps each merged row's **round-scoped identity**
//! ([`KvRowMeta::row`], the index within its owner's rows): the delta
//! downlink ([`GlobalKvDeltaFrame`]) references aggregated rows by that
//! id so an attendee can retain its own rows from the fresh KV it
//! contributed instead of re-receiving them.
//!
//! [`KvRowMeta::row`]: crate::fedattn::kv::KvRowMeta::row
//! [`GlobalKvDeltaFrame`]: crate::fedattn::protocol::GlobalKvDeltaFrame

use anyhow::Result;

use crate::fedattn::kv::GlobalKv;
use crate::fedattn::sparse::{KvExchangePolicy, TxContext};
use crate::util::prng::Xoshiro256ss;

/// Per-participant inputs to [`Aggregator::aggregate`]: the participant's
/// padded K/V tensors, global positions, valid row count, and transmitted
/// flags — the same tuple [`GlobalKv::pack`] consumes.
pub type PartRows<'a> = (
    &'a crate::tensor::HostTensor,
    &'a crate::tensor::HostTensor,
    &'a [i32],
    usize,
    &'a [bool],
);

/// A KV aggregation policy: row selection + contribution merging.
///
/// Implementations must be deterministic given the RNG handed to
/// [`Aggregator::select`] — the driver's golden fixtures pin aggregation
/// output byte-for-byte across refactors.
pub trait Aggregator: Send + Sync {
    /// The exchange policy this aggregator applies.
    fn policy(&self) -> KvExchangePolicy;

    /// Stable display name (bench labels, logs).
    fn name(&self) -> &'static str {
        self.policy().as_str()
    }

    /// Whether the driver must track per-row attention mass for this
    /// aggregator (adaptive aggregation).
    fn needs_relevance(&self) -> bool {
        self.policy().needs_relevance()
    }

    /// Which of a participant's rows are transmitted this round.  Never
    /// empty for `ctx.len > 0` (the invariant every policy shares).
    fn select(&self, ctx: &TxContext, rng: &mut Xoshiro256ss) -> Vec<bool> {
        self.policy().transmitted_ctx(ctx, rng)
    }

    /// Merge the participants' rows into the padded global KV, stamping
    /// relevance metadata when tracked.  The default is positional
    /// concatenation — the paper's Π_n scatter in packed form.
    fn aggregate(
        &self,
        parts: &[PartRows<'_>],
        g_pad: usize,
        relevance: Option<&[Vec<f64>]>,
    ) -> Result<GlobalKv> {
        let mut gkv = GlobalKv::pack(parts, g_pad)?;
        if let Some(scores) = relevance {
            gkv.attach_relevance(scores);
        }
        Ok(gkv)
    }
}

/// Concatenating aggregation with a blind (relevance-free) selection
/// policy — the federated-inference baseline.
pub struct ConcatAggregator {
    policy: KvExchangePolicy,
}

impl ConcatAggregator {
    /// Rejects relevance-driven policies; those belong to
    /// [`AdaptiveAggregator`].
    pub fn new(policy: KvExchangePolicy) -> Result<Self> {
        anyhow::ensure!(
            !policy.needs_relevance(),
            "{} is relevance-driven; use AdaptiveAggregator",
            policy.as_str()
        );
        Ok(Self { policy })
    }

    /// The Alg. 1 baseline: transmit every row.
    pub fn full() -> Self {
        Self { policy: KvExchangePolicy::Full }
    }
}

impl Aggregator for ConcatAggregator {
    fn policy(&self) -> KvExchangePolicy {
        self.policy
    }
}

/// Relevance-weighted adaptive aggregation (§V Obs. 4): rows are selected
/// by accumulated attention mass and carry their scores in the packed
/// metadata.
pub struct AdaptiveAggregator {
    policy: KvExchangePolicy,
}

impl AdaptiveAggregator {
    /// Rejects blind policies; those belong to [`ConcatAggregator`].
    pub fn new(policy: KvExchangePolicy) -> Result<Self> {
        anyhow::ensure!(
            policy.needs_relevance(),
            "{} is not relevance-driven; use ConcatAggregator",
            policy.as_str()
        );
        Ok(Self { policy })
    }
}

impl Aggregator for AdaptiveAggregator {
    fn policy(&self) -> KvExchangePolicy {
        self.policy
    }
}

/// The aggregator implementing `policy` (the driver's factory).
pub fn for_policy(policy: KvExchangePolicy) -> Box<dyn Aggregator> {
    if policy.needs_relevance() {
        Box::new(AdaptiveAggregator { policy })
    } else {
        Box::new(ConcatAggregator { policy })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::HostTensor;

    #[test]
    fn factory_maps_policies_to_kinds() {
        for policy in [
            KvExchangePolicy::Full,
            KvExchangePolicy::Random { ratio: 0.5 },
            KvExchangePolicy::PublisherPriority { remote_ratio: 0.5 },
            KvExchangePolicy::RecentBudget { budget_rows: 4 },
        ] {
            let a = for_policy(policy);
            assert!(!a.needs_relevance(), "{}", a.name());
            assert!(ConcatAggregator::new(policy).is_ok());
            assert!(AdaptiveAggregator::new(policy).is_err());
        }
        for policy in [
            KvExchangePolicy::TopKRelevance { budget_rows: 4 },
            KvExchangePolicy::ByteBudget { bytes_per_round: 1024 },
        ] {
            let a = for_policy(policy);
            assert!(a.needs_relevance(), "{}", a.name());
            assert!(AdaptiveAggregator::new(policy).is_ok());
            assert!(ConcatAggregator::new(policy).is_err());
        }
    }

    #[test]
    fn select_matches_policy() {
        // The trait's default selection must be the policy's own — the
        // golden fixtures depend on this byte-for-byte.
        let policy = KvExchangePolicy::Random { ratio: 0.4 };
        let agg = for_policy(policy);
        let ctx = TxContext::basic(0, 1, 12);
        let mut r1 = Xoshiro256ss::new(9);
        let mut r2 = Xoshiro256ss::new(9);
        assert_eq!(agg.select(&ctx, &mut r1), policy.transmitted_ctx(&ctx, &mut r2));
    }

    #[test]
    fn aggregate_is_pack_plus_relevance() {
        let mut k = HostTensor::zeros(&[3, 1, 2]);
        for i in 0..3 {
            k.row_mut(i).fill(i as f32);
        }
        let v = k.clone();
        let pos = [0, 1, 2];
        let tx = [true, false, true];
        let parts: Vec<PartRows> = vec![(&k, &v, &pos, 3, &tx)];
        let agg = for_policy(KvExchangePolicy::TopKRelevance { budget_rows: 2 });
        let scores = vec![vec![0.5, 1.5, 2.5]];
        let g = agg.aggregate(&parts, 4, Some(&scores)).unwrap();
        let mut want = GlobalKv::pack(&parts, 4).unwrap();
        want.attach_relevance(&scores);
        assert_eq!(g.k, want.k);
        assert_eq!(g.meta, want.meta);
    }
}
