//! Additive attention-mask builders.
//!
//! All FedAttn semantics that the HLO artifacts do *not* know about —
//! causality by global position, padding validity, cross-participant
//! visibility and sparse-KV-exchange filtering — are carried by these
//! masks, built on the host per block.

use crate::tensor::{HostTensor, NEG_MASK};

/// Local causal mask `[l_pad, l_pad]` for one participant's padded slice.
///
/// `pos[i]` is the *global* position of local row `i`; rows `>= valid` are
/// padding (fully masked, and invisible as keys).
pub fn local_mask(pos: &[i32], valid: usize) -> HostTensor {
    let l = pos.len();
    let mut m = HostTensor::full(&[l, l], NEG_MASK);
    let data = m.data_mut();
    for i in 0..valid {
        for j in 0..valid {
            if pos[j] <= pos[i] {
                data[i * l + j] = 0.0;
            }
        }
    }
    m
}

/// Global-attention mask `[l_pad, g_pad]` for one attending participant.
///
/// * `q_pos` / `q_valid` — the participant's padded query rows.
/// * `kv_pos[j]` — global position of packed KV row `j` (`kv_rows` valid).
/// * `kv_owner[j]` — owning participant of row `j`.
/// * `kv_transmitted[j]` — whether row `j` was actually exchanged this
///   round (sparse KV exchange drops remote rows; own rows are always
///   visible to their owner regardless — paper §VII-B6).
/// * `me` — the attending participant.
#[allow(clippy::too_many_arguments)]
pub fn global_mask(
    q_pos: &[i32],
    q_valid: usize,
    g_pad: usize,
    kv_pos: &[i32],
    kv_owner: &[usize],
    kv_transmitted: &[bool],
    kv_rows: usize,
    me: usize,
) -> HostTensor {
    let l = q_pos.len();
    let mut m = HostTensor::full(&[l, g_pad], NEG_MASK);
    let data = m.data_mut();
    for i in 0..q_valid {
        let pi = q_pos[i];
        let row = &mut data[i * g_pad..(i + 1) * g_pad];
        for j in 0..kv_rows {
            let own = kv_owner[j] == me;
            if kv_pos[j] <= pi && (own || kv_transmitted[j]) {
                row[j] = 0.0;
            }
        }
    }
    m
}

/// Decode-cache mask `[1, c]`: visible rows are the `valid_rows` prefix
/// flagged in `row_visible`.
pub fn decode_mask(c: usize, row_visible: &[bool]) -> HostTensor {
    let mut m = HostTensor::full(&[1, c], NEG_MASK);
    let data = m.data_mut();
    for (j, &vis) in row_visible.iter().enumerate().take(c) {
        if vis {
            data[j] = 0.0;
        }
    }
    m
}

/// Flip one column of a `[1, c]` decode mask to visible, in place — the
/// O(1) incremental counterpart of rebuilding [`decode_mask`] after a
/// cache append (the session keeps one mask per block cache and flips
/// only the newly appended column).
pub fn decode_mask_set_visible(mask: &mut HostTensor, col: usize) {
    mask.data_mut()[col] = 0.0;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn local_mask_is_causal() {
        let pos = [5, 6, 7, 0]; // last row is padding
        let m = local_mask(&pos, 3);
        // row 0 (pos 5) sees only itself among valid rows
        assert_eq!(m.row(0), &[0.0, NEG_MASK, NEG_MASK, NEG_MASK]);
        // row 2 (pos 7) sees all three valid
        assert_eq!(m.row(2), &[0.0, 0.0, 0.0, NEG_MASK]);
        // padding row fully masked
        assert!(m.row(3).iter().all(|&v| v == NEG_MASK));
    }

    #[test]
    fn global_mask_visibility_rules() {
        // q from participant 0 at positions 10,11; kv rows:
        //   j0: own  (p=1,  owner 0, not transmitted)  -> visible (own)
        //   j1: rem  (p=2,  owner 1, transmitted)      -> visible
        //   j2: rem  (p=3,  owner 1, NOT transmitted)  -> hidden (sparse)
        //   j3: rem  (p=12, owner 1, transmitted)      -> hidden (future)
        let m = global_mask(
            &[10, 11],
            2,
            6,
            &[1, 2, 3, 12],
            &[0, 1, 1, 1],
            &[false, true, false, true],
            4,
            0,
        );
        assert_eq!(m.row(0)[..4], [0.0, 0.0, NEG_MASK, NEG_MASK]);
        // padding KV columns hidden
        assert_eq!(m.row(0)[4..], [NEG_MASK, NEG_MASK]);
    }

    #[test]
    fn global_mask_full_exchange_equals_causal() {
        // With everything transmitted and one owner per row, the global mask
        // must be exactly the causal mask over global positions.
        propcheck(50, |rng| {
            let l = 1 + rng.below(16) as usize;
            let g = l;
            let q_pos: Vec<i32> = (0..l as i32).collect();
            let owners: Vec<usize> = (0..g).map(|_| rng.below(3) as usize).collect();
            let tx = vec![true; g];
            let m = global_mask(&q_pos, l, g, &q_pos, &owners, &tx, g, 0);
            for i in 0..l {
                for j in 0..g {
                    let want = if j <= i { 0.0 } else { NEG_MASK };
                    if m.row(i)[j] != want {
                        return Err(format!("({i},{j}) = {}", m.row(i)[j]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_mask_flags() {
        let m = decode_mask(5, &[true, false, true]);
        assert_eq!(m.data(), &[0.0, NEG_MASK, 0.0, NEG_MASK, NEG_MASK]);
    }

    #[test]
    fn incremental_decode_mask_matches_fresh_build() {
        // Start empty, append visibility flags one at a time via the
        // incremental flip; the mask must equal the fresh build at every
        // intermediate state.
        propcheck(40, |rng| {
            let c = 1 + rng.below(24) as usize;
            let mut visible = vec![false; c];
            let mut m = HostTensor::full(&[1, c], NEG_MASK);
            let appended = rng.below(c as u64 + 1) as usize;
            for j in 0..appended {
                let vis = rng.bernoulli(0.7);
                visible[j] = vis;
                if vis {
                    decode_mask_set_visible(&mut m, j);
                }
                if m != decode_mask(c, &visible) {
                    return Err(format!("mask drift after append {j} of {appended}"));
                }
            }
            Ok(())
        });
    }
}
