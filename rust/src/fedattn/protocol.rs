//! Typed round messages for the participant protocol.
//!
//! The paper frames FedAttn as participants that *exchange KV messages*
//! through periodic aggregation (Alg. 1, Eq. 20): local compute, a
//! per-round uplink of selected KV rows, and a downlink of the aggregated
//! frame — the structural dual of federated optimization's model-delta
//! exchange.  This module makes those messages concrete, serializable
//! values instead of implicit shared-memory state:
//!
//! * [`KvContribution`] — one participant's transmitted KV rows for one
//!   sync block (the uplink payload).
//! * [`GlobalKvFrame`] — the aggregated global KV broadcast back to
//!   attendees (the downlink payload).
//! * [`DecodeTail`] — one decode-step KV row append for one block (the
//!   wire form of the device decode tail).
//! * [`TokenBroadcast`] — a decoded token pushed to participants.
//!
//! Every message has a binary `encode`/`decode` pair (little-endian,
//! self-describing header) so a networked deployment can ship it as-is.
//! **Byte accounting is derived from these messages**: the driver feeds
//! [`KvContribution::payload_bytes`] straight into
//! [`NetSim::exchange_round`], making the encoded payload the single
//! source of truth for per-round communication cost.  `payload_bytes`
//! counts the KV data plane only (`rows ×`[`GlobalKv::row_bytes`]`)` —
//! exactly the paper's bits-transmitted metric; the per-row control
//! fields (`pos`, `relevance`) and the fixed header are reported
//! separately by [`KvContribution::control_bytes`].
//!
//! [`NetSim::exchange_round`]: crate::net::NetSim::exchange_round
//! [`GlobalKv::row_bytes`]: crate::fedattn::GlobalKv::row_bytes

use crate::fedattn::kv::{GlobalKv, KvRowMeta};
use crate::tensor::HostTensor;

/// First byte of every encoded protocol message.
pub const WIRE_MAGIC: u8 = 0xFA;
/// Wire format revision; bumped on any layout change.
pub const WIRE_VERSION: u8 = 1;

const TAG_CONTRIBUTION: u8 = 1;
const TAG_FRAME: u8 = 2;
const TAG_DECODE_TAIL: u8 = 3;
const TAG_TOKEN: u8 = 4;

/// Message kind of an encoded protocol frame, as peeked from its header.
///
/// The wire transport multiplexes protocol messages and its own control
/// frames over one stream; receivers peek the kind first and then run the
/// matching typed decoder (which re-validates the full header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireKind {
    Contribution,
    Frame,
    DecodeTail,
    Token,
}

/// Peek the kind of an encoded protocol message from its magic + tag
/// bytes.  Returns `None` for anything that is not a protocol frame
/// (wrong magic, unknown tag, or too short to carry a header); full
/// validation still happens in the typed `decode`.
pub fn wire_kind(b: &[u8]) -> Option<WireKind> {
    if b.len() < 2 || b[0] != WIRE_MAGIC {
        return None;
    }
    match b[1] {
        TAG_CONTRIBUTION => Some(WireKind::Contribution),
        TAG_FRAME => Some(WireKind::Frame),
        TAG_DECODE_TAIL => Some(WireKind::DecodeTail),
        TAG_TOKEN => Some(WireKind::Token),
        _ => None,
    }
}

/// Decode failure for a protocol message.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("wire message truncated at byte {0}")]
    Truncated(usize),
    #[error("bad wire header: expected tag {expected:#04x}, got {got:#04x}")]
    BadTag { expected: u8, got: u8 },
    #[error("unsupported wire version {0}")]
    Version(u8),
    #[error("malformed message: {0}")]
    Malformed(String),
    #[error("{0} trailing bytes after message")]
    Trailing(usize),
}

// ---------------------------------------------------------------------------
// Little-endian writer / reader
// ---------------------------------------------------------------------------

pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(tag: u8, cap_hint: usize) -> Self {
        Self::with_magic(WIRE_MAGIC, tag, cap_hint)
    }

    /// A writer for another magic namespace (the transport's control
    /// frames share this codec but must never collide with protocol
    /// messages).
    pub(crate) fn with_magic(magic: u8, tag: u8, cap_hint: usize) -> Self {
        let mut buf = Vec::with_capacity(cap_hint + HEADER_BYTES);
        buf.push(magic);
        buf.push(tag);
        buf.push(WIRE_VERSION);
        Self { buf }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn i32s(&mut self, xs: &[i32]) {
        for &x in xs {
            self.i32(x);
        }
    }

    pub(crate) fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.f32(x);
        }
    }

    pub(crate) fn bytes(&mut self, xs: &[u8]) {
        self.buf.extend_from_slice(xs);
    }

    pub(crate) fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// `magic + tag + version`.
pub(crate) const HEADER_BYTES: usize = 3;

/// `rows × kv_heads × head_dim` from untrusted header fields, with
/// overflow surfaced as a decode error instead of a silent wrap.
pub(crate) fn row_elems(rows: usize, kv_heads: usize, head_dim: usize) -> Result<usize, WireError> {
    rows.checked_mul(kv_heads)
        .and_then(|x| x.checked_mul(head_dim))
        .ok_or_else(|| WireError::Malformed("row dimensions overflow".into()))
}

pub(crate) struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn open(b: &'a [u8], tag: u8) -> Result<Self, WireError> {
        Self::open_with_magic(b, WIRE_MAGIC, tag)
    }

    /// Open a frame in another magic namespace (see
    /// [`Writer::with_magic`]).
    pub(crate) fn open_with_magic(b: &'a [u8], magic: u8, tag: u8) -> Result<Self, WireError> {
        let mut r = Self { b, pos: 0 };
        let got_magic = r.u8()?;
        if got_magic != magic {
            return Err(WireError::BadTag { expected: magic, got: got_magic });
        }
        let got = r.u8()?;
        if got != tag {
            return Err(WireError::BadTag { expected: tag, got });
        }
        let version = r.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::Version(version));
        }
        Ok(r)
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.b.len() - self.pos {
            return Err(WireError::Truncated(self.b.len()));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Reject a claimed element count before allocating for it: decoders
    /// consume untrusted bytes, so a hostile length field must fail as
    /// `Truncated`/`Malformed`, never as a huge allocation or a silent
    /// `usize` wrap.
    pub(crate) fn ensure_remaining(&self, elems: usize, bytes_per: usize) -> Result<(), WireError> {
        let need = elems
            .checked_mul(bytes_per)
            .ok_or_else(|| WireError::Malformed("length field overflows".into()))?;
        if need > self.b.len() - self.pos {
            return Err(WireError::Truncated(self.b.len()));
        }
        Ok(())
    }

    pub(crate) fn i32s(&mut self, n: usize) -> Result<Vec<i32>, WireError> {
        self.ensure_remaining(n, 4)?;
        (0..n).map(|_| self.i32()).collect()
    }

    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        self.ensure_remaining(n, 4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn done(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Trailing(self.b.len() - self.pos));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// KvContribution — the uplink
// ---------------------------------------------------------------------------

/// One participant's transmitted KV rows for one sync block: the uplink
/// half of a KV-exchange round (Alg. 1 line 8).  Only rows the exchange
/// policy selected ride along; untransmitted rows never leave their owner.
#[derive(Debug, Clone, PartialEq)]
pub struct KvContribution {
    /// Transformer block (sync round) this contribution belongs to.
    pub block: usize,
    /// Contributing participant.
    pub owner: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Global token position of each transmitted row.
    pub pos: Vec<i32>,
    /// Accumulated relevance score of each transmitted row (0 when the
    /// policy does not track relevance).
    pub relevance: Vec<f32>,
    /// Transmitted key rows, packed `[rows × kv_heads × head_dim]`.
    pub k: Vec<f32>,
    /// Transmitted value rows, same layout as `k`.
    pub v: Vec<f32>,
}

impl KvContribution {
    /// Extract the rows flagged in `tx` from a participant's padded
    /// `[l_pad, Hkv, hd]` K/V tensors.  `pos[i]` is local row `i`'s global
    /// position and `relevance` (when tracked) its accumulated score.
    pub fn from_rows(
        block: usize,
        owner: usize,
        k: &HostTensor,
        v: &HostTensor,
        pos: &[i32],
        tx: &[bool],
        relevance: Option<&[f64]>,
    ) -> Self {
        let (kv_heads, head_dim) = (k.shape()[1], k.shape()[2]);
        let rows = tx.iter().filter(|&&b| b).count();
        let mut mpos = Vec::with_capacity(rows);
        let mut mrel = Vec::with_capacity(rows);
        let mut mk = Vec::with_capacity(rows * kv_heads * head_dim);
        let mut mv = Vec::with_capacity(rows * kv_heads * head_dim);
        for (i, &t) in tx.iter().enumerate() {
            if !t {
                continue;
            }
            mpos.push(pos[i]);
            mrel.push(
                relevance.and_then(|r| r.get(i)).map(|&s| s as f32).unwrap_or(0.0),
            );
            mk.extend_from_slice(k.row(i));
            mv.extend_from_slice(v.row(i));
        }
        Self { block, owner, kv_heads, head_dim, pos: mpos, relevance: mrel, k: mk, v: mv }
    }

    /// Transmitted rows in this contribution.
    pub fn rows(&self) -> usize {
        self.pos.len()
    }

    /// **Data-plane bytes** — the K/V row payload, and the value every
    /// round's comm accounting is derived from.  Always equals
    /// `rows() × GlobalKv::row_bytes(kv_heads, head_dim)` (asserted by the
    /// protocol property suite), which is the paper's bits-transmitted
    /// metric.
    pub fn payload_bytes(&self) -> u64 {
        4 * (self.k.len() + self.v.len()) as u64
    }

    /// Control-plane bytes: header + per-row `pos`/`relevance` metadata.
    /// Reported separately; excluded from the round accounting to keep
    /// parity with the paper's metric (≤ 8 B/row, negligible next to the
    /// KV payload).
    pub fn control_bytes(&self) -> u64 {
        (self.encoded_len() as u64) - self.payload_bytes()
    }

    /// Exact length of [`KvContribution::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        HEADER_BYTES + 5 * 4 + self.pos.len() * 8 + (self.k.len() + self.v.len()) * 4
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_CONTRIBUTION, self.encoded_len());
        w.u32(self.block as u32);
        w.u32(self.owner as u32);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.rows() as u32);
        w.i32s(&self.pos);
        w.f32s(&self.relevance);
        w.f32s(&self.k);
        w.f32s(&self.v);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_CONTRIBUTION)?;
        let block = r.u32()? as usize;
        let owner = r.u32()? as usize;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let rows = r.u32()? as usize;
        let elems = row_elems(rows, kv_heads, head_dim)?;
        let pos = r.i32s(rows)?;
        let relevance = r.f32s(rows)?;
        let k = r.f32s(elems)?;
        let v = r.f32s(elems)?;
        r.done()?;
        Ok(Self { block, owner, kv_heads, head_dim, pos, relevance, k, v })
    }
}

// ---------------------------------------------------------------------------
// GlobalKvFrame — the downlink
// ---------------------------------------------------------------------------

/// The aggregated global KV for one sync block, as broadcast to attendees
/// (Eq. 20's packed form + per-row metadata).  Carries *all* packed rows
/// with their `transmitted` flags so each attendee can rebuild the exact
/// visibility mask; on a real wire an attendee only receives the rows it
/// does not already own, which is what [`GlobalKvFrame::payload_bytes_for`]
/// measures.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalKvFrame {
    pub block: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Per packed-row metadata, in [`GlobalKv::pack`] order.
    ///
    /// [`GlobalKv::pack`]: crate::fedattn::GlobalKv::pack
    pub meta: Vec<KvRowMeta>,
    /// Packed key rows `[rows × kv_heads × head_dim]` (padding trimmed).
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl GlobalKvFrame {
    /// Snapshot a packed [`GlobalKv`] (padding rows trimmed off).
    pub fn from_global(block: usize, g: &GlobalKv) -> Self {
        let (kv_heads, head_dim) = (g.k.shape()[1], g.k.shape()[2]);
        let rows = g.rows();
        let row_len = kv_heads * head_dim;
        let mut k = Vec::with_capacity(rows * row_len);
        let mut v = Vec::with_capacity(rows * row_len);
        for i in 0..rows {
            k.extend_from_slice(g.k.row(i));
            v.extend_from_slice(g.v.row(i));
        }
        Self { block, kv_heads, head_dim, meta: g.meta.clone(), k, v }
    }

    /// Rebuild the padded [`GlobalKv`] this frame was taken from.
    pub fn to_global(&self, g_pad: usize) -> Result<GlobalKv, WireError> {
        let rows = self.meta.len();
        if rows > g_pad {
            return Err(WireError::Malformed(format!(
                "{rows} frame rows exceed padded size {g_pad}"
            )));
        }
        let row_len = self.kv_heads * self.head_dim;
        if self.k.len() != rows * row_len || self.v.len() != rows * row_len {
            return Err(WireError::Malformed("k/v length mismatch".into()));
        }
        let mut k = HostTensor::zeros(&[g_pad, self.kv_heads, self.head_dim]);
        let mut v = HostTensor::zeros(&[g_pad, self.kv_heads, self.head_dim]);
        k.data_mut()[..self.k.len()].copy_from_slice(&self.k);
        v.data_mut()[..self.v.len()].copy_from_slice(&self.v);
        Ok(GlobalKv { k, v, meta: self.meta.clone() })
    }

    pub fn rows(&self) -> usize {
        self.meta.len()
    }

    /// Data-plane bytes `attendee` actually receives from this frame: the
    /// transmitted rows of *other* participants (its own rows never cross
    /// the wire).  Matches the `NetSim` downlink accounting
    /// `round_total - own_tx` row for row.
    pub fn payload_bytes_for(&self, attendee: usize) -> u64 {
        let row_bytes = GlobalKv::row_bytes(self.kv_heads, self.head_dim) as u64;
        self.meta
            .iter()
            .filter(|m| m.transmitted && m.owner != attendee)
            .count() as u64
            * row_bytes
    }

    /// Exact length of [`GlobalKvFrame::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        HEADER_BYTES + 4 * 4 + self.meta.len() * 13 + (self.k.len() + self.v.len()) * 4
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_FRAME, self.encoded_len());
        w.u32(self.block as u32);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.meta.len() as u32);
        for m in &self.meta {
            w.i32(m.pos);
            w.u32(m.owner as u32);
            w.u8(m.transmitted as u8);
            w.f32(m.relevance);
        }
        w.f32s(&self.k);
        w.f32s(&self.v);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_FRAME)?;
        let block = r.u32()? as usize;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let rows = r.u32()? as usize;
        let elems = row_elems(rows, kv_heads, head_dim)?;
        r.ensure_remaining(rows, 13)?; // pos + owner + transmitted + relevance
        let mut meta = Vec::with_capacity(rows);
        for _ in 0..rows {
            let pos = r.i32()?;
            let owner = r.u32()? as usize;
            let transmitted = match r.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Malformed(format!(
                        "bad transmitted flag {other}"
                    )))
                }
            };
            let relevance = r.f32()?;
            meta.push(KvRowMeta { pos, owner, transmitted, relevance });
        }
        let k = r.f32s(elems)?;
        let v = r.f32s(elems)?;
        r.done()?;
        Ok(Self { block, kv_heads, head_dim, meta, k, v })
    }
}

// ---------------------------------------------------------------------------
// DecodeTail — per-step cache append
// ---------------------------------------------------------------------------

/// One decode-step KV row append for one block: the wire form of the
/// device decode tail (paper §IV-C).  A networked decode ships one of
/// these per layer per generated token instead of re-sending the cache.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeTail {
    pub block: usize,
    /// Global position of the appended token.
    pub pos: i32,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Appended key row `[kv_heads × head_dim]`.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl DecodeTail {
    pub fn from_row(block: usize, pos: i32, k: &[f32], v: &[f32], kv_heads: usize, head_dim: usize) -> Self {
        debug_assert_eq!(k.len(), kv_heads * head_dim);
        debug_assert_eq!(v.len(), kv_heads * head_dim);
        Self { block, pos, kv_heads, head_dim, k: k.to_vec(), v: v.to_vec() }
    }

    /// Data-plane bytes: one K row + one V row.
    pub fn payload_bytes(&self) -> u64 {
        4 * (self.k.len() + self.v.len()) as u64
    }

    /// Exact length of [`DecodeTail::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        HEADER_BYTES + 4 * 4 + (self.k.len() + self.v.len()) * 4
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_DECODE_TAIL, self.encoded_len());
        w.u32(self.block as u32);
        w.i32(self.pos);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.f32s(&self.k);
        w.f32s(&self.v);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_DECODE_TAIL)?;
        let block = r.u32()? as usize;
        let pos = r.i32()?;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let elems = row_elems(1, kv_heads, head_dim)?;
        let k = r.f32s(elems)?;
        let v = r.f32s(elems)?;
        r.done()?;
        Ok(Self { block, pos, kv_heads, head_dim, k, v })
    }
}

// ---------------------------------------------------------------------------
// TokenBroadcast
// ---------------------------------------------------------------------------

/// A decoded token pushed from the decoding participant to its peers
/// (e.g. streaming the answer back, or driving a collaborative decode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenBroadcast {
    /// Decode step the token was produced at.
    pub step: usize,
    pub token: i32,
}

impl TokenBroadcast {
    /// Exact length of [`TokenBroadcast::encode`]'s output.
    pub const ENCODED_LEN: usize = HEADER_BYTES + 2 * 4;

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_TOKEN, Self::ENCODED_LEN);
        w.u32(self.step as u32);
        w.i32(self.token);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_TOKEN)?;
        let step = r.u32()? as usize;
        let token = r.i32()?;
        r.done()?;
        Ok(Self { step, token })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(rows: usize, hkv: usize, hd: usize, base: f32) -> HostTensor {
        let mut t = HostTensor::zeros(&[rows, hkv, hd]);
        for i in 0..rows {
            t.row_mut(i).fill(base + i as f32);
        }
        t
    }

    #[test]
    fn contribution_extracts_flagged_rows() {
        let k = tensor(4, 2, 3, 10.0);
        let v = tensor(4, 2, 3, -10.0);
        let pos = [5, 6, 7, 8];
        let tx = [true, false, true, false];
        let rel = [0.25f64, 0.5, 0.75, 1.0];
        let c = KvContribution::from_rows(2, 1, &k, &v, &pos, &tx, Some(&rel));
        assert_eq!(c.rows(), 2);
        assert_eq!(c.pos, vec![5, 7]);
        assert_eq!(c.relevance, vec![0.25, 0.75]);
        assert_eq!(&c.k[..6], k.row(0));
        assert_eq!(&c.k[6..], k.row(2));
        assert_eq!(c.payload_bytes(), 2 * GlobalKv::row_bytes(2, 3) as u64);
    }

    #[test]
    fn contribution_roundtrip_and_lengths() {
        let k = tensor(3, 1, 2, 1.0);
        let c = KvContribution::from_rows(
            0,
            2,
            &k,
            &k.clone(),
            &[0, 1, 2],
            &[true, true, false],
            None,
        );
        let bytes = c.encode();
        assert_eq!(bytes.len(), c.encoded_len());
        assert_eq!(KvContribution::decode(&bytes).unwrap(), c);
        assert_eq!(c.payload_bytes() + c.control_bytes(), bytes.len() as u64);
    }

    #[test]
    fn frame_roundtrip_through_global_kv() {
        let k = tensor(3, 1, 2, 1.0);
        let pos = [0, 1, 2];
        let tx = [true, false, true];
        let g = GlobalKv::pack(&[(&k, &k.clone(), &pos, 3, &tx)], 5).unwrap();
        let f = GlobalKvFrame::from_global(4, &g);
        assert_eq!(f.rows(), 3);
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        let f2 = GlobalKvFrame::decode(&bytes).unwrap();
        assert_eq!(f2, f);
        let g2 = f2.to_global(5).unwrap();
        assert_eq!(g2.k, g.k);
        assert_eq!(g2.v, g.v);
        assert_eq!(g2.meta, g.meta);
        // rows not transmitted or owned by the attendee do not cross the
        // wire: owner 0 receives nothing of its own rows.
        assert_eq!(f.payload_bytes_for(0), 0);
        assert_eq!(f.payload_bytes_for(1), 2 * GlobalKv::row_bytes(1, 2) as u64);
    }

    #[test]
    fn decode_tail_and_token_roundtrip() {
        let t = DecodeTail::from_row(3, 17, &[1.0, 2.0], &[3.0, 4.0], 1, 2);
        let bytes = t.encode();
        assert_eq!(bytes.len(), t.encoded_len());
        assert_eq!(DecodeTail::decode(&bytes).unwrap(), t);
        assert_eq!(t.payload_bytes(), GlobalKv::row_bytes(1, 2) as u64);

        let tb = TokenBroadcast { step: 9, token: -1 };
        let bytes = tb.encode();
        assert_eq!(bytes.len(), TokenBroadcast::ENCODED_LEN);
        assert_eq!(TokenBroadcast::decode(&bytes).unwrap(), tb);
    }

    #[test]
    fn decode_rejects_garbage() {
        let tb = TokenBroadcast { step: 1, token: 2 }.encode();
        // truncated
        assert!(matches!(
            TokenBroadcast::decode(&tb[..tb.len() - 1]),
            Err(WireError::Truncated(_))
        ));
        // wrong tag for the decoder
        assert!(matches!(
            KvContribution::decode(&tb),
            Err(WireError::BadTag { .. })
        ));
        // trailing bytes
        let mut long = tb.clone();
        long.push(0);
        assert!(matches!(TokenBroadcast::decode(&long), Err(WireError::Trailing(1))));
        // bad version
        let mut bad = tb.clone();
        bad[2] = 99;
        assert!(matches!(TokenBroadcast::decode(&bad), Err(WireError::Version(99))));
        // bad magic
        let mut bad = tb;
        bad[0] = 0;
        assert!(matches!(TokenBroadcast::decode(&bad), Err(WireError::BadTag { .. })));
    }

    #[test]
    fn decode_rejects_hostile_length_fields() {
        // A ~19-byte frame claiming u32::MAX rows must fail cleanly
        // (Truncated) before any row-sized allocation happens.
        let mut msg = vec![WIRE_MAGIC, TAG_FRAME, WIRE_VERSION];
        for field in [7u32, 1, 1, u32::MAX] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        assert!(matches!(
            GlobalKvFrame::decode(&msg),
            Err(WireError::Truncated(_))
        ));
        // All-max dimensions overflow usize: must be Malformed, not a
        // silent wrap that "successfully" decodes inconsistent lengths.
        let mut msg = vec![WIRE_MAGIC, TAG_CONTRIBUTION, WIRE_VERSION];
        for field in [0u32, 0, u32::MAX, u32::MAX, u32::MAX] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        assert!(matches!(
            KvContribution::decode(&msg),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn wire_kind_peeks_tags() {
        let tb = TokenBroadcast { step: 0, token: 1 }.encode();
        assert_eq!(wire_kind(&tb), Some(WireKind::Token));
        let t = DecodeTail::from_row(0, 0, &[1.0], &[2.0], 1, 1).encode();
        assert_eq!(wire_kind(&t), Some(WireKind::DecodeTail));
        assert_eq!(wire_kind(&[]), None);
        assert_eq!(wire_kind(&[WIRE_MAGIC]), None);
        assert_eq!(wire_kind(&[WIRE_MAGIC, 99]), None);
        assert_eq!(wire_kind(&[0x00, TAG_TOKEN]), None);
    }

    #[test]
    fn frame_to_global_validates() {
        let k = tensor(2, 1, 2, 0.0);
        let g = GlobalKv::pack(&[(&k, &k.clone(), &[0, 1], 2, &[true, true])], 2).unwrap();
        let f = GlobalKvFrame::from_global(0, &g);
        assert!(f.to_global(1).is_err()); // rows exceed padding
        let mut broken = f.clone();
        broken.k.pop();
        assert!(broken.to_global(4).is_err());
    }
}
