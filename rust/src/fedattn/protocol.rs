//! Typed round messages for the participant protocol.
//!
//! The paper frames FedAttn as participants that *exchange KV messages*
//! through periodic aggregation (Alg. 1, Eq. 20): local compute, a
//! per-round uplink of selected KV rows, and a downlink of the aggregated
//! frame — the structural dual of federated optimization's model-delta
//! exchange.  This module makes those messages concrete, serializable
//! values instead of implicit shared-memory state:
//!
//! * [`KvContribution`] — one participant's transmitted KV rows for one
//!   sync block (the uplink payload).
//! * [`GlobalKvFrame`] — the aggregated global KV broadcast back to
//!   attendees (the downlink payload).
//! * [`GlobalKvDeltaFrame`] — the incremental downlink: only the rows an
//!   attendee does not already hold ship; its own rows ride as a
//!   retain-list of round-scoped row ids it resolves against the fresh
//!   KV it contributed this round, and untransmitted remote rows (which
//!   the attendee may never see — they are masked) are elided entirely.
//! * [`DecodeTail`] — one decode-step KV row append for one block (the
//!   wire form of the device decode tail).
//! * [`TokenBroadcast`] — a decoded token pushed to participants.
//!
//! Every message has a binary `encode`/`decode` pair (little-endian,
//! self-describing header) so a networked deployment can ship it as-is.
//! **Byte accounting is derived from these messages**: the driver feeds
//! [`KvContribution::payload_bytes`] straight into
//! [`NetSim::exchange_round`], making the encoded payload the single
//! source of truth for per-round communication cost.  `payload_bytes`
//! counts the KV data plane only (`rows ×`[`GlobalKv::row_bytes`]`)` —
//! exactly the paper's bits-transmitted metric; the per-row control
//! fields (`pos`, `relevance`) and the fixed header are reported
//! separately by [`KvContribution::control_bytes`].
//!
//! [`NetSim::exchange_round`]: crate::net::NetSim::exchange_round
//! [`GlobalKv::row_bytes`]: crate::fedattn::GlobalKv::row_bytes

use crate::fedattn::kv::{GlobalKv, KvRowMeta};
use crate::tensor::HostTensor;

/// First byte of every encoded protocol message.
pub const WIRE_MAGIC: u8 = 0xFA;
/// Wire format revision; bumped on any layout change.
pub const WIRE_VERSION: u8 = 1;
/// Wire revision for quantized KV payloads: the header grows a precision
/// byte and the K/V data plane ships reduced-precision rows (per-row
/// absmax scales for int8).  `f32` messages always encode as version 1 —
/// byte-identical to the pre-quantization wire — so version 2 appears on
/// the wire only when a session opts in via `kv_precision`.
pub const WIRE_VERSION_QUANT: u8 = 2;

/// Wire precision of K/V row payloads (`federation.kv_precision` /
/// `--kv-precision`).  Applies to the data plane of [`KvContribution`],
/// [`GlobalKvFrame`] and [`GlobalKvDeltaFrame`] (including the `Resync`
/// replay frames, which are encoded downlink frames); control fields
/// (`pos`, relevance, row metadata, retain-lists) always stay exact.
///
/// * `F32` — the legacy exact wire; encodes as version-1 bytes.
/// * `F16` — IEEE 754 half per element (2 B), saturating on overflow.
/// * `Int8` — symmetric per-row absmax quantization: each K and V row
///   carries one f32 scale (`absmax / 127`) and 1 B per element.
///
/// Decoded messages always hold dequantized f32 values; quantization is
/// an encode-time transform, so everything downstream of a decode (pack,
/// attention, fresh-KV caches, delta reassembly) operates on f32 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvPrecision {
    #[default]
    F32,
    F16,
    Int8,
}

impl KvPrecision {
    /// Canonical knob spelling (TOML / CLI / bench reports).
    pub fn as_str(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::F16 => "f16",
            KvPrecision::Int8 => "int8",
        }
    }

    /// Parse the knob spelling; `None` for anything unknown (callers
    /// report the loud error with their own context).
    pub fn from_str_opt(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(KvPrecision::F32),
            "f16" | "fp16" => Some(KvPrecision::F16),
            "int8" | "i8" => Some(KvPrecision::Int8),
            _ => None,
        }
    }

    /// The precision byte carried in a version-2 header.  `F32` has no
    /// wire byte: it must encode as version 1.
    pub(crate) fn wire_byte(self) -> u8 {
        match self {
            KvPrecision::F32 => 0,
            KvPrecision::F16 => 1,
            KvPrecision::Int8 => 2,
        }
    }

    pub(crate) fn from_wire_byte(b: u8) -> Result<Self, WireError> {
        match b {
            1 => Ok(KvPrecision::F16),
            2 => Ok(KvPrecision::Int8),
            other => Err(WireError::Malformed(format!("bad precision byte {other}"))),
        }
    }

    /// Bytes per element of the K/V data plane (scales excluded).
    pub fn elem_bytes(self) -> usize {
        match self {
            KvPrecision::F32 => 4,
            KvPrecision::F16 => 2,
            KvPrecision::Int8 => 1,
        }
    }

    /// **Wire bytes of one K+V row pair** at this precision — the
    /// quantized analogue of [`GlobalKv::row_bytes`] (which stays the
    /// in-memory f32 metric).  Int8 includes the two per-row f32 scales,
    /// so byte accounting follows what actually ships.
    pub fn wire_row_bytes(self, kv_heads: usize, head_dim: usize) -> usize {
        let elems = 2 * kv_heads * head_dim;
        match self {
            KvPrecision::F32 => elems * 4,
            KvPrecision::F16 => elems * 2,
            KvPrecision::Int8 => elems + 2 * 4,
        }
    }
}

// ---------------------------------------------------------------------------
// Quantization primitives
// ---------------------------------------------------------------------------

/// Convert f32 to IEEE 754 half bits, round-to-nearest-even, *saturating*
/// at ±65504 instead of producing infinities (a finite KV row must stay
/// finite on the wire — decoders reject non-finite payloads).  NaN maps
/// to zero: fresh KV data is always finite, and a total conversion keeps
/// the encoder panic-free on any input.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    if x.is_nan() {
        return 0;
    }
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let ax = x.abs();
    if ax > 65504.0 {
        return sign | 0x7BFF; // saturate at f16::MAX
    }
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    let e = exp - 127 + 15;
    if e >= 1 {
        // Normal half: round mantissa 23 -> 10 bits to nearest-even.
        let mut m = man >> 13;
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = e as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
        }
        if he >= 31 {
            return sign | 0x7BFF; // rounded into overflow: saturate
        }
        sign | ((he as u16) << 10) | (m as u16)
    } else {
        // Subnormal half (or zero): shift the implicit bit down into the
        // 10-bit mantissa, rounding to nearest-even.  A carry out of the
        // mantissa (m == 0x400) lands exactly on the smallest normal
        // half's bit pattern, so it needs no special case.
        if exp == 0 && man == 0 {
            return sign; // ±0
        }
        let full = man | 0x0080_0000;
        let sh = (13 + (1 - e)) as u32;
        if sh >= 32 {
            return sign; // underflows to zero
        }
        let mut m = full >> sh;
        let rem = full & ((1u32 << sh) - 1);
        let half = 1u32 << (sh - 1);
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        sign | (m as u16)
    }
}

/// Convert IEEE 754 half bits to f32 (exact: every half value is
/// representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal half: renormalize into an f32 exponent.
            let mut e = 127 - 15 + 1;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3FF) << 13)
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Per-row symmetric absmax scale for int8: the smallest **power of
/// two** `≥ absmax / 127`, or zero for an all-zero (or degenerate
/// subnormal) row.
///
/// Power-of-two scales cost at most one extra bit of quantization error
/// versus raw `absmax / 127`, and buy an exactness property the value
/// plane depends on: `q × scale` is exact in IEEE arithmetic, and
/// re-quantizing an already-quantized row reproduces it bit-for-bit
/// ([`requantize_row`] is idempotent).  The driver's packed global KV
/// holds *decoded* (already-quantized) contribution rows, and the
/// downlink re-encodes them — without idempotence that second pass
/// would drift the values attendees see away from what the in-process
/// reference computes.
pub fn int8_row_scale(row: &[f32]) -> f32 {
    let absmax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if absmax == 0.0 || !absmax.is_finite() {
        return 0.0;
    }
    let t = absmax / 127.0;
    if t < f32::MIN_POSITIVE {
        return 0.0; // rows this small round to zero at any int8 scale
    }
    // Smallest power of two >= t, via exponent extraction (t is a
    // positive normal here, so the biased exponent is authoritative).
    let bits = t.to_bits();
    let mut e = ((bits >> 23) & 0xFF) as i32 - 127;
    if bits & 0x007F_FFFF != 0 {
        e += 1;
    }
    // 127 × 2^121 is the largest level range that stays finite.
    f32::powi(2.0, e.min(121))
}

/// A decoded int8 scale must be zero or a positive normal small enough
/// that `127 × scale` stays finite — anything else (NaN, ±inf, negative,
/// subnormal, overflow-range) is a hostile or corrupt frame.
fn validate_scale(s: f32) -> Result<(), WireError> {
    if s == 0.0 || (s.is_finite() && s >= f32::MIN_POSITIVE && s <= f32::MAX / 127.0) {
        Ok(())
    } else {
        Err(WireError::Malformed(format!("hostile int8 scale {s:e}")))
    }
}

#[inline]
fn quant_i8(x: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// Apply one encode→decode round-trip to a row *in place*: the value the
/// far side of the wire would see.  The in-process session applies this
/// to every transmitted row so a quantized wire session and the
/// in-process reference stay transcript-identical; node hosts apply it
/// to their own transmitted rows when restoring them from the fresh-KV
/// cache (their raw copy never crossed the wire, but every peer sees the
/// quantized one, and attention must agree).  `F32` is the identity.
pub fn requantize_row(row: &mut [f32], precision: KvPrecision) {
    match precision {
        KvPrecision::F32 => {}
        KvPrecision::F16 => {
            for x in row.iter_mut() {
                *x = f16_bits_to_f32(f32_to_f16_bits(*x));
            }
        }
        KvPrecision::Int8 => {
            let s = int8_row_scale(row);
            for x in row.iter_mut() {
                *x = quant_i8(*x, s) as f32 * s;
            }
        }
    }
}

const TAG_CONTRIBUTION: u8 = 1;
const TAG_FRAME: u8 = 2;
const TAG_DECODE_TAIL: u8 = 3;
const TAG_TOKEN: u8 = 4;
const TAG_DELTA_FRAME: u8 = 5;

/// Message kind of an encoded protocol frame, as peeked from its header.
///
/// The wire transport multiplexes protocol messages and its own control
/// frames over one stream; receivers peek the kind first and then run the
/// matching typed decoder (which re-validates the full header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireKind {
    Contribution,
    Frame,
    DecodeTail,
    Token,
    DeltaFrame,
}

/// Peek the kind of an encoded protocol message from its magic + tag
/// bytes.  Returns `None` for anything that is not a protocol frame
/// (wrong magic, unknown tag, or too short to carry a header); full
/// validation still happens in the typed `decode`.
pub fn wire_kind(b: &[u8]) -> Option<WireKind> {
    if b.len() < 2 || b[0] != WIRE_MAGIC {
        return None;
    }
    match b[1] {
        TAG_CONTRIBUTION => Some(WireKind::Contribution),
        TAG_FRAME => Some(WireKind::Frame),
        TAG_DECODE_TAIL => Some(WireKind::DecodeTail),
        TAG_TOKEN => Some(WireKind::Token),
        TAG_DELTA_FRAME => Some(WireKind::DeltaFrame),
        _ => None,
    }
}

/// Decode failure for a protocol message.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("wire message truncated at byte {0}")]
    Truncated(usize),
    #[error("bad wire header: expected tag {expected:#04x}, got {got:#04x}")]
    BadTag { expected: u8, got: u8 },
    #[error("unsupported wire version {0}")]
    Version(u8),
    #[error("malformed message: {0}")]
    Malformed(String),
    #[error("{0} trailing bytes after message")]
    Trailing(usize),
}

// ---------------------------------------------------------------------------
// Little-endian writer / reader
// ---------------------------------------------------------------------------

pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(tag: u8, cap_hint: usize) -> Self {
        Self::with_magic(WIRE_MAGIC, tag, cap_hint)
    }

    /// A writer for another magic namespace (the transport's control
    /// frames share this codec but must never collide with protocol
    /// messages).
    pub(crate) fn with_magic(magic: u8, tag: u8, cap_hint: usize) -> Self {
        Self::with_magic_version(magic, tag, WIRE_VERSION, cap_hint)
    }

    /// A writer with an explicit header version byte (the quantized KV
    /// layouts and the precision-carrying control frames write
    /// [`WIRE_VERSION_QUANT`]; everything else stays on version 1).
    pub(crate) fn with_magic_version(magic: u8, tag: u8, version: u8, cap_hint: usize) -> Self {
        let mut buf = Vec::with_capacity(cap_hint + HEADER_BYTES);
        buf.push(magic);
        buf.push(tag);
        buf.push(version);
        Self { buf }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// 8-byte LE f64 — bit-preserving, so f64 payloads (per-row attention
    /// masses) survive a wire round-trip exactly.
    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn i32s(&mut self, xs: &[i32]) {
        for &x in xs {
            self.i32(x);
        }
    }

    pub(crate) fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.f32(x);
        }
    }

    pub(crate) fn f64s(&mut self, xs: &[f64]) {
        for &x in xs {
            self.f64(x);
        }
    }

    pub(crate) fn bytes(&mut self, xs: &[u8]) {
        self.buf.extend_from_slice(xs);
    }

    /// f32 values down-converted to IEEE half on the wire (2 B each).
    fn f16s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
    }

    /// Row-major values quantized to int8 against per-row scales (1 B per
    /// element; `scales[r]` covers `xs[r*row_len..(r+1)*row_len]`).
    fn i8_rows(&mut self, xs: &[f32], row_len: usize, scales: &[f32]) {
        debug_assert_eq!(xs.len(), scales.len() * row_len);
        for (r, &s) in scales.iter().enumerate() {
            for &x in &xs[r * row_len..(r + 1) * row_len] {
                self.buf.push(quant_i8(x, s) as u8);
            }
        }
    }

    pub(crate) fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// `magic + tag + version`.
pub(crate) const HEADER_BYTES: usize = 3;

/// `rows × kv_heads × head_dim` from untrusted header fields, with
/// overflow surfaced as a decode error instead of a silent wrap.
pub(crate) fn row_elems(rows: usize, kv_heads: usize, head_dim: usize) -> Result<usize, WireError> {
    rows.checked_mul(kv_heads)
        .and_then(|x| x.checked_mul(head_dim))
        .ok_or_else(|| WireError::Malformed("row dimensions overflow".into()))
}

pub(crate) struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn open(b: &'a [u8], tag: u8) -> Result<Self, WireError> {
        Self::open_with_magic(b, WIRE_MAGIC, tag)
    }

    /// Open a frame in another magic namespace (see
    /// [`Writer::with_magic`]).
    pub(crate) fn open_with_magic(b: &'a [u8], magic: u8, tag: u8) -> Result<Self, WireError> {
        let (r, version) = Self::open_with_magic_versioned(b, magic, tag)?;
        if version != WIRE_VERSION {
            return Err(WireError::Version(version));
        }
        Ok(r)
    }

    /// Open a frame accepting either wire version, returning the version
    /// byte so the caller can dispatch on the layout.  Only the KV
    /// messages (and the precision-carrying control frames) have a
    /// version-2 layout; every other decoder keeps the strict
    /// [`Reader::open_with_magic`] and rejects version 2 outright.
    pub(crate) fn open_with_magic_versioned(
        b: &'a [u8],
        magic: u8,
        tag: u8,
    ) -> Result<(Self, u8), WireError> {
        let mut r = Self { b, pos: 0 };
        let got_magic = r.u8()?;
        if got_magic != magic {
            return Err(WireError::BadTag { expected: magic, got: got_magic });
        }
        let got = r.u8()?;
        if got != tag {
            return Err(WireError::BadTag { expected: tag, got });
        }
        let version = r.u8()?;
        if version != WIRE_VERSION && version != WIRE_VERSION_QUANT {
            return Err(WireError::Version(version));
        }
        Ok((r, version))
    }

    /// Open a KV message header: version 1 is the legacy f32 layout;
    /// version 2 carries a precision byte (`f16`/`int8` only — an `f32`
    /// message must be version 1, so there is exactly one encoding of
    /// every message and decode stays canonical).
    fn open_quant(b: &'a [u8], tag: u8) -> Result<(Self, KvPrecision), WireError> {
        let (mut r, version) = Self::open_with_magic_versioned(b, WIRE_MAGIC, tag)?;
        if version == WIRE_VERSION {
            return Ok((r, KvPrecision::F32));
        }
        let precision = KvPrecision::from_wire_byte(r.u8()?)?;
        Ok((r, precision))
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.b.len() - self.pos {
            return Err(WireError::Truncated(self.b.len()));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Reject a claimed element count before allocating for it: decoders
    /// consume untrusted bytes, so a hostile length field must fail as
    /// `Truncated`/`Malformed`, never as a huge allocation or a silent
    /// `usize` wrap.
    pub(crate) fn ensure_remaining(&self, elems: usize, bytes_per: usize) -> Result<(), WireError> {
        let need = elems
            .checked_mul(bytes_per)
            .ok_or_else(|| WireError::Malformed("length field overflows".into()))?;
        if need > self.b.len() - self.pos {
            return Err(WireError::Truncated(self.b.len()));
        }
        Ok(())
    }

    pub(crate) fn i32s(&mut self, n: usize) -> Result<Vec<i32>, WireError> {
        self.ensure_remaining(n, 4)?;
        (0..n).map(|_| self.i32()).collect()
    }

    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        self.ensure_remaining(n, 4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub(crate) fn f64s(&mut self, n: usize) -> Result<Vec<f64>, WireError> {
        self.ensure_remaining(n, 8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    /// `n` IEEE-half payload values, dequantized to f32.  Non-finite
    /// halves are rejected: a finite KV row can never encode one (the
    /// encoder saturates), so inf/NaN here means a hostile or corrupt
    /// frame — and rejecting them keeps decode canonical (every accepted
    /// half re-encodes to its exact wire bits).
    fn f16s_dequant(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        self.ensure_remaining(n, 2)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let bits = u16::from_le_bytes(self.take(2)?.try_into().unwrap());
            let x = f16_bits_to_f32(bits);
            if !x.is_finite() {
                return Err(WireError::Malformed("non-finite f16 payload".into()));
            }
            out.push(x);
        }
        Ok(out)
    }

    /// Per-row int8 scales followed by validation: each must be zero or a
    /// positive normal with `127 × scale` finite (see [`validate_scale`]).
    fn i8_scales(&mut self, rows: usize) -> Result<Vec<f32>, WireError> {
        let scales = self.f32s(rows)?;
        for &s in &scales {
            validate_scale(s)?;
        }
        Ok(scales)
    }

    /// Row-major int8 payload dequantized against per-row scales.
    /// Rejects `-128` (its dequantized value cannot re-encode to itself
    /// under the symmetric ±127 clamp, which would break canonical
    /// decode) and any nonzero level under a zero scale (a zero-scale row
    /// is all-zero by construction).
    fn i8_rows_dequant(
        &mut self,
        row_len: usize,
        scales: &[f32],
    ) -> Result<Vec<f32>, WireError> {
        let n = scales
            .len()
            .checked_mul(row_len)
            .ok_or_else(|| WireError::Malformed("int8 payload overflows".into()))?;
        self.ensure_remaining(n, 1)?;
        let mut out = Vec::with_capacity(n);
        for &s in scales {
            for &b in self.take(row_len)? {
                let q = b as i8;
                if q == i8::MIN {
                    return Err(WireError::Malformed("int8 level -128".into()));
                }
                if s == 0.0 && q != 0 {
                    return Err(WireError::Malformed(
                        "nonzero int8 level under zero scale".into(),
                    ));
                }
                out.push(q as f32 * s);
            }
        }
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn done(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Trailing(self.b.len() - self.pos));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// KvContribution — the uplink
// ---------------------------------------------------------------------------

/// One participant's transmitted KV rows for one sync block: the uplink
/// half of a KV-exchange round (Alg. 1 line 8).  Only rows the exchange
/// policy selected ride along; untransmitted rows never leave their owner.
#[derive(Debug, Clone, PartialEq)]
pub struct KvContribution {
    /// Transformer block (sync round) this contribution belongs to.
    pub block: usize,
    /// Contributing participant.
    pub owner: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Global token position of each transmitted row.
    pub pos: Vec<i32>,
    /// Accumulated relevance score of each transmitted row (0 when the
    /// policy does not track relevance).
    pub relevance: Vec<f32>,
    /// Transmitted key rows, packed `[rows × kv_heads × head_dim]`.
    /// Always dequantized f32 — quantization is an encode-time transform.
    pub k: Vec<f32>,
    /// Transmitted value rows, same layout as `k`.
    pub v: Vec<f32>,
    /// Wire precision of the K/V payload.  Fresh messages default to
    /// `F32`; senders set it from the session's `kv_precision` before
    /// encoding, and decode records what the wire actually carried so
    /// byte accounting follows the quantized sizes.
    pub precision: KvPrecision,
    /// Per-row int8 dequantization scales exactly as decoded from the
    /// wire (empty unless this message was decoded from an int8 frame).
    /// Re-encoding reuses them so decode→encode is bit-exact; recomputing
    /// a scale from dequantized data is not (floating-point `absmax/127`
    /// of `q·s` values need not reproduce `s`).
    pub qscale_k: Vec<f32>,
    pub qscale_v: Vec<f32>,
}

impl KvContribution {
    /// Extract the rows flagged in `tx` from a participant's padded
    /// `[l_pad, Hkv, hd]` K/V tensors.  `pos[i]` is local row `i`'s global
    /// position and `relevance` (when tracked) its accumulated score.
    pub fn from_rows(
        block: usize,
        owner: usize,
        k: &HostTensor,
        v: &HostTensor,
        pos: &[i32],
        tx: &[bool],
        relevance: Option<&[f64]>,
    ) -> Self {
        let (kv_heads, head_dim) = (k.shape()[1], k.shape()[2]);
        let rows = tx.iter().filter(|&&b| b).count();
        let mut mpos = Vec::with_capacity(rows);
        let mut mrel = Vec::with_capacity(rows);
        let mut mk = Vec::with_capacity(rows * kv_heads * head_dim);
        let mut mv = Vec::with_capacity(rows * kv_heads * head_dim);
        for (i, &t) in tx.iter().enumerate() {
            if !t {
                continue;
            }
            mpos.push(pos[i]);
            mrel.push(
                relevance.and_then(|r| r.get(i)).map(|&s| s as f32).unwrap_or(0.0),
            );
            mk.extend_from_slice(k.row(i));
            mv.extend_from_slice(v.row(i));
        }
        Self {
            block,
            owner,
            kv_heads,
            head_dim,
            pos: mpos,
            relevance: mrel,
            k: mk,
            v: mv,
            precision: KvPrecision::F32,
            qscale_k: Vec::new(),
            qscale_v: Vec::new(),
        }
    }

    /// Set the wire precision (builder-style, for senders).
    pub fn with_precision(mut self, precision: KvPrecision) -> Self {
        self.precision = precision;
        self
    }

    /// Transmitted rows in this contribution.
    pub fn rows(&self) -> usize {
        self.pos.len()
    }

    /// **Data-plane bytes** — the K/V row payload *as it ships*, and the
    /// value every round's comm accounting is derived from.  Always
    /// equals `rows() × precision.wire_row_bytes(kv_heads, head_dim)`
    /// (asserted by the protocol property suite); at `F32` that is
    /// `rows() × GlobalKv::row_bytes`, the paper's bits-transmitted
    /// metric, and at reduced precision it follows the quantized sizes
    /// (int8 scales included) so the savings in the reports are real.
    pub fn payload_bytes(&self) -> u64 {
        (self.rows() * self.precision.wire_row_bytes(self.kv_heads, self.head_dim)) as u64
    }

    /// Control-plane bytes: header + per-row `pos`/`relevance` metadata.
    /// Reported separately; excluded from the round accounting to keep
    /// parity with the paper's metric (≤ 8 B/row, negligible next to the
    /// KV payload).
    pub fn control_bytes(&self) -> u64 {
        (self.encoded_len() as u64) - self.payload_bytes()
    }

    /// Exact length of [`KvContribution::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        let ver_extra = usize::from(self.precision != KvPrecision::F32);
        HEADER_BYTES + ver_extra + 5 * 4 + self.pos.len() * 8 + self.payload_bytes() as usize
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = open_kv_writer(TAG_CONTRIBUTION, self.precision, self.encoded_len());
        w.u32(self.block as u32);
        w.u32(self.owner as u32);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.rows() as u32);
        w.i32s(&self.pos);
        w.f32s(&self.relevance);
        write_kv_payload(
            &mut w,
            self.precision,
            self.kv_heads * self.head_dim,
            self.rows(),
            &self.k,
            &self.v,
            &self.qscale_k,
            &self.qscale_v,
        );
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let (mut r, precision) = Reader::open_quant(b, TAG_CONTRIBUTION)?;
        let block = r.u32()? as usize;
        let owner = r.u32()? as usize;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let rows = r.u32()? as usize;
        let elems = row_elems(rows, kv_heads, head_dim)?;
        let pos = r.i32s(rows)?;
        let relevance = r.f32s(rows)?;
        let payload = read_kv_payload(&mut r, precision, rows, kv_heads * head_dim, elems)?;
        r.done()?;
        Ok(Self {
            block,
            owner,
            kv_heads,
            head_dim,
            pos,
            relevance,
            k: payload.k,
            v: payload.v,
            precision,
            qscale_k: payload.qscale_k,
            qscale_v: payload.qscale_v,
        })
    }
}

/// A writer with the right header for a KV message at `precision`: `f32`
/// writes the legacy version-1 header, reduced precisions write version
/// 2 plus the precision byte.
fn open_kv_writer(tag: u8, precision: KvPrecision, cap_hint: usize) -> Writer {
    match precision {
        KvPrecision::F32 => Writer::new(tag, cap_hint),
        p => {
            let mut w = Writer::with_magic_version(WIRE_MAGIC, tag, WIRE_VERSION_QUANT, cap_hint);
            w.u8(p.wire_byte());
            w
        }
    }
}

/// Write a K/V data plane at `precision`.  Int8 writes per-row scales
/// (k rows' scales, then v rows') ahead of the level bytes; decoded
/// messages pass their stored wire scales back in so re-encode is
/// bit-exact, fresh messages pass empty slices and the scales are
/// computed from the data.
#[allow(clippy::too_many_arguments)]
fn write_kv_payload(
    w: &mut Writer,
    precision: KvPrecision,
    row_len: usize,
    rows: usize,
    k: &[f32],
    v: &[f32],
    qscale_k: &[f32],
    qscale_v: &[f32],
) {
    match precision {
        KvPrecision::F32 => {
            w.f32s(k);
            w.f32s(v);
        }
        KvPrecision::F16 => {
            w.f16s(k);
            w.f16s(v);
        }
        KvPrecision::Int8 => {
            let sk = stored_or_computed_scales(k, row_len, rows, qscale_k);
            let sv = stored_or_computed_scales(v, row_len, rows, qscale_v);
            w.f32s(&sk);
            w.f32s(&sv);
            w.i8_rows(k, row_len, &sk);
            w.i8_rows(v, row_len, &sv);
        }
    }
}

fn stored_or_computed_scales(
    data: &[f32],
    row_len: usize,
    rows: usize,
    stored: &[f32],
) -> Vec<f32> {
    if stored.len() == rows {
        stored.to_vec()
    } else {
        (0..rows)
            .map(|r| int8_row_scale(&data[r * row_len..(r + 1) * row_len]))
            .collect()
    }
}

/// A decoded K/V data plane: dequantized values plus (for int8) the wire
/// scales, kept so re-encode is canonical.
struct KvPayload {
    k: Vec<f32>,
    v: Vec<f32>,
    qscale_k: Vec<f32>,
    qscale_v: Vec<f32>,
}

fn read_kv_payload(
    r: &mut Reader<'_>,
    precision: KvPrecision,
    rows: usize,
    row_len: usize,
    elems: usize,
) -> Result<KvPayload, WireError> {
    let empty = Vec::new;
    match precision {
        KvPrecision::F32 => Ok(KvPayload {
            k: r.f32s(elems)?,
            v: r.f32s(elems)?,
            qscale_k: empty(),
            qscale_v: empty(),
        }),
        KvPrecision::F16 => Ok(KvPayload {
            k: r.f16s_dequant(elems)?,
            v: r.f16s_dequant(elems)?,
            qscale_k: empty(),
            qscale_v: empty(),
        }),
        KvPrecision::Int8 => {
            let qscale_k = r.i8_scales(rows)?;
            let qscale_v = r.i8_scales(rows)?;
            let k = r.i8_rows_dequant(row_len, &qscale_k)?;
            let v = r.i8_rows_dequant(row_len, &qscale_v)?;
            Ok(KvPayload { k, v, qscale_k, qscale_v })
        }
    }
}

// ---------------------------------------------------------------------------
// GlobalKvFrame — the downlink
// ---------------------------------------------------------------------------

/// The aggregated global KV for one sync block, as broadcast to attendees
/// (Eq. 20's packed form + per-row metadata).  Carries *all* packed rows
/// with their `transmitted` flags so each attendee can rebuild the exact
/// visibility mask; on a real wire an attendee only receives the rows it
/// does not already own, which is what [`GlobalKvFrame::payload_bytes_for`]
/// measures.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalKvFrame {
    pub block: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Per packed-row metadata, in [`GlobalKv::pack`] order.
    ///
    /// [`GlobalKv::pack`]: crate::fedattn::GlobalKv::pack
    pub meta: Vec<KvRowMeta>,
    /// Packed key rows `[rows × kv_heads × head_dim]` (padding trimmed).
    /// Always dequantized f32 — quantization is an encode-time transform.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Wire precision of the K/V payload (see [`KvPrecision`]).
    pub precision: KvPrecision,
    /// Per-row int8 wire scales as decoded (empty on fresh frames);
    /// re-encode reuses them so decode→encode is bit-exact.
    pub qscale_k: Vec<f32>,
    pub qscale_v: Vec<f32>,
}

impl GlobalKvFrame {
    /// Snapshot a packed [`GlobalKv`] (padding rows trimmed off).
    pub fn from_global(block: usize, g: &GlobalKv) -> Self {
        let (kv_heads, head_dim) = (g.k.shape()[1], g.k.shape()[2]);
        let rows = g.rows();
        let row_len = kv_heads * head_dim;
        let mut k = Vec::with_capacity(rows * row_len);
        let mut v = Vec::with_capacity(rows * row_len);
        for i in 0..rows {
            k.extend_from_slice(g.k.row(i));
            v.extend_from_slice(g.v.row(i));
        }
        Self {
            block,
            kv_heads,
            head_dim,
            meta: g.meta.clone(),
            k,
            v,
            precision: KvPrecision::F32,
            qscale_k: Vec::new(),
            qscale_v: Vec::new(),
        }
    }

    /// Set the wire precision (builder-style, for senders).
    pub fn with_precision(mut self, precision: KvPrecision) -> Self {
        self.precision = precision;
        self
    }

    /// Rebuild the padded [`GlobalKv`] this frame was taken from.
    pub fn to_global(&self, g_pad: usize) -> Result<GlobalKv, WireError> {
        let rows = self.meta.len();
        if rows > g_pad {
            return Err(WireError::Malformed(format!(
                "{rows} frame rows exceed padded size {g_pad}"
            )));
        }
        let row_len = self.kv_heads * self.head_dim;
        if self.k.len() != rows * row_len || self.v.len() != rows * row_len {
            return Err(WireError::Malformed("k/v length mismatch".into()));
        }
        let mut k = HostTensor::zeros(&[g_pad, self.kv_heads, self.head_dim]);
        let mut v = HostTensor::zeros(&[g_pad, self.kv_heads, self.head_dim]);
        k.data_mut()[..self.k.len()].copy_from_slice(&self.k);
        v.data_mut()[..self.v.len()].copy_from_slice(&self.v);
        Ok(GlobalKv { k, v, meta: self.meta.clone() })
    }

    pub fn rows(&self) -> usize {
        self.meta.len()
    }

    /// Data-plane bytes `attendee` receives from this round's downlink
    /// when delta encoding is on (the default): the transmitted rows of
    /// *other* participants — its own rows ride as a retain-list and
    /// untransmitted remote rows are elided (see [`GlobalKvDeltaFrame`]).
    /// Matches the `NetSim` downlink accounting `round_total - own_tx`
    /// row for row.
    pub fn payload_bytes_for(&self, attendee: usize) -> u64 {
        let row_bytes = self.precision.wire_row_bytes(self.kv_heads, self.head_dim) as u64;
        self.meta
            .iter()
            .filter(|m| m.transmitted && m.owner != attendee)
            .count() as u64
            * row_bytes
    }

    /// Data-plane bytes a *full* (non-delta) broadcast of this frame
    /// ships to every attendee: all packed rows, the attendee's own and
    /// the untransmitted ones included.  This is what the pre-delta wire
    /// actually delivered; `delta_frames = false` bills it so the comm
    /// benches can compare the two modes honestly.
    pub fn full_payload_bytes(&self) -> u64 {
        self.meta.len() as u64 * self.precision.wire_row_bytes(self.kv_heads, self.head_dim) as u64
    }

    /// Exact length of [`GlobalKvFrame::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        let ver_extra = usize::from(self.precision != KvPrecision::F32);
        HEADER_BYTES
            + ver_extra
            + 4 * 4
            + self.meta.len() * META_ENTRY_BYTES
            + self.full_payload_bytes() as usize
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = open_kv_writer(TAG_FRAME, self.precision, self.encoded_len());
        w.u32(self.block as u32);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.meta.len() as u32);
        write_meta(&mut w, &self.meta);
        write_kv_payload(
            &mut w,
            self.precision,
            self.kv_heads * self.head_dim,
            self.meta.len(),
            &self.k,
            &self.v,
            &self.qscale_k,
            &self.qscale_v,
        );
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let (mut r, precision) = Reader::open_quant(b, TAG_FRAME)?;
        let block = r.u32()? as usize;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let rows = r.u32()? as usize;
        let elems = row_elems(rows, kv_heads, head_dim)?;
        let meta = read_meta(&mut r, rows)?;
        let payload = read_kv_payload(&mut r, precision, rows, kv_heads * head_dim, elems)?;
        r.done()?;
        Ok(Self {
            block,
            kv_heads,
            head_dim,
            meta,
            k: payload.k,
            v: payload.v,
            precision,
            qscale_k: payload.qscale_k,
            qscale_v: payload.qscale_v,
        })
    }
}

/// Bytes of one encoded [`KvRowMeta`] entry (`pos + owner + transmitted +
/// relevance`).  The round-scoped row id is *not* shipped: packing is
/// owner-major in local order, so receivers reconstruct it as the row's
/// occurrence index among its owner's rows.
pub(crate) const META_ENTRY_BYTES: usize = 13;

fn write_meta(w: &mut Writer, meta: &[KvRowMeta]) {
    for m in meta {
        w.i32(m.pos);
        w.u32(m.owner as u32);
        w.u8(m.transmitted as u8);
        w.f32(m.relevance);
    }
}

/// Read `rows` meta entries, reconstructing each row's round-scoped id as
/// its occurrence index among its owner's rows (the [`GlobalKv::pack`]
/// stamping, which is what [`write_meta`] elides from the wire).  The
/// per-owner counters live in a map bounded by the row count, so a
/// hostile owner field cannot drive an allocation.
///
/// [`GlobalKv::pack`]: crate::fedattn::GlobalKv::pack
fn read_meta(r: &mut Reader<'_>, rows: usize) -> Result<Vec<KvRowMeta>, WireError> {
    r.ensure_remaining(rows, META_ENTRY_BYTES)?;
    let mut counters: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
    let mut meta = Vec::with_capacity(rows);
    for _ in 0..rows {
        let pos = r.i32()?;
        let owner = r.u32()? as usize;
        let transmitted = match r.u8()? {
            0 => false,
            1 => true,
            other => {
                return Err(WireError::Malformed(format!(
                    "bad transmitted flag {other}"
                )))
            }
        };
        let relevance = r.f32()?;
        let row = {
            let c = counters.entry(owner).or_insert(0);
            let row = *c;
            *c += 1;
            row
        };
        meta.push(KvRowMeta { pos, owner, row, transmitted, relevance });
    }
    Ok(meta)
}

// ---------------------------------------------------------------------------
// GlobalKvDeltaFrame — the incremental downlink
// ---------------------------------------------------------------------------

/// The aggregated round for one attendee, delta-encoded against what the
/// attendee already holds.  A full [`GlobalKvFrame`] re-ships every
/// packed row; per attendee, most of that is redundant:
///
/// * its **own rows** were handed to its node host this very round (the
///   contribute request carries the fresh K/V) — they ride here as a
///   *retain-list* of round-scoped row ids ([`KvRowMeta::row`]) the node
///   resolves against that fresh KV;
/// * **untransmitted remote rows** are invisible to the attendee by
///   construction (the visibility mask pins them to `-inf`), so their
///   values are elided entirely and reassembled as zeros.
///
/// Only transmitted rows of *other* participants ship as data — exactly
/// the rows [`GlobalKvFrame::payload_bytes_for`] has always billed, so
/// with delta frames the wire finally matches the accounting.  The full
/// per-row metadata still rides along (control plane, ≤ 13 B/row) so the
/// attendee rebuilds the exact packed geometry and visibility mask, and
/// `epoch` (the executed-sync-round ordinal) ties the frame to the fresh
/// KV generation it references: a receiver whose cached generation does
/// not match must reject the delta as a protocol error — never guess.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalKvDeltaFrame {
    pub block: usize,
    /// Executed-sync-round ordinal the retained rows belong to; must
    /// match the epoch of the attendee's cached fresh KV for `block`.
    pub epoch: usize,
    /// The participant this delta was cut for (retention is
    /// per-attendee).
    pub attendee: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Per packed-row metadata for the *whole* reassembled frame, in
    /// [`GlobalKv::pack`] order.
    ///
    /// [`GlobalKv::pack`]: crate::fedattn::GlobalKv::pack
    pub meta: Vec<KvRowMeta>,
    /// Round-scoped row ids of the attendee's own rows, one per meta row
    /// with `owner == attendee`, in meta order; each indexes the fresh
    /// K/V the attendee contributed this round.
    pub retain: Vec<u32>,
    /// Shipped key rows — the transmitted rows of other participants, in
    /// meta order, packed `[shipped × kv_heads × head_dim]`.  Always
    /// dequantized f32 — quantization is an encode-time transform.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Wire precision of the shipped K/V payload (see [`KvPrecision`]).
    pub precision: KvPrecision,
    /// Per-*shipped*-row int8 wire scales as decoded (empty on fresh
    /// deltas); re-encode reuses them so decode→encode is bit-exact.
    pub qscale_k: Vec<f32>,
    pub qscale_v: Vec<f32>,
}

impl GlobalKvDeltaFrame {
    /// Cut `frame` down to the delta `attendee` actually needs.
    pub fn from_frame(frame: &GlobalKvFrame, epoch: usize, attendee: usize) -> Self {
        let row_len = frame.kv_heads * frame.head_dim;
        let shipped = frame
            .meta
            .iter()
            .filter(|m| m.transmitted && m.owner != attendee)
            .count();
        let mut k = Vec::with_capacity(shipped * row_len);
        let mut v = Vec::with_capacity(shipped * row_len);
        let mut retain = Vec::new();
        for (i, m) in frame.meta.iter().enumerate() {
            if m.owner == attendee {
                retain.push(m.row as u32);
            } else if m.transmitted {
                k.extend_from_slice(&frame.k[i * row_len..(i + 1) * row_len]);
                v.extend_from_slice(&frame.v[i * row_len..(i + 1) * row_len]);
            }
        }
        Self {
            block: frame.block,
            epoch,
            attendee,
            kv_heads: frame.kv_heads,
            head_dim: frame.head_dim,
            meta: frame.meta.clone(),
            retain,
            k,
            v,
            // Inherit the wire precision so the delta bills (and ships)
            // exactly what the full frame would for this attendee.
            precision: frame.precision,
            qscale_k: Vec::new(),
            qscale_v: Vec::new(),
        }
    }

    /// Cut the delta for `attendee` straight from the packed [`GlobalKv`]
    /// without materializing the full broadcast frame first: only the
    /// shipped rows (and the meta) are copied, which keeps the hot
    /// delta-downlink path free of the O(total rows) copy a
    /// [`GlobalKvFrame::from_global`] + [`GlobalKvDeltaFrame::from_frame`]
    /// chain would pay per attendee.  Produces exactly the same message.
    pub fn from_global(block: usize, g: &GlobalKv, epoch: usize, attendee: usize) -> Self {
        let (kv_heads, head_dim) = (g.k.shape()[1], g.k.shape()[2]);
        let row_len = kv_heads * head_dim;
        let shipped = g
            .meta
            .iter()
            .filter(|m| m.transmitted && m.owner != attendee)
            .count();
        let mut k = Vec::with_capacity(shipped * row_len);
        let mut v = Vec::with_capacity(shipped * row_len);
        let mut retain = Vec::new();
        for (i, m) in g.meta.iter().enumerate() {
            if m.owner == attendee {
                retain.push(m.row as u32);
            } else if m.transmitted {
                k.extend_from_slice(g.k.row(i));
                v.extend_from_slice(g.v.row(i));
            }
        }
        Self {
            block,
            epoch,
            attendee,
            kv_heads,
            head_dim,
            meta: g.meta.clone(),
            retain,
            k,
            v,
            precision: KvPrecision::F32,
            qscale_k: Vec::new(),
            qscale_v: Vec::new(),
        }
    }

    /// Set the wire precision (builder-style, for senders).
    pub fn with_precision(mut self, precision: KvPrecision) -> Self {
        self.precision = precision;
        self
    }

    /// Total rows of the reassembled frame.
    pub fn rows(&self) -> usize {
        self.meta.len()
    }

    /// Rows whose K/V data actually ships (transmitted, not the
    /// attendee's own).
    pub fn shipped_rows(&self) -> usize {
        self.meta
            .iter()
            .filter(|m| m.transmitted && m.owner != self.attendee)
            .count()
    }

    /// Data-plane bytes: only the shipped rows, at the wire precision
    /// (int8 scales included).  Always equals the source frame's
    /// [`GlobalKvFrame::payload_bytes_for`] the attendee at matched
    /// precision.
    pub fn payload_bytes(&self) -> u64 {
        (self.shipped_rows() * self.precision.wire_row_bytes(self.kv_heads, self.head_dim)) as u64
    }

    /// Control-plane bytes: header, metadata, and the retain-list.
    pub fn control_bytes(&self) -> u64 {
        (self.encoded_len() as u64) - self.payload_bytes()
    }

    /// Exact length of [`GlobalKvDeltaFrame::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        let ver_extra = usize::from(self.precision != KvPrecision::F32);
        HEADER_BYTES
            + ver_extra
            + 6 * 4
            + self.meta.len() * META_ENTRY_BYTES
            + 4
            + self.retain.len() * 4
            + self.payload_bytes() as usize
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = open_kv_writer(TAG_DELTA_FRAME, self.precision, self.encoded_len());
        w.u32(self.block as u32);
        w.u32(self.epoch as u32);
        w.u32(self.attendee as u32);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.u32(self.meta.len() as u32);
        write_meta(&mut w, &self.meta);
        w.u32(self.retain.len() as u32);
        for &id in &self.retain {
            w.u32(id);
        }
        write_kv_payload(
            &mut w,
            self.precision,
            self.kv_heads * self.head_dim,
            self.shipped_rows(),
            &self.k,
            &self.v,
            &self.qscale_k,
            &self.qscale_v,
        );
        w.finish()
    }

    /// Decode and structurally validate a delta frame.  The retain-list
    /// length must equal the count of meta rows owned by the attendee and
    /// the shipped K/V lengths are derived from the metadata, so a
    /// successful decode is canonical (re-encodes to the same bytes) and
    /// every length field is bounded against the buffer before any
    /// allocation.
    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let (mut r, precision) = Reader::open_quant(b, TAG_DELTA_FRAME)?;
        let block = r.u32()? as usize;
        let epoch = r.u32()? as usize;
        let attendee = r.u32()? as usize;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let rows = r.u32()? as usize;
        let meta = read_meta(&mut r, rows)?;
        let own = meta.iter().filter(|m| m.owner == attendee).count();
        let retain_len = r.u32()? as usize;
        if retain_len != own {
            return Err(WireError::Malformed(format!(
                "retain-list length {retain_len} != {own} attendee-owned rows"
            )));
        }
        r.ensure_remaining(retain_len, 4)?;
        let retain: Vec<u32> = (0..retain_len).map(|_| r.u32()).collect::<Result<_, _>>()?;
        let shipped = meta
            .iter()
            .filter(|m| m.transmitted && m.owner != attendee)
            .count();
        let elems = row_elems(shipped, kv_heads, head_dim)?;
        let payload = read_kv_payload(&mut r, precision, shipped, kv_heads * head_dim, elems)?;
        r.done()?;
        Ok(Self {
            block,
            epoch,
            attendee,
            kv_heads,
            head_dim,
            meta,
            retain,
            k: payload.k,
            v: payload.v,
            precision,
            qscale_k: payload.qscale_k,
            qscale_v: payload.qscale_v,
        })
    }

    /// Reassemble the full downlink frame from this delta plus the
    /// attendee's own fresh K/V for the round (`own_k`/`own_v`, row-major
    /// `[own_rows × kv_heads × head_dim]` — the exact tensors it
    /// contributed from).  Shipped rows come from the delta payload,
    /// retained rows from the fresh KV at their round-scoped id, and
    /// elided (untransmitted remote) rows are zero-filled — they are
    /// masked to `-inf` for this attendee, so zero weights erase them
    /// from attention and decode outputs stay byte-identical to a
    /// full-frame session.
    ///
    /// Every retain id is validated against `own_rows` before use: an
    /// unknown id is a [`WireError::Malformed`] protocol error, never a
    /// panic or an out-of-bounds read.
    pub fn reassemble(
        &self,
        own_k: &[f32],
        own_v: &[f32],
        own_rows: usize,
    ) -> Result<GlobalKvFrame, WireError> {
        let row_len = self.kv_heads * self.head_dim;
        if own_k.len() != own_rows * row_len || own_v.len() != own_rows * row_len {
            return Err(WireError::Malformed(format!(
                "own KV geometry mismatch: {} rows of {} elems vs {}/{} values",
                own_rows,
                row_len,
                own_k.len(),
                own_v.len()
            )));
        }
        if self.k.len() != self.shipped_rows() * row_len || self.v.len() != self.k.len() {
            return Err(WireError::Malformed("shipped k/v length mismatch".into()));
        }
        let own = self.meta.iter().filter(|m| m.owner == self.attendee).count();
        if self.retain.len() != own {
            return Err(WireError::Malformed(format!(
                "retain-list length {} != {own} attendee-owned rows",
                self.retain.len()
            )));
        }
        let rows = self.meta.len();
        let mut k = vec![0.0f32; rows * row_len];
        let mut v = vec![0.0f32; rows * row_len];
        let mut next_retained = 0usize;
        let mut next_shipped = 0usize;
        for (i, m) in self.meta.iter().enumerate() {
            let dst = i * row_len..(i + 1) * row_len;
            if m.owner == self.attendee {
                let id = self.retain[next_retained] as usize;
                next_retained += 1;
                if id >= own_rows {
                    return Err(WireError::Malformed(format!(
                        "retain id {id} out of range ({own_rows} own rows)"
                    )));
                }
                let src = id * row_len..(id + 1) * row_len;
                k[dst.clone()].copy_from_slice(&own_k[src.clone()]);
                v[dst].copy_from_slice(&own_v[src]);
            } else if m.transmitted {
                let src = next_shipped * row_len..(next_shipped + 1) * row_len;
                next_shipped += 1;
                k[dst.clone()].copy_from_slice(&self.k[src.clone()]);
                v[dst].copy_from_slice(&self.v[src]);
            }
            // Untransmitted remote rows stay zero: masked for this
            // attendee, so the values never reach an attention output.
        }
        Ok(GlobalKvFrame {
            block: self.block,
            kv_heads: self.kv_heads,
            head_dim: self.head_dim,
            meta: self.meta.clone(),
            k,
            v,
            // The reassembled frame inherits the wire precision so its
            // byte accounting stays consistent; it is a local value-plane
            // object (never re-encoded), so no wire scales carry over.
            precision: self.precision,
            qscale_k: Vec::new(),
            qscale_v: Vec::new(),
        })
    }
}

// ---------------------------------------------------------------------------
// DecodeTail — per-step cache append
// ---------------------------------------------------------------------------

/// One decode-step KV row append for one block: the wire form of the
/// device decode tail (paper §IV-C).  A networked decode ships one of
/// these per layer per generated token instead of re-sending the cache.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeTail {
    pub block: usize,
    /// Global position of the appended token.
    pub pos: i32,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Appended key row `[kv_heads × head_dim]`.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl DecodeTail {
    pub fn from_row(block: usize, pos: i32, k: &[f32], v: &[f32], kv_heads: usize, head_dim: usize) -> Self {
        debug_assert_eq!(k.len(), kv_heads * head_dim);
        debug_assert_eq!(v.len(), kv_heads * head_dim);
        Self { block, pos, kv_heads, head_dim, k: k.to_vec(), v: v.to_vec() }
    }

    /// Data-plane bytes: one K row + one V row.
    pub fn payload_bytes(&self) -> u64 {
        4 * (self.k.len() + self.v.len()) as u64
    }

    /// Exact length of [`DecodeTail::encode`]'s output.
    pub fn encoded_len(&self) -> usize {
        HEADER_BYTES + 4 * 4 + (self.k.len() + self.v.len()) * 4
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_DECODE_TAIL, self.encoded_len());
        w.u32(self.block as u32);
        w.i32(self.pos);
        w.u32(self.kv_heads as u32);
        w.u32(self.head_dim as u32);
        w.f32s(&self.k);
        w.f32s(&self.v);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_DECODE_TAIL)?;
        let block = r.u32()? as usize;
        let pos = r.i32()?;
        let kv_heads = r.u32()? as usize;
        let head_dim = r.u32()? as usize;
        let elems = row_elems(1, kv_heads, head_dim)?;
        let k = r.f32s(elems)?;
        let v = r.f32s(elems)?;
        r.done()?;
        Ok(Self { block, pos, kv_heads, head_dim, k, v })
    }
}

// ---------------------------------------------------------------------------
// TokenBroadcast
// ---------------------------------------------------------------------------

/// A decoded token pushed from the decoding participant to its peers
/// (e.g. streaming the answer back, or driving a collaborative decode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenBroadcast {
    /// Decode step the token was produced at.
    pub step: usize,
    pub token: i32,
}

impl TokenBroadcast {
    /// Exact length of [`TokenBroadcast::encode`]'s output.
    pub const ENCODED_LEN: usize = HEADER_BYTES + 2 * 4;

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new(TAG_TOKEN, Self::ENCODED_LEN);
        w.u32(self.step as u32);
        w.i32(self.token);
        w.finish()
    }

    pub fn decode(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::open(b, TAG_TOKEN)?;
        let step = r.u32()? as usize;
        let token = r.i32()?;
        r.done()?;
        Ok(Self { step, token })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(rows: usize, hkv: usize, hd: usize, base: f32) -> HostTensor {
        let mut t = HostTensor::zeros(&[rows, hkv, hd]);
        for i in 0..rows {
            t.row_mut(i).fill(base + i as f32);
        }
        t
    }

    #[test]
    fn contribution_extracts_flagged_rows() {
        let k = tensor(4, 2, 3, 10.0);
        let v = tensor(4, 2, 3, -10.0);
        let pos = [5, 6, 7, 8];
        let tx = [true, false, true, false];
        let rel = [0.25f64, 0.5, 0.75, 1.0];
        let c = KvContribution::from_rows(2, 1, &k, &v, &pos, &tx, Some(&rel));
        assert_eq!(c.rows(), 2);
        assert_eq!(c.pos, vec![5, 7]);
        assert_eq!(c.relevance, vec![0.25, 0.75]);
        assert_eq!(&c.k[..6], k.row(0));
        assert_eq!(&c.k[6..], k.row(2));
        assert_eq!(c.payload_bytes(), 2 * GlobalKv::row_bytes(2, 3) as u64);
    }

    #[test]
    fn contribution_roundtrip_and_lengths() {
        let k = tensor(3, 1, 2, 1.0);
        let c = KvContribution::from_rows(
            0,
            2,
            &k,
            &k.clone(),
            &[0, 1, 2],
            &[true, true, false],
            None,
        );
        let bytes = c.encode();
        assert_eq!(bytes.len(), c.encoded_len());
        assert_eq!(KvContribution::decode(&bytes).unwrap(), c);
        assert_eq!(c.payload_bytes() + c.control_bytes(), bytes.len() as u64);
    }

    #[test]
    fn frame_roundtrip_through_global_kv() {
        let k = tensor(3, 1, 2, 1.0);
        let pos = [0, 1, 2];
        let tx = [true, false, true];
        let g = GlobalKv::pack(&[(&k, &k.clone(), &pos, 3, &tx)], 5).unwrap();
        let f = GlobalKvFrame::from_global(4, &g);
        assert_eq!(f.rows(), 3);
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        let f2 = GlobalKvFrame::decode(&bytes).unwrap();
        assert_eq!(f2, f);
        let g2 = f2.to_global(5).unwrap();
        assert_eq!(g2.k, g.k);
        assert_eq!(g2.v, g.v);
        assert_eq!(g2.meta, g.meta);
        // rows not transmitted or owned by the attendee do not cross the
        // wire: owner 0 receives nothing of its own rows.
        assert_eq!(f.payload_bytes_for(0), 0);
        assert_eq!(f.payload_bytes_for(1), 2 * GlobalKv::row_bytes(1, 2) as u64);
    }

    #[test]
    fn decode_tail_and_token_roundtrip() {
        let t = DecodeTail::from_row(3, 17, &[1.0, 2.0], &[3.0, 4.0], 1, 2);
        let bytes = t.encode();
        assert_eq!(bytes.len(), t.encoded_len());
        assert_eq!(DecodeTail::decode(&bytes).unwrap(), t);
        assert_eq!(t.payload_bytes(), GlobalKv::row_bytes(1, 2) as u64);

        let tb = TokenBroadcast { step: 9, token: -1 };
        let bytes = tb.encode();
        assert_eq!(bytes.len(), TokenBroadcast::ENCODED_LEN);
        assert_eq!(TokenBroadcast::decode(&bytes).unwrap(), tb);
    }

    #[test]
    fn decode_rejects_garbage() {
        let tb = TokenBroadcast { step: 1, token: 2 }.encode();
        // truncated
        assert!(matches!(
            TokenBroadcast::decode(&tb[..tb.len() - 1]),
            Err(WireError::Truncated(_))
        ));
        // wrong tag for the decoder
        assert!(matches!(
            KvContribution::decode(&tb),
            Err(WireError::BadTag { .. })
        ));
        // trailing bytes
        let mut long = tb.clone();
        long.push(0);
        assert!(matches!(TokenBroadcast::decode(&long), Err(WireError::Trailing(1))));
        // bad version
        let mut bad = tb.clone();
        bad[2] = 99;
        assert!(matches!(TokenBroadcast::decode(&bad), Err(WireError::Version(99))));
        // bad magic
        let mut bad = tb;
        bad[0] = 0;
        assert!(matches!(TokenBroadcast::decode(&bad), Err(WireError::BadTag { .. })));
    }

    #[test]
    fn decode_rejects_hostile_length_fields() {
        // A ~19-byte frame claiming u32::MAX rows must fail cleanly
        // (Truncated) before any row-sized allocation happens.
        let mut msg = vec![WIRE_MAGIC, TAG_FRAME, WIRE_VERSION];
        for field in [7u32, 1, 1, u32::MAX] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        assert!(matches!(
            GlobalKvFrame::decode(&msg),
            Err(WireError::Truncated(_))
        ));
        // All-max dimensions overflow usize: must be Malformed, not a
        // silent wrap that "successfully" decodes inconsistent lengths.
        let mut msg = vec![WIRE_MAGIC, TAG_CONTRIBUTION, WIRE_VERSION];
        for field in [0u32, 0, u32::MAX, u32::MAX, u32::MAX] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        assert!(matches!(
            KvContribution::decode(&msg),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn wire_kind_peeks_tags() {
        let tb = TokenBroadcast { step: 0, token: 1 }.encode();
        assert_eq!(wire_kind(&tb), Some(WireKind::Token));
        let t = DecodeTail::from_row(0, 0, &[1.0], &[2.0], 1, 1).encode();
        assert_eq!(wire_kind(&t), Some(WireKind::DecodeTail));
        assert_eq!(wire_kind(&[WIRE_MAGIC, TAG_DELTA_FRAME]), Some(WireKind::DeltaFrame));
        assert_eq!(wire_kind(&[]), None);
        assert_eq!(wire_kind(&[WIRE_MAGIC]), None);
        assert_eq!(wire_kind(&[WIRE_MAGIC, 99]), None);
        assert_eq!(wire_kind(&[0x00, TAG_TOKEN]), None);
    }

    /// Two-participant frame for the delta tests: owner 0 holds rows
    /// {0, 1, 2} (row 1 untransmitted), owner 1 holds rows {3, 4}.
    fn two_party_frame() -> (GlobalKvFrame, HostTensor, HostTensor) {
        let k0 = tensor(3, 1, 2, 10.0);
        let v0 = tensor(3, 1, 2, -10.0);
        let k1 = tensor(2, 1, 2, 100.0);
        let v1 = tensor(2, 1, 2, -100.0);
        let g = GlobalKv::pack(
            &[
                (&k0, &v0, &[0, 1, 2][..], 3, &[true, false, true][..]),
                (&k1, &v1, &[3, 4][..], 2, &[true, true][..]),
            ],
            6,
        )
        .unwrap();
        (GlobalKvFrame::from_global(4, &g), k0, v0)
    }

    #[test]
    fn delta_frame_roundtrips_and_bills_like_payload_bytes_for() {
        let (frame, _, _) = two_party_frame();
        for attendee in 0..2usize {
            let d = GlobalKvDeltaFrame::from_frame(&frame, 7, attendee);
            assert_eq!(d.rows(), frame.rows());
            assert_eq!(d.payload_bytes(), frame.payload_bytes_for(attendee));
            assert!(d.payload_bytes() < frame.full_payload_bytes());
            let bytes = d.encode();
            assert_eq!(bytes.len(), d.encoded_len());
            let back = GlobalKvDeltaFrame::decode(&bytes).unwrap();
            assert_eq!(back, d);
            assert_eq!(back.encode(), bytes);
        }
        // Attendee 0 retains its 3 own rows by id, ships owner 1's 2 rows.
        let d = GlobalKvDeltaFrame::from_frame(&frame, 7, 0);
        assert_eq!(d.retain, vec![0, 1, 2]);
        assert_eq!(d.shipped_rows(), 2);
    }

    #[test]
    fn delta_from_global_equals_from_frame() {
        // The hot-path constructor (no full-frame materialization) must
        // produce the identical message.
        let k0 = tensor(3, 1, 2, 10.0);
        let v0 = tensor(3, 1, 2, -10.0);
        let k1 = tensor(2, 1, 2, 100.0);
        let v1 = tensor(2, 1, 2, -100.0);
        let g = GlobalKv::pack(
            &[
                (&k0, &v0, &[0, 1, 2][..], 3, &[true, false, true][..]),
                (&k1, &v1, &[3, 4][..], 2, &[true, true][..]),
            ],
            6,
        )
        .unwrap();
        let frame = GlobalKvFrame::from_global(4, &g);
        for attendee in 0..2usize {
            assert_eq!(
                GlobalKvDeltaFrame::from_global(4, &g, 7, attendee),
                GlobalKvDeltaFrame::from_frame(&frame, 7, attendee),
                "attendee {attendee}"
            );
        }
    }

    #[test]
    fn delta_reassembles_full_frame_with_zeros_only_where_masked() {
        let (frame, k0, v0) = two_party_frame();
        let d = GlobalKvDeltaFrame::from_frame(&frame, 3, 0);
        let re = d.reassemble(k0.data(), v0.data(), 3).unwrap();
        assert_eq!(re.meta, frame.meta);
        assert_eq!(re.block, frame.block);
        // Every row attendee 0 can see (own or transmitted) is
        // value-identical to the full frame; elided rows are zero.
        let row_len = 2usize;
        for (i, m) in frame.meta.iter().enumerate() {
            let (got, want) = (&re.k[i * row_len..(i + 1) * row_len], &frame.k[i * row_len..(i + 1) * row_len]);
            if m.owner == 0 || m.transmitted {
                assert_eq!(got, want, "visible row {i} drifted");
            }
        }
        // No elided rows exist for attendee 0's view except... none here:
        // all of owner 0's rows are its own.  Attendee 1's view elides
        // owner 0's untransmitted row 1, which must reassemble as zeros.
        let k1 = tensor(2, 1, 2, 100.0);
        let v1 = tensor(2, 1, 2, -100.0);
        let d1 = GlobalKvDeltaFrame::from_frame(&frame, 3, 1);
        let re1 = d1.reassemble(k1.data(), v1.data(), 2).unwrap();
        assert!(re1.k[row_len..2 * row_len].iter().all(|&x| x == 0.0));
        assert_eq!(&re1.k[..row_len], &frame.k[..row_len]);
        assert_eq!(&re1.k[2 * row_len..], &frame.k[2 * row_len..]);
    }

    #[test]
    fn delta_rejects_bad_retain_and_geometry() {
        let (frame, k0, v0) = two_party_frame();
        let mut d = GlobalKvDeltaFrame::from_frame(&frame, 0, 0);
        // Unknown retain id: protocol error, not a panic or OOB read.
        d.retain[1] = 99;
        assert!(matches!(
            d.reassemble(k0.data(), v0.data(), 3),
            Err(WireError::Malformed(_))
        ));
        // Own-KV geometry mismatch.
        let d = GlobalKvDeltaFrame::from_frame(&frame, 0, 0);
        assert!(d.reassemble(k0.data(), v0.data(), 2).is_err());
        // A decoded retain-list must exactly cover the attendee's rows.
        let mut bytes = GlobalKvDeltaFrame::from_frame(&frame, 0, 0).encode();
        // retain length field sits after header + 6 u32s + meta entries.
        let at = HEADER_BYTES + 6 * 4 + frame.rows() * META_ENTRY_BYTES;
        bytes[at..at + 4].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(
            GlobalKvDeltaFrame::decode(&bytes),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn delta_decode_rejects_hostile_length_fields() {
        // Astronomical row count: must fail before any row allocation.
        let mut msg = vec![WIRE_MAGIC, TAG_DELTA_FRAME, WIRE_VERSION];
        for field in [0u32, 0, 0, 1, 1, u32::MAX] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        assert!(matches!(
            GlobalKvDeltaFrame::decode(&msg),
            Err(WireError::Truncated(_))
        ));
        // Overflowing dimensions: Malformed, not a silent wrap.
        let mut msg = vec![WIRE_MAGIC, TAG_DELTA_FRAME, WIRE_VERSION];
        for field in [0u32, 0, 0, u32::MAX, u32::MAX, 0] {
            msg.extend_from_slice(&field.to_le_bytes());
        }
        // 0 meta rows -> retain length comes next; claim a huge one.
        msg.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(GlobalKvDeltaFrame::decode(&msg).is_err());
    }

    #[test]
    fn frame_to_global_validates() {
        let k = tensor(2, 1, 2, 0.0);
        let g = GlobalKv::pack(&[(&k, &k.clone(), &[0, 1], 2, &[true, true])], 2).unwrap();
        let f = GlobalKvFrame::from_global(0, &g);
        assert!(f.to_global(1).is_err()); // rows exceed padding
        let mut broken = f.clone();
        broken.k.pop();
        assert!(broken.to_global(4).is_err());
    }

    // -----------------------------------------------------------------
    // Quantized wire rows (kv_precision)
    // -----------------------------------------------------------------

    #[test]
    fn kv_precision_parses_and_sizes_rows() {
        for (s, p) in [
            ("f32", KvPrecision::F32),
            ("f16", KvPrecision::F16),
            ("int8", KvPrecision::Int8),
        ] {
            assert_eq!(KvPrecision::from_str_opt(s), Some(p));
            assert_eq!(p.as_str(), s);
        }
        assert_eq!(KvPrecision::from_str_opt("f8"), None);
        assert_eq!(KvPrecision::default(), KvPrecision::F32);
        // Wire bytes per K+V row pair at the fixture geometry (2 heads ×
        // 24 dims): strictly decreasing f32 → f16 → int8, with int8 a
        // ≥ 3.5× cut even after paying for its two per-row scales.
        let f32b = KvPrecision::F32.wire_row_bytes(2, 24);
        let f16b = KvPrecision::F16.wire_row_bytes(2, 24);
        let i8b = KvPrecision::Int8.wire_row_bytes(2, 24);
        assert_eq!(f32b, GlobalKv::row_bytes(2, 24));
        assert!(f32b > f16b && f16b > i8b, "{f32b} {f16b} {i8b}");
        assert!(f32b as f64 / i8b as f64 >= 3.5, "{f32b}/{i8b}");
    }

    #[test]
    fn f16_conversion_saturates_and_roundtrips_finite_halves() {
        // Every finite half value survives f16 -> f32 -> f16 bit-exactly
        // (this is what makes f16 decode canonical).
        for bits in 0..=u16::MAX {
            let x = f16_bits_to_f32(bits);
            if x.is_finite() {
                assert_eq!(f32_to_f16_bits(x), bits, "half bits {bits:#06x}");
            }
        }
        // Overflow saturates to ±65504 instead of inf; NaN maps to zero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0e9)), 65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1.0e9)), -65504.0);
        assert_eq!(f32_to_f16_bits(f32::NAN), 0);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7BFF);
        // Values exactly representable in half are preserved.
        for x in [0.0f32, -0.0, 1.0, -2.5, 0.125, 65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x);
        }
    }

    /// Quantized messages decode to exactly what [`requantize_row`]
    /// produces (the value-plane contract the in-process session relies
    /// on), and decode→encode is bit-exact (canonical).
    #[test]
    fn quant_contribution_decodes_to_requantized_rows_and_is_canonical() {
        let k = tensor(3, 2, 3, 1.375);
        let v = tensor(3, 2, 3, -0.631);
        for precision in [KvPrecision::F16, KvPrecision::Int8] {
            let c = KvContribution::from_rows(
                2,
                1,
                &k,
                &v,
                &[5, 6, 7],
                &[true, true, false],
                Some(&[0.25, 0.5, 0.75]),
            )
            .with_precision(precision);
            let bytes = c.encode();
            assert_eq!(bytes.len(), c.encoded_len(), "{precision:?}");
            assert_eq!(bytes[2], WIRE_VERSION_QUANT);
            assert!(bytes.len() < c.clone().with_precision(KvPrecision::F32).encode().len());
            let back = KvContribution::decode(&bytes).unwrap();
            assert_eq!(back.precision, precision);
            assert_eq!(back.pos, c.pos);
            assert_eq!(back.relevance, c.relevance);
            // Control fields exact; data plane == requantized original.
            let row_len = 6usize;
            for (r, chunk) in c.k.chunks(row_len).enumerate() {
                let mut want = chunk.to_vec();
                requantize_row(&mut want, precision);
                assert_eq!(&back.k[r * row_len..(r + 1) * row_len], &want[..], "{precision:?} k row {r}");
            }
            assert_eq!(back.encode(), bytes, "{precision:?} not canonical");
            assert_eq!(
                back.payload_bytes(),
                (c.rows() * precision.wire_row_bytes(2, 3)) as u64
            );
            assert_eq!(back.payload_bytes() + back.control_bytes(), bytes.len() as u64);
        }
    }

    #[test]
    fn quant_frame_and_delta_bill_and_roundtrip_consistently() {
        let (frame, k0, v0) = two_party_frame();
        for precision in [KvPrecision::F16, KvPrecision::Int8] {
            let qf = frame.clone().with_precision(precision);
            let bytes = qf.encode();
            assert_eq!(bytes.len(), qf.encoded_len());
            let back = GlobalKvFrame::decode(&bytes).unwrap();
            assert_eq!(back.precision, precision);
            assert_eq!(back.meta, qf.meta);
            assert_eq!(back.encode(), bytes, "{precision:?} frame not canonical");
            // Delta cut from the quantized frame bills the same rows.
            for attendee in 0..2usize {
                let d = GlobalKvDeltaFrame::from_frame(&qf, 7, attendee);
                assert_eq!(d.precision, precision);
                assert_eq!(d.payload_bytes(), qf.payload_bytes_for(attendee));
                let dbytes = d.encode();
                assert_eq!(dbytes.len(), d.encoded_len());
                let dback = GlobalKvDeltaFrame::decode(&dbytes).unwrap();
                assert_eq!(dback.encode(), dbytes, "{precision:?} delta not canonical");
                // Shipped rows reassemble to the requantized originals;
                // retained own rows come back raw (the node requantizes
                // its transmitted ones separately, from the frame's
                // precision).
                let (own_k, own_v, own_rows) = if attendee == 0 {
                    (k0.data(), v0.data(), 3)
                } else {
                    (&frame.k[6..10], &frame.v[6..10], 2)
                };
                let re = dback.reassemble(own_k, own_v, own_rows).unwrap();
                let row_len = 2usize;
                for (i, m) in frame.meta.iter().enumerate() {
                    if m.owner != attendee && m.transmitted {
                        let mut want = frame.k[i * row_len..(i + 1) * row_len].to_vec();
                        requantize_row(&mut want, precision);
                        assert_eq!(
                            &re.k[i * row_len..(i + 1) * row_len],
                            &want[..],
                            "{precision:?} shipped row {i}"
                        );
                    }
                }
            }
            // Quantized payloads are strictly smaller than f32's.
            assert!(qf.full_payload_bytes() < frame.full_payload_bytes());
        }
    }

    #[test]
    fn quant_all_zero_rows_use_zero_scale() {
        let k = HostTensor::zeros(&[2, 1, 4]);
        let c = KvContribution::from_rows(0, 0, &k, &k.clone(), &[0, 1], &[true, true], None)
            .with_precision(KvPrecision::Int8);
        let bytes = c.encode();
        let back = KvContribution::decode(&bytes).unwrap();
        assert!(back.qscale_k.iter().all(|&s| s == 0.0));
        assert!(back.k.iter().all(|&x| x == 0.0));
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn hostile_int8_scales_and_levels_rejected() {
        let k = tensor(2, 1, 2, 3.0);
        let c = KvContribution::from_rows(0, 0, &k, &k.clone(), &[0, 1], &[true, true], None)
            .with_precision(KvPrecision::Int8);
        let bytes = c.encode();
        // scale_k[0] sits after header+precision + 5 u32s + pos + rel.
        let scale_at = HEADER_BYTES + 1 + 5 * 4 + 2 * 8;
        for hostile in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.0, 1.0e-45, f32::MAX] {
            let mut bad = bytes.clone();
            bad[scale_at..scale_at + 4].copy_from_slice(&hostile.to_le_bytes());
            assert!(
                KvContribution::decode(&bad).is_err(),
                "scale {hostile:e} must be rejected"
            );
        }
        // Zero scale over nonzero levels is inconsistent.
        let mut bad = bytes.clone();
        bad[scale_at..scale_at + 4].copy_from_slice(&0.0f32.to_le_bytes());
        assert!(KvContribution::decode(&bad).is_err(), "zero scale, nonzero levels");
        // Level -128 cannot re-encode canonically under the ±127 clamp.
        let level_at = scale_at + 4 * 4; // past the four scales
        let mut bad = bytes.clone();
        bad[level_at] = 0x80;
        assert!(KvContribution::decode(&bad).is_err(), "level -128");
        // Version 2 with an f32 (or unknown) precision byte is not a
        // valid encoding — f32 must ship as version 1.
        for p in [0u8, 3, 255] {
            let mut bad = bytes.clone();
            bad[3] = p;
            assert!(KvContribution::decode(&bad).is_err(), "precision byte {p}");
        }
        // Unknown versions stay rejected.
        let mut bad = bytes;
        bad[2] = 3;
        assert!(matches!(KvContribution::decode(&bad), Err(WireError::Version(3))));
    }

    #[test]
    fn requantize_row_is_idempotent() {
        for precision in [KvPrecision::F32, KvPrecision::F16, KvPrecision::Int8] {
            let mut row = vec![0.73f32, -1.9, 0.0, 2.44, -0.031, 5.5];
            requantize_row(&mut row, precision);
            let once = row.clone();
            requantize_row(&mut row, precision);
            assert_eq!(row, once, "{precision:?}");
        }
    }
}
