//! Sparsity policies from the paper's §V duality toolkit.
//!
//! * `LocalSparsity` — sparse local attention (Fig. 9): each participant
//!   randomly subsamples its input tokens *before* inference.  Irreversible
//!   information loss ⇒ monotone quality degradation.
//! * `KvExchangePolicy` — sparse / adaptive KV exchange (Fig. 10 and §V
//!   Obs. 4): which of a participant's KV rows are transmitted at a sync
//!   block.  Own rows remain visible to their owner regardless.
//!
//! Invariant shared by every policy: a participant with `len > 0` valid
//! rows never transmits an *empty* set — an empty exchange would silently
//! degenerate the sync round into local attention for its peers.

use crate::fedattn::relevance::select_rows_by_budget;
use crate::util::prng::Xoshiro256ss;

/// Sparse local attention: keep each token independently with probability
/// `ratio` (the question-final "A:" anchor tokens are always kept so the
/// publisher can still decode).
#[derive(Debug, Clone, Copy)]
pub struct LocalSparsity {
    pub ratio: f64,
}

impl LocalSparsity {
    pub fn full() -> Self {
        Self { ratio: 1.0 }
    }

    /// Select which local indices (0..len) survive; always keeps at least
    /// one token and the final `protect_tail` tokens.
    pub fn select(&self, len: usize, protect_tail: usize, rng: &mut Xoshiro256ss) -> Vec<usize> {
        if self.ratio >= 1.0 || len == 0 {
            return (0..len).collect();
        }
        let protected_from = len.saturating_sub(protect_tail);
        let mut keep: Vec<usize> = (0..len)
            .filter(|&i| i >= protected_from || rng.bernoulli(self.ratio))
            .collect();
        if keep.is_empty() {
            keep.push(len - 1);
        }
        keep
    }
}

/// Per-participant inputs to a transmission decision beyond the policy's
/// own parameters (relevance scores and coordinator-allocated budgets).
#[derive(Debug, Clone, Copy)]
pub struct TxContext<'a> {
    /// Deciding participant.
    pub who: usize,
    /// Task publisher.
    pub publisher: usize,
    /// Valid local KV rows `who` holds this round.
    pub len: usize,
    /// Wire size of one KV row (converts `ByteBudget` bytes to rows).
    pub row_bytes: usize,
    /// Accumulated per-row attention mass for `who`'s rows
    /// ([`crate::fedattn::relevance::RelevanceTracker`]); `None` before
    /// the first sync round or for non-adaptive policies.
    pub relevance: Option<&'a [f64]>,
    /// Coordinator-allocated per-participant row budget (heterogeneous
    /// links); overrides the budget embedded in the policy when present.
    pub row_budget: Option<usize>,
}

impl<'a> TxContext<'a> {
    /// Context with no relevance history and no budget override (the
    /// legacy call path; `row_bytes = 1` makes `ByteBudget` count rows).
    pub fn basic(who: usize, publisher: usize, len: usize) -> Self {
        Self { who, publisher, len, row_bytes: 1, relevance: None, row_budget: None }
    }
}

/// KV-exchange policy applied per participant per sync block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvExchangePolicy {
    /// Transmit every valid row (Alg. 1 baseline).
    Full,
    /// Transmit a uniform random subset of rows (Fig. 10).
    Random { ratio: f64 },
    /// Adaptive aggregation (§V Obs. 4): the publisher transmits all rows,
    /// other participants transmit a random `remote_ratio` subset.
    PublisherPriority { remote_ratio: f64 },
    /// Per-round budget: the `budget_rows` most recent rows (temporal
    /// recency heuristic from the sparse-attention literature [37]–[40]).
    RecentBudget { budget_rows: usize },
    /// Relevance-aware adaptive aggregation (§V Obs. 4): transmit the
    /// `budget_rows` rows with the highest accumulated attention mass
    /// observed at earlier sync rounds; cold start falls back to recency.
    TopKRelevance { budget_rows: usize },
    /// Relevance selection under an explicit byte budget per sync round.
    /// `bytes_per_round` is the *total* across participants; the session
    /// splits it into per-participant row budgets proportional to link
    /// bandwidth ([`crate::net::allocate_row_budgets`]).  Standalone (no
    /// allocation in the context) it acts as a per-participant budget of
    /// `bytes_per_round / row_bytes` rows.
    ByteBudget { bytes_per_round: usize },
}

impl KvExchangePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            KvExchangePolicy::Full => "full",
            KvExchangePolicy::Random { .. } => "random",
            KvExchangePolicy::PublisherPriority { .. } => "publisher-priority",
            KvExchangePolicy::RecentBudget { .. } => "recent-budget",
            KvExchangePolicy::TopKRelevance { .. } => "top-k-relevance",
            KvExchangePolicy::ByteBudget { .. } => "byte-budget",
        }
    }

    /// Whether the session must track per-row attention mass for this
    /// policy (adaptive aggregation).
    pub fn needs_relevance(&self) -> bool {
        matches!(
            self,
            KvExchangePolicy::TopKRelevance { .. } | KvExchangePolicy::ByteBudget { .. }
        )
    }

    /// Whether the policy selects under an explicit row/byte budget.
    pub fn is_budgeted(&self) -> bool {
        matches!(
            self,
            KvExchangePolicy::RecentBudget { .. }
                | KvExchangePolicy::TopKRelevance { .. }
                | KvExchangePolicy::ByteBudget { .. }
        )
    }

    /// Which of `len` valid rows participant `who` transmits this round.
    /// Returns a boolean row mask.  Legacy entry point: no relevance
    /// history, no budget override.
    pub fn transmitted(
        &self,
        who: usize,
        publisher: usize,
        len: usize,
        rng: &mut Xoshiro256ss,
    ) -> Vec<bool> {
        self.transmitted_ctx(&TxContext::basic(who, publisher, len), rng)
    }

    /// Which rows `ctx.who` transmits this round, with relevance history
    /// and coordinator budgets available.  For `ctx.len > 0` the returned
    /// mask is never all-false (see module docs).
    pub fn transmitted_ctx(&self, ctx: &TxContext, rng: &mut Xoshiro256ss) -> Vec<bool> {
        let len = ctx.len;
        match *self {
            KvExchangePolicy::Full => vec![true; len],
            KvExchangePolicy::Random { ratio } => {
                let mut tx: Vec<bool> = (0..len).map(|_| rng.bernoulli(ratio)).collect();
                if !tx.iter().any(|&b| b) && len > 0 {
                    tx[len - 1] = true; // never transmit an empty set
                }
                tx
            }
            KvExchangePolicy::PublisherPriority { remote_ratio } => {
                if ctx.who == ctx.publisher {
                    vec![true; len]
                } else {
                    KvExchangePolicy::Random { ratio: remote_ratio }.transmitted_ctx(ctx, rng)
                }
            }
            KvExchangePolicy::RecentBudget { budget_rows } => {
                let b = ctx.row_budget.unwrap_or(budget_rows).max(1);
                let start = len.saturating_sub(b);
                (0..len).map(|i| i >= start).collect()
            }
            KvExchangePolicy::TopKRelevance { budget_rows } => {
                let b = ctx.row_budget.unwrap_or(budget_rows);
                select_rows_by_budget(len, b, ctx.relevance)
            }
            KvExchangePolicy::ByteBudget { bytes_per_round } => {
                let b = ctx
                    .row_budget
                    .unwrap_or(bytes_per_round / ctx.row_bytes.max(1));
                select_rows_by_budget(len, b, ctx.relevance)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn full_policy_transmits_all() {
        let mut rng = Xoshiro256ss::new(1);
        let tx = KvExchangePolicy::Full.transmitted(0, 2, 10, &mut rng);
        assert!(tx.iter().all(|&b| b));
    }

    #[test]
    fn random_ratio_approximate() {
        let mut rng = Xoshiro256ss::new(2);
        let mut kept = 0usize;
        let n = 20_000;
        let tx = KvExchangePolicy::Random { ratio: 0.3 };
        for _ in 0..n / 100 {
            kept += tx
                .transmitted(0, 1, 100, &mut rng)
                .iter()
                .filter(|&&b| b)
                .count();
        }
        let frac = kept as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.05, "kept fraction {frac}");
    }

    #[test]
    fn publisher_priority_keeps_publisher_full() {
        let mut rng = Xoshiro256ss::new(3);
        let p = KvExchangePolicy::PublisherPriority { remote_ratio: 0.2 };
        assert!(p.transmitted(2, 2, 50, &mut rng).iter().all(|&b| b));
        let remote = p.transmitted(0, 2, 50, &mut rng);
        assert!(remote.iter().filter(|&&b| b).count() < 40);
    }

    #[test]
    fn recent_budget_keeps_tail() {
        let mut rng = Xoshiro256ss::new(4);
        let p = KvExchangePolicy::RecentBudget { budget_rows: 3 };
        let tx = p.transmitted(0, 1, 8, &mut rng);
        assert_eq!(tx, vec![false, false, false, false, false, true, true, true]);
    }

    #[test]
    fn recent_budget_zero_transmits_one_row() {
        // Regression: budget 0 used to produce an empty transmission set.
        let mut rng = Xoshiro256ss::new(5);
        let p = KvExchangePolicy::RecentBudget { budget_rows: 0 };
        let tx = p.transmitted(0, 1, 6, &mut rng);
        assert_eq!(tx, vec![false, false, false, false, false, true]);
    }

    #[test]
    fn top_k_relevance_selects_by_score() {
        let mut rng = Xoshiro256ss::new(6);
        let p = KvExchangePolicy::TopKRelevance { budget_rows: 2 };
        let scores = [0.5, 9.0, 0.1, 4.0];
        let ctx = TxContext { relevance: Some(&scores), ..TxContext::basic(0, 1, 4) };
        assert_eq!(p.transmitted_ctx(&ctx, &mut rng), vec![false, true, false, true]);
        // Cold start (no scores): recency fallback.
        let tx = p.transmitted(0, 1, 4, &mut rng);
        assert_eq!(tx, vec![false, false, true, true]);
    }

    #[test]
    fn byte_budget_converts_bytes_to_rows() {
        let mut rng = Xoshiro256ss::new(7);
        let p = KvExchangePolicy::ByteBudget { bytes_per_round: 256 };
        let ctx = TxContext { row_bytes: 128, ..TxContext::basic(0, 1, 5) };
        // 256 B / 128 B-per-row = 2 rows; cold start picks the 2 most recent.
        assert_eq!(
            p.transmitted_ctx(&ctx, &mut rng),
            vec![false, false, false, true, true]
        );
    }

    #[test]
    fn coordinator_budget_overrides_policy_budget() {
        let mut rng = Xoshiro256ss::new(8);
        for p in [
            KvExchangePolicy::RecentBudget { budget_rows: 5 },
            KvExchangePolicy::TopKRelevance { budget_rows: 5 },
            KvExchangePolicy::ByteBudget { bytes_per_round: 5000 },
        ] {
            let ctx = TxContext { row_budget: Some(1), ..TxContext::basic(0, 1, 6) };
            let tx = p.transmitted_ctx(&ctx, &mut rng);
            assert_eq!(tx.iter().filter(|&&b| b).count(), 1, "{}", p.as_str());
        }
    }

    /// The never-empty invariant pinned across *all* policy variants
    /// (including adversarial parameters: ratio 0, budget 0).
    #[test]
    fn no_policy_transmits_empty_set() {
        let policies = [
            KvExchangePolicy::Full,
            KvExchangePolicy::Random { ratio: 0.0 },
            KvExchangePolicy::Random { ratio: 0.05 },
            KvExchangePolicy::PublisherPriority { remote_ratio: 0.0 },
            KvExchangePolicy::RecentBudget { budget_rows: 0 },
            KvExchangePolicy::RecentBudget { budget_rows: 3 },
            KvExchangePolicy::TopKRelevance { budget_rows: 0 },
            KvExchangePolicy::TopKRelevance { budget_rows: 4 },
            KvExchangePolicy::ByteBudget { bytes_per_round: 0 },
            KvExchangePolicy::ByteBudget { bytes_per_round: 1024 },
        ];
        propcheck(100, |rng| {
            let len = 1 + rng.below(30) as usize;
            let who = rng.below(3) as usize;
            let scores: Vec<f64> = (0..len).map(|_| rng.next_f64()).collect();
            for p in &policies {
                let ctx = TxContext {
                    row_bytes: 64,
                    relevance: rng.bernoulli(0.5).then_some(scores.as_slice()),
                    ..TxContext::basic(who, 1, len)
                };
                let tx = p.transmitted_ctx(&ctx, rng);
                if tx.len() != len {
                    return Err(format!("{}: mask length {}", p.as_str(), tx.len()));
                }
                if !tx.iter().any(|&b| b) {
                    return Err(format!("{}: empty transmission set", p.as_str()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn local_sparsity_protects_tail() {
        propcheck(100, |rng| {
            let len = 5 + rng.below(100) as usize;
            let keep = LocalSparsity { ratio: 0.3 }.select(len, 4, rng);
            for t in len - 4..len {
                if !keep.contains(&t) {
                    return Err(format!("tail token {t} dropped"));
                }
            }
            // strictly increasing
            for w in keep.windows(2) {
                if w[0] >= w[1] {
                    return Err("not sorted".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn full_sparsity_keeps_everything() {
        let mut rng = Xoshiro256ss::new(9);
        assert_eq!(
            LocalSparsity::full().select(7, 0, &mut rng),
            (0..7).collect::<Vec<_>>()
        );
    }
}
