//! Sparsity policies from the paper's §V duality toolkit.
//!
//! * `LocalSparsity` — sparse local attention (Fig. 9): each participant
//!   randomly subsamples its input tokens *before* inference.  Irreversible
//!   information loss ⇒ monotone quality degradation.
//! * `KvExchangePolicy` — sparse / adaptive KV exchange (Fig. 10 and §V
//!   Obs. 4): which of a participant's KV rows are transmitted at a sync
//!   block.  Own rows remain visible to their owner regardless.

use crate::util::prng::Xoshiro256ss;

/// Sparse local attention: keep each token independently with probability
/// `ratio` (the question-final "A:" anchor tokens are always kept so the
/// publisher can still decode).
#[derive(Debug, Clone, Copy)]
pub struct LocalSparsity {
    pub ratio: f64,
}

impl LocalSparsity {
    pub fn full() -> Self {
        Self { ratio: 1.0 }
    }

    /// Select which local indices (0..len) survive; always keeps at least
    /// one token and the final `protect_tail` tokens.
    pub fn select(&self, len: usize, protect_tail: usize, rng: &mut Xoshiro256ss) -> Vec<usize> {
        if self.ratio >= 1.0 || len == 0 {
            return (0..len).collect();
        }
        let protected_from = len.saturating_sub(protect_tail);
        let mut keep: Vec<usize> = (0..len)
            .filter(|&i| i >= protected_from || rng.bernoulli(self.ratio))
            .collect();
        if keep.is_empty() {
            keep.push(len - 1);
        }
        keep
    }
}

/// KV-exchange policy applied per participant per sync block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvExchangePolicy {
    /// Transmit every valid row (Alg. 1 baseline).
    Full,
    /// Transmit a uniform random subset of rows (Fig. 10).
    Random { ratio: f64 },
    /// Adaptive aggregation (§V Obs. 4): the publisher transmits all rows,
    /// other participants transmit a random `remote_ratio` subset.
    PublisherPriority { remote_ratio: f64 },
    /// Per-round budget: the `budget_rows` most recent rows (temporal
    /// recency heuristic from the sparse-attention literature [37]–[40]).
    RecentBudget { budget_rows: usize },
}

impl KvExchangePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            KvExchangePolicy::Full => "full",
            KvExchangePolicy::Random { .. } => "random",
            KvExchangePolicy::PublisherPriority { .. } => "publisher-priority",
            KvExchangePolicy::RecentBudget { .. } => "recent-budget",
        }
    }

    /// Which of `len` valid rows participant `who` transmits this round.
    /// Returns a boolean row mask.
    pub fn transmitted(
        &self,
        who: usize,
        publisher: usize,
        len: usize,
        rng: &mut Xoshiro256ss,
    ) -> Vec<bool> {
        match *self {
            KvExchangePolicy::Full => vec![true; len],
            KvExchangePolicy::Random { ratio } => {
                let mut tx: Vec<bool> =
                    (0..len).map(|_| rng.bernoulli(ratio)).collect();
                if ratio > 0.0 && !tx.iter().any(|&b| b) && len > 0 {
                    tx[len - 1] = true; // never transmit an empty set
                }
                tx
            }
            KvExchangePolicy::PublisherPriority { remote_ratio } => {
                if who == publisher {
                    vec![true; len]
                } else {
                    KvExchangePolicy::Random { ratio: remote_ratio }
                        .transmitted(who, publisher, len, rng)
                }
            }
            KvExchangePolicy::RecentBudget { budget_rows } => {
                let start = len.saturating_sub(budget_rows);
                (0..len).map(|i| i >= start).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;

    #[test]
    fn full_policy_transmits_all() {
        let mut rng = Xoshiro256ss::new(1);
        let tx = KvExchangePolicy::Full.transmitted(0, 2, 10, &mut rng);
        assert!(tx.iter().all(|&b| b));
    }

    #[test]
    fn random_ratio_approximate() {
        let mut rng = Xoshiro256ss::new(2);
        let mut kept = 0usize;
        let n = 20_000;
        let tx = KvExchangePolicy::Random { ratio: 0.3 };
        for _ in 0..n / 100 {
            kept += tx
                .transmitted(0, 1, 100, &mut rng)
                .iter()
                .filter(|&&b| b)
                .count();
        }
        let frac = kept as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.05, "kept fraction {frac}");
    }

    #[test]
    fn publisher_priority_keeps_publisher_full() {
        let mut rng = Xoshiro256ss::new(3);
        let p = KvExchangePolicy::PublisherPriority { remote_ratio: 0.2 };
        assert!(p.transmitted(2, 2, 50, &mut rng).iter().all(|&b| b));
        let remote = p.transmitted(0, 2, 50, &mut rng);
        assert!(remote.iter().filter(|&&b| b).count() < 40);
    }

    #[test]
    fn recent_budget_keeps_tail() {
        let mut rng = Xoshiro256ss::new(4);
        let p = KvExchangePolicy::RecentBudget { budget_rows: 3 };
        let tx = p.transmitted(0, 1, 8, &mut rng);
        assert_eq!(tx, vec![false, false, false, false, false, true, true, true]);
    }

    #[test]
    fn random_never_empty() {
        propcheck(100, |rng| {
            let len = 1 + rng.below(30) as usize;
            let tx = KvExchangePolicy::Random { ratio: 0.05 }
                .transmitted(0, 1, len, rng);
            if tx.iter().any(|&b| b) {
                Ok(())
            } else {
                Err("empty transmission set".into())
            }
        });
    }

    #[test]
    fn local_sparsity_protects_tail() {
        propcheck(100, |rng| {
            let len = 5 + rng.below(100) as usize;
            let keep = LocalSparsity { ratio: 0.3 }.select(len, 4, rng);
            for t in len - 4..len {
                if !keep.contains(&t) {
                    return Err(format!("tail token {t} dropped"));
                }
            }
            // strictly increasing
            for w in keep.windows(2) {
                if w[0] >= w[1] {
                    return Err("not sorted".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn full_sparsity_keeps_everything() {
        let mut rng = Xoshiro256ss::new(9);
        assert_eq!(
            LocalSparsity::full().select(7, 0, &mut rng),
            (0..7).collect::<Vec<_>>()
        );
    }
}
