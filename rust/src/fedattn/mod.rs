//! The FedAttn paradigm (paper Alg. 1 + §V toolkit) as a participant
//! protocol: per-participant nodes, typed round messages, pluggable KV
//! aggregation, sync schedules, sparsity policies, masks, and the session
//! driver running prefill + decode through the runtime.
//!
//! Semantics (matching the paper):
//!  * Every participant runs every Transformer block over its own tokens.
//!  * A participant *attending* globally at block `m` projects Q/K/V
//!    (Eq. 17), receives the other participants' transmitted KV rows for
//!    block `m`, aggregates them positionally (Eq. 20, the Π_n scatter) and
//!    attends with its local Q over the global KV (Eq. 21).
//!  * Non-attending participants perform plain local self-attention
//!    (Eq. 18).  Their K/V for the block exist anyway (computed by the
//!    fused block) and are what gets transmitted to attendees.
//!  * Sparse KV exchange (§V Obs. 4 / Fig. 10) drops *remote* rows only;
//!    a participant always sees its own full KV.
//!
//! Structure (the federated-optimization duality, made literal):
//!  * [`node`] — [`ParticipantNode`] owns one participant's state behind
//!    the [`Participant`] trait (local compute).
//!  * [`protocol`] — serializable round messages; their encoded payload
//!    sizes are the single source of truth for comm-byte accounting.
//!  * [`aggregate`] — the [`Aggregator`] policy object (global
//!    aggregation; concat and relevance-adaptive built-ins).
//!  * [`driver`] — [`SessionDriver`] sequences rounds purely through
//!    messages; dropout and attendance gaps are schedule inputs, and
//!    per-round deadlines turn link latency into partial aggregation.
//!  * [`transport`] — the wire deployment, node-resident: length-prefixed
//!    frames over channel or TCP transports, [`RemoteParticipant`]
//!    proxies, [`NodeHost`]s that own their participant's engine, hidden
//!    states and decode caches outright (only protocol messages ever
//!    cross the wire — never a hidden state or token embedding), and the
//!    [`TransportDriver`] (byte-identical to the in-process session at
//!    infinite deadline; a node lost mid-session is demoted like a
//!    deadline miss — or, with churn recovery on, put on probation and
//!    readmitted through the `Rejoin`/`Resync` handshake).  Connect
//!    retries ([`RetryPolicy`]) and the deterministic fault-injection
//!    decorator ([`ChaosTransport`]) live here too.
//!  * [`session`] — the [`FedSession`] facade (byte-identical to the
//!    pre-protocol session).

pub mod aggregate;
pub mod driver;
pub mod kv;
pub mod masks;
pub mod node;
pub mod protocol;
pub mod relevance;
pub mod schedule;
pub mod session;
pub mod sparse;
pub mod transport;

pub use aggregate::{for_policy, AdaptiveAggregator, Aggregator, ConcatAggregator};
pub use driver::{
    DecodeHandle, DecodeMachine, DecodeStep, PrefillOutput, Reconnector, SessionConfig,
    SessionDriver, SessionReport,
};
pub use kv::{GlobalKv, KvRowMeta};
pub use masks::{decode_mask, decode_mask_set_visible, global_mask, local_mask};
pub use node::{Participant, ParticipantNode};
pub use protocol::{
    requantize_row, wire_kind, DecodeTail, GlobalKvDeltaFrame, GlobalKvFrame, KvContribution,
    KvPrecision, TokenBroadcast, WireError, WireKind,
};
pub use relevance::RelevanceTracker;
pub use schedule::{Scheme, SyncSchedule};
pub use session::FedSession;
pub use sparse::{KvExchangePolicy, LocalSparsity, TxContext};
pub use transport::{
    read_timeout_for_deadline, read_timeout_for_deadline_with_grace, ChannelTransport,
    ChaosTransport, CtrlMsg, Fault, FaultSchedule, NodeHost, RemoteParticipant, RetryPolicy,
    TcpTransport, Transport, TransportDriver, TransportError,
};
