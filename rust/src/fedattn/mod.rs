//! The FedAttn paradigm (paper Alg. 1 + §V toolkit): participant state,
//! sync schedules, KV exchange & aggregation, sparsity policies, masks and
//! the per-task session driving prefill + decode through the runtime.
//!
//! Semantics (matching the paper):
//!  * Every participant runs every Transformer block over its own tokens.
//!  * A participant *attending* globally at block `m` projects Q/K/V
//!    (Eq. 17), receives the other participants' transmitted KV rows for
//!    block `m`, aggregates them positionally (Eq. 20, the Π_n scatter) and
//!    attends with its local Q over the global KV (Eq. 21).
//!  * Non-attending participants perform plain local self-attention
//!    (Eq. 18).  Their K/V for the block exist anyway (computed by the
//!    fused block) and are what gets transmitted to attendees.
//!  * Sparse KV exchange (§V Obs. 4 / Fig. 10) drops *remote* rows only;
//!    a participant always sees its own full KV.

pub mod kv;
pub mod masks;
pub mod relevance;
pub mod schedule;
pub mod session;
pub mod sparse;

pub use kv::{GlobalKv, KvRowMeta};
pub use masks::{decode_mask, decode_mask_set_visible, global_mask, local_mask};
pub use relevance::RelevanceTracker;
pub use schedule::{Scheme, SyncSchedule};
pub use session::{FedSession, PrefillOutput, SessionConfig, SessionReport};
pub use sparse::{KvExchangePolicy, LocalSparsity, TxContext};
